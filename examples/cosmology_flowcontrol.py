"""Cosmology use case (paper §4.2.2): Nyx + Reeber with flow control and the
custom double-open/close I/O idiom handled by an external action script.

Wilkins features exercised:
  * custom actions (paper Listing 5) from a user script -- task code unchanged,
  * flow control ``io_freq: 2`` (the 'some' strategy, paper Table 3),
  * filename glob ports (``plt*.h5``).

    PYTHONPATH=src python examples/cosmology_flowcontrol.py
"""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Wilkins, h5

GRID = 48
SNAPSHOTS = 10

ACTION_SCRIPT = '''
def nyx(vol, rank):
    """Paper Listing 5: serve only at the second close of each snapshot."""
    def afc_cb(f):
        if vol.file_close_counter % 2 == 1:
            vol.clear_files()          # 1st close: single-rank metadata write
        else:
            vol.serve_all(True, True)  # 2nd close: bulk data -> consumer
            vol.clear_files()
            vol.broadcast_files()
    vol.set_after_file_close(afc_cb)
'''

WORKFLOW = """
tasks:
  - func: nyx
    nprocs: 1024
    actions: ["nyx_actions", "nyx"]
    outports:
      - filename: plt*.h5
        dsets:
          - {name: /level_0/density, memory: 1}
  - func: reeber
    nprocs: 64
    inports:
      - filename: plt*.h5
        io_freq: 2   # 'some' flow control: analyze every 2nd snapshot
        dsets:
          - {name: /level_0/density, memory: 1}
"""


@jax.jit
def nyx_step(rho, key):
    lap = sum(jnp.roll(rho, s, a) for a in range(3) for s in (1, -1)) - 6 * rho
    return jnp.clip(rho + 0.1 * lap
                    + 0.06 * jax.random.normal(key, rho.shape) * rho, 0.0, None)


@jax.jit
def find_halos(rho, cutoff=1.05):
    return jnp.sum(rho > cutoff)


def nyx():
    key = jax.random.PRNGKey(0)
    rho = jnp.ones((GRID, GRID, GRID))
    for t in range(SNAPSHOTS):
        key = jax.random.fold_in(key, t)
        rho = nyx_step(rho, key)
        # Nyx's custom I/O: open/close twice per snapshot (paper §4.2.2)
        with h5.File(f"plt{t:05d}.h5", "w") as f:   # 1st: metadata from rank 0
            f.create_dataset("/level_0/density", data=np.zeros(1, np.float32))
        with h5.File(f"plt{t:05d}.h5", "w") as f:   # 2nd: bulk parallel write
            ds = f.create_dataset("/level_0/density", data=np.asarray(rho))
            ds.attrs["a"] = 1.0 / (1.0 + 10 - t)     # scale factor


def reeber():
    analyzed = 0
    while True:
        f = h5.File("plt*.h5", "r")
        if f is None:
            break
        rho = jnp.asarray(f["/level_0/density"][:])
        n = int(find_halos(rho))
        time.sleep(0.1)  # Reeber is slower than Nyx (why flow control exists)
        print(f"[reeber] {f.filename}: {n} halo cells above cutoff")
        analyzed += 1
    print(f"[reeber] analyzed {analyzed}/{SNAPSHOTS} snapshots "
          f"(io_freq=2 -> every 2nd)")


if __name__ == "__main__":
    with tempfile.TemporaryDirectory() as d:
        with open(os.path.join(d, "nyx_actions.py"), "w") as f:
            f.write(ACTION_SCRIPT)
        w = Wilkins(WORKFLOW, {"nyx": nyx, "reeber": reeber},
                    action_dirs=[d])
        report = w.run(timeout=300)
        print(report.summary())
