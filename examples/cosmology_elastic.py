"""Elastic cosmology pipeline: the halo finder loses an instance mid-run and
the workflow RESIZES it instead of merely restarting it.

Wilkins features exercised:
  * ``on_failure: {rescale: {nslots: N}}`` -- a supervised restart that
    changes the task's instance count: the surgery re-cuts the sharded
    checkpoints with ``reshard_blocks``, rebuilds the redistributing
    channels for the new partition, and replays the undelivered snapshots
    into the re-partitioned consumers,
  * ``stall_timeout_s:`` + the health watchdog -- a silent (hung, not
    crashed) instance is declared stalled after the window and the same
    rescale policy fences it and brings the task back smaller,
  * ``comm.checkpoint(state, sharded_axes={"counts": 0})`` -- the
    accumulator is each instance's shard of a global array, which is what
    makes the M->N re-cut well-defined,
  * rescale visibility: RESCALE / STALL lines in ``report.summary()`` and
    discrete events on the telemetry timeline.

The acceptance property (same as ``tests/test_rescale.py``): the resized
run's halo counts, concatenated over the final instances, are byte-identical
to a crash-free run's at the original size.

    PYTHONPATH=src python examples/cosmology_elastic.py
"""

import numpy as np

from repro.core import FaultSpec, Wilkins, h5, world
from repro.core.redistribute import even_blocks

GRID = 32
SNAPSHOTS = 8

WORKFLOW = """
tasks:
  - func: nyx
    nprocs: 64
    on_failure:
      restart: {max_retries: 3}
    outports:
      - filename: plt*.h5
        dsets:
          - {name: /level_0/density, memory: 1}
  - func: reeber
    taskCount: 2          # two halo-finder instances, each owns a slab
    stall_timeout_s: 0.3  # health watchdog: silence past this is a stall
    on_failure:
      rescale: {nslots: 1, max_retries: 3}   # come back at HALF size
    inports:
      - filename: plt*.h5
        redistribute: 1   # slab decomposition along axis 0
        dsets:
          - {name: /level_0/density, memory: 1}
"""


def evolve(rho, t):
    """One deterministic diffusion step (pure function of (state, t))."""
    lap = sum(np.roll(rho, s, a) for a in range(3) for s in (1, -1)) - 6 * rho
    return np.clip(rho + 0.1 * lap + 0.01 * np.sin(t + rho), 0.0, None)


def nyx(comm):
    state = {"rho": np.ones((GRID, GRID, GRID), np.float64),
             "t": np.zeros((), np.int64)}
    restored = comm.restore(state)
    if restored is not None:
        state = restored[1]
    for t in range(int(state["t"]), SNAPSHOTS):
        rho = evolve(state["rho"], t)
        with h5.File(f"plt{t:05d}.h5", "w") as f:
            f.create_dataset("/level_0/density", data=rho)
        state = {"rho": rho, "t": np.array(t + 1, np.int64)}
        comm.checkpoint(state)


RESULTS = {}


def reeber():
    """Halo finder over ITS slab of the density grid.

    The body is size-oblivious: the slab extent comes from the instance's
    frozen ``RedistSpec``, so the same function runs before the rescale
    (2 instances, half the grid each) and after (1 instance, whole grid) --
    the post-rescale incarnation restores a re-cut shard of ``counts``.
    """
    comm = world()
    spec = comm.resolve_redist_spec(port="plt*.h5")
    _, (rows,) = even_blocks((GRID,), spec.nslots)[spec.slot]
    state = {"counts": np.zeros((rows, SNAPSHOTS), np.int64),
             "n": np.zeros((), np.int64)}
    restored = comm.restore(state)
    if restored is not None:
        state = restored[1]
        print(f"[reeber{comm.instance}] attempt {comm.attempt}: resumed "
              f"after {int(state['n'])} snapshots with a {rows}-row shard")
    counts, n = state["counts"].copy(), int(state["n"])
    while True:
        f = h5.File("plt*.h5", "r")
        if f is None:
            break
        slab = f["/level_0/density"][...]   # THIS instance's rows only
        counts[:, n] = np.sum(slab > 1.01, axis=(1, 2))
        n += 1
        comm.checkpoint({"counts": counts, "n": np.array(n, np.int64)},
                        sharded_axes={"counts": 0})
    RESULTS[comm.instance] = counts.copy()


def run(tag, faults=None):
    RESULTS.clear()
    w = Wilkins(WORKFLOW, {"nyx": nyx, "reeber": reeber})
    report = w.run(timeout=300, faults=faults)
    final = w.graph.tasks["reeber"].task_count
    counts = np.concatenate([RESULTS[j] for j in range(final)], axis=0)
    print(f"[{tag}] reeber finished at taskCount={final}; per-snapshot halo "
          f"cells: {counts.sum(axis=0).tolist()}")
    return report, counts


if __name__ == "__main__":
    print("=== crash-free reference run (2 halo-finder instances) ===")
    _, ref = run("reference")

    print("\n=== faulted run: reeber[0] crashes at snapshot 2 -> policy "
          "rescale 2->1 ===")
    report, crash_counts = run("crash", faults=FaultSpec(
        task="reeber", point="recv", step=2, instance=0))
    print("\n" + report.summary())
    assert len(report.rescales) == 1
    assert (report.rescales[0]["old_nslots"],
            report.rescales[0]["new_nslots"]) == (2, 1)
    assert crash_counts.tobytes() == ref.tobytes(), \
        "rescaled run diverged from the reference"

    print("\n=== stalled run: reeber[1] hangs (no crash) -> watchdog "
          "declares a stall -> rescale 2->1 ===")
    report, stall_counts = run("stall", faults=FaultSpec(
        task="reeber", kind="stall", point="recv", step=1, instance=1,
        seconds=2.0))
    print("\n" + report.summary())
    assert len(report.stalls) == 1 and report.stalls[0]["action"] == "rescale"
    assert report.rescales[0]["trigger"] == "stall"
    assert stall_counts.tobytes() == ref.tobytes(), \
        "watchdog-rescaled run diverged from the reference"

    print("\nrecovered: one policy rescale + one watchdog rescale, halo "
          "counts byte-identical to the crash-free run")
