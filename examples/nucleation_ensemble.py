"""Materials-science use case (paper §4.2.1): an NxN ensemble of MD
simulations coupled in situ to crystal-nucleation detectors.

Wilkins features exercised:
  * ensembles via one ``taskCount`` line (paper Listing 4),
  * subset writers (``nwriters: 1`` -- the LAMMPS gather-to-rank-0 idiom),
  * stateless consumers relaunched per snapshot by the query protocol.

    PYTHONPATH=src python examples/nucleation_ensemble.py [n_instances]
"""

import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Wilkins, h5, world

N_INSTANCES = int(sys.argv[1]) if len(sys.argv) > 1 else 4
N_ATOMS = 512
TIMESTEPS = 5

WORKFLOW = f"""
tasks:
  - func: freeze
    taskCount: {N_INSTANCES}   # the only change needed to define ensembles
    nprocs: 32
    nwriters: 1                # only rank 0 performs I/O (LAMMPS idiom)
    outports:
      - filename: dump-h5md.h5
        dsets:
          - {{name: /particles/*, memory: 1}}
  - func: detector
    taskCount: {N_INSTANCES}
    nprocs: 8
    inports:
      - filename: dump-h5md.h5
        dsets:
          - {{name: /particles/*, memory: 1}}
"""


@jax.jit
def md_step(pos, key, temp):
    """Toy water-freezing dynamics: cooled random kicks + soft repulsion."""
    kick = jax.random.normal(key, pos.shape) * temp
    d = pos[:, None, :] - pos[None, :, :]
    r2 = jnp.sum(d * d, axis=-1) + jnp.eye(pos.shape[0])
    force = jnp.sum(d / (r2[..., None] ** 2 + 0.1), axis=1)
    return pos + 1e-3 * force + kick


@jax.jit
def diamond_detector(pos, cutoff=0.25):
    """Count atoms with >=4 neighbours inside the cutoff ('nucleated')."""
    d = pos[:, None, :] - pos[None, :, :]
    r = jnp.sqrt(jnp.sum(d * d, axis=-1))
    neigh = jnp.sum((r < cutoff) & (r > 0), axis=1)
    return jnp.sum(neigh >= 4)


_lock = threading.Lock()
detections = {}


def freeze():
    comm = world()  # restricted world: instance id, io-proc role
    key = jax.random.PRNGKey(comm.instance)
    pos = jax.random.uniform(key, (N_ATOMS, 3))
    for t in range(TIMESTEPS):
        key = jax.random.fold_in(key, t)
        temp = 0.02 * (1.0 - t / TIMESTEPS)  # cooling schedule
        pos = md_step(pos, key, temp)
        if comm.is_io_proc():   # subset writers: rank 0 only
            with h5.File("dump-h5md.h5", "w") as f:
                ds = f.create_dataset("/particles/pos", data=np.asarray(pos))
                ds.attrs["timestep"] = t
                ds.attrs["instance"] = comm.instance


def detector():
    comm = world()
    f = h5.File("dump-h5md.h5", "r")
    if f is None:
        return
    n = int(diamond_detector(jnp.asarray(f["/particles/pos"][:])))
    t = int(f["/particles/pos"].attrs["timestep"])
    with _lock:
        detections.setdefault(comm.instance, []).append((t, n))


if __name__ == "__main__":
    w = Wilkins(WORKFLOW, {"freeze": freeze, "detector": detector})
    report = w.run(timeout=300)
    for inst in sorted(detections):
        series = sorted(detections[inst])
        print(f"instance {inst}: nucleated counts {[n for _, n in series]}")
    rare = max(detections, key=lambda i: max(n for _, n in detections[i]))
    print(f"-> most nucleation observed in instance {rare} "
          f"(the 'rare event' the ensemble exists to catch)")
    print(report.summary())
