"""Cosmology use case, adaptive-scheduler variant: Nyx feeding TWO analysis
consumers with disparate data rates, arbitrated at runtime instead of by
hand-tuned static knobs (compare ``cosmology_flowcontrol.py``, which solves
the same rate mismatch statically with ``io_freq: 2``).

Wilkins features exercised:
  * top-level ``scheduler:`` block -- ``policy: fair`` (deficit-weighted
    round-robin prep arbitration) with a telemetry timeline,
  * per-inport ``weight:`` -- the halo finder (3) outweighs the spectrum
    probe (1) for prefetch-pool service under contention,
  * per-inport ``autotune:`` -- the halo finder's prefetch depth floats in
    [1, 4], widened while its consumer blocks and narrowed when preps idle,
  * telemetry export -- the per-edge timeline ring lands in a JSON file any
    SIM-SITU-style replay tool can consume.

    PYTHONPATH=src python examples/cosmology_scheduler.py
"""

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Wilkins, h5

GRID = 32
SNAPSHOTS = 12

WORKFLOW = """
scheduler:
  policy: fair       # DWRR over per-edge prep queues (fifo = legacy order)
  tick_every: 2      # autotuner/telemetry tick period, in step events
  telemetry: 512     # timeline ring capacity (samples)
tasks:
  - func: nyx
    nprocs: 4
    outports:
      - filename: plt*.h5
        ownership: {axis: 0}
        dsets:
          - {name: /level_0/density, memory: 1}
  - func: reeber
    nprocs: 2
    inports:
      - filename: plt*.h5
        redistribute: 1
        weight: 3              # halo finding outweighs the spectrum probe
        autotune: {min: 1, max: 4}
        queue_depth: 4
        dsets:
          - {name: /level_0/density, memory: 1}
  - func: spectrum
    nprocs: 2
    inports:
      - filename: plt*.h5
        redistribute: 1
        weight: 1
        prefetch: 1
        dsets:
          - {name: /level_0/density, memory: 1}
"""


@jax.jit
def nyx_step(rho, key):
    lap = sum(jnp.roll(rho, s, a) for a in range(3) for s in (1, -1)) - 6 * rho
    return jnp.clip(rho + 0.1 * lap
                    + 0.06 * jax.random.normal(key, rho.shape) * rho, 0.0, None)


@jax.jit
def find_halos(rho, cutoff=1.05):
    return jnp.sum(rho > cutoff)


def nyx():
    key = jax.random.PRNGKey(0)
    rho = jnp.ones((GRID, GRID, GRID))
    for t in range(SNAPSHOTS):
        key = jax.random.fold_in(key, t)
        rho = nyx_step(rho, key)
        with h5.File(f"plt{t:05d}.h5", "w") as f:
            ds = f.create_dataset("/level_0/density",
                                  data=np.asarray(rho).reshape(GRID, -1))
            ds.attrs["a"] = 1.0 / (1.0 + SNAPSHOTS - t)


def reeber():
    analyzed = 0
    while True:
        f = h5.File("plt*.h5", "r")
        if f is None:
            break
        rho = jnp.asarray(f["/level_0/density"][:])
        n = int(find_halos(rho))
        time.sleep(0.02)  # halo finding is the slow consumer
        print(f"[reeber] {f.filename}: {n} halo cells above cutoff")
        analyzed += 1
    print(f"[reeber] analyzed {analyzed}/{SNAPSHOTS} snapshots")


def spectrum():
    while True:
        f = h5.File("plt*.h5", "r")
        if f is None:
            break
        rho = np.asarray(f["/level_0/density"][:])
        print(f"[spectrum] {f.filename}: mean density {rho.mean():.4f}")


if __name__ == "__main__":
    w = Wilkins(WORKFLOW, {"nyx": nyx, "reeber": reeber,
                           "spectrum": spectrum})
    report = w.run(timeout=300)
    print(report.summary())
    out = os.path.join(tempfile.gettempdir(), "cosmology_timeline.json")
    report.timeline.export(out)
    print(f"telemetry timeline ({len(report.timeline)} samples) -> {out}")
