"""End-to-end driver: train an LM while an in-situ evaluator consumes
checkpoints over the Wilkins transport -- the paper's thesis applied to ML.

The trainer is the "simulation": every ``eval_every`` steps it writes the
model parameters + step metadata as an HDF5-style file (no workflow API in
the train loop -- ordinary h5 writes).  The evaluator is a slower consumer
that scores held-out batches; flow control ``latest`` (io_freq: -1) means the
trainer NEVER blocks on a slow evaluator -- stale checkpoints are dropped and
the evaluator always scores the freshest weights.  That is exactly the
paper's in-situ coupling (bypass the filesystem, rate-mismatch handled by
flow control), with the checkpoint store in the role of the parallel
filesystem being bypassed.

    PYTHONPATH=src python examples/train_insitu_eval.py                # demo (~8M params)
    PYTHONPATH=src python examples/train_insitu_eval.py --preset 100m  # ~114M params
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Wilkins, h5
from repro.models.config import ModelConfig
from repro.train import (AdamWConfig, DataConfig, SyntheticCorpus, init_state,
                         make_train_step)

PRESETS = {
    # family-faithful llama-style configs
    "demo": ModelConfig(name="demo-8m", family="dense", n_layers=4,
                        d_model=192, n_heads=4, n_kv_heads=2, d_ff=512,
                        vocab=4096, dtype="float32", loss_chunk=128),
    "100m": ModelConfig(name="lm-114m", family="dense", n_layers=12,
                        d_model=768, n_heads=12, n_kv_heads=4, d_ff=2048,
                        vocab=32000, dtype="float32", loss_chunk=256),
}

WORKFLOW = """
tasks:
  - func: trainer
    nprocs: 8
    outports:
      - filename: ckpt*.h5
        dsets:
          - {name: /model/*, memory: 1}
          - {name: /meta/*, memory: 1}
  - func: evaluator
    nprocs: 2
    inports:
      - filename: ckpt*.h5
        io_freq: -1   # 'latest': never block training on a slow evaluator
        dsets:
          - {name: /model/*, memory: 1}
          - {name: /meta/*, memory: 1}
"""


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="demo")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--eval-every", type=int, default=10)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    steps = args.steps or (120 if args.preset == "demo" else 300)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=max(1, steps // 20),
                       total_steps=steps)
    print(f"model: {cfg.name} ({cfg.param_count() / 1e6:.1f}M params), "
          f"{steps} steps, batch {args.batch} x seq {args.seq}")

    train_data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                            global_batch=args.batch, seed=0)
    eval_data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch, seed=10_000)  # held out

    leaves0, treedef = jax.tree_util.tree_flatten(
        init_state(jax.random.PRNGKey(0), cfg, ocfg).params)

    def trainer():
        state = init_state(jax.random.PRNGKey(0), cfg, ocfg)
        step_fn = jax.jit(make_train_step(cfg, ocfg), donate_argnums=0)
        corpus = SyntheticCorpus(train_data)
        t0 = time.monotonic()
        for step in range(steps):
            batch = {k: jnp.asarray(v) for k, v in corpus.batch(step).items()}
            state, metrics = step_fn(state, batch)
            if (step + 1) % args.eval_every == 0:
                # ordinary h5 write; 'latest' flow control decides delivery
                with h5.File(f"ckpt{step + 1:06d}.h5", "w") as f:
                    for i, leaf in enumerate(
                            jax.tree_util.tree_leaves(state.params)):
                        f.create_dataset(f"/model/p{i}", data=np.asarray(leaf))
                    f.create_dataset(
                        "/meta/info",
                        data=np.array([step + 1, float(metrics["loss"])],
                                      np.float64))
            if (step + 1) % 20 == 0:
                tput = (step + 1) * args.batch * args.seq / (time.monotonic() - t0)
                print(f"[trainer] step {step + 1:4d} "
                      f"loss {float(metrics['loss']):.4f} tok/s {tput:,.0f}")

    evals = []

    def evaluator():
        corpus = SyntheticCorpus(eval_data)
        from repro.models.registry import get_family
        loss_fn = jax.jit(
            lambda p, b: get_family(cfg).loss_fn(p, cfg, b))
        while True:
            f = h5.File("ckpt*.h5", "r")
            if f is None:
                break
            leaves = [jnp.asarray(f[f"/model/p{i}"][:])
                      for i in range(len(leaves0))]
            params = jax.tree_util.tree_unflatten(treedef, leaves)
            step, train_loss = f["/meta/info"][:]
            batch = {k: jnp.asarray(v) for k, v in corpus.batch(0).items()}
            ev = float(loss_fn(params, batch))
            evals.append((int(step), ev))
            print(f"[eval]    step {int(step):4d} "
                  f"train {train_loss:.4f} held-out {ev:.4f}")

    w = Wilkins(WORKFLOW, {"trainer": trainer, "evaluator": evaluator})
    report = w.run(timeout=3600)
    print(report.summary())
    assert evals, "evaluator never ran"
    assert evals[-1][1] < evals[0][1] + 0.5, "eval loss diverged"
    dropped = report.total_dropped
    print(f"in-situ evals: {len(evals)}; checkpoints dropped by 'latest' "
          f"flow control: {dropped} (training never blocked)")


if __name__ == "__main__":
    main()
