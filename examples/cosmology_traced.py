"""Traced cosmology pipeline: the fault-tolerant Nyx+Reeber workflow with
run-wide span tracing ON, exporting a Perfetto timeline and printing the
critical-path attribution.

Wilkins features exercised (PR 10, ``repro.obs``):
  * ``tracing:`` in the workflow YAML (equivalently ``run(trace=...)``) --
    every layer records closed spans into the run's lock-sharded
    ``SpanRecorder``: VOL open/close rendezvous, channel offer/get block
    intervals, prefetch preps and waits, reshard executes, checkpoint
    save/restore, restart surgery, plus queue-depth/in-flight counters;
  * ``trace.json`` -- one Chrome/Perfetto artifact (load it at
    https://ui.perfetto.dev): a track per task instance, flow arrows from
    each producer offer to its consumer receive, telemetry instants for
    the restart/drop lifecycle events;
  * critical-path attribution in ``report.summary()`` -- each instance's
    wall split into block / prep / reshard / checkpoint / recovery /
    compute, per-step rows on the critical instance, per-edge hand-off
    costs (the same report ``python -m repro.obs report trace.json``
    produces offline);
  * the flight recorder -- on a TERMINAL failure (retries exhausted, stall
    declared, join timeout) the most recent spans of every instance are
    snapshotted into ``report.flight_recorder`` alongside the chained
    error; the recovered crash below leaves its mark as ``recovery`` spans
    and an aborted ``channel.get`` instead.

    PYTHONPATH=src python examples/cosmology_traced.py
"""

import numpy as np

from repro.core import FaultSpec, Wilkins, h5
from repro.obs import load_trace, span_categories

GRID = 24
SNAPSHOTS = 6
TRACE_PATH = "trace.json"

WORKFLOW = f"""
tasks:
  - func: nyx
    nprocs: 4
    on_failure:
      restart: {{max_retries: 3, backoff_s: 0.02}}
    outports:
      - filename: plt*.h5
        dsets:
          - {{name: /level_0/density, memory: 1}}
  - func: reeber
    taskCount: 2
    nprocs: 2
    on_failure:
      restart: {{max_retries: 3}}
    inports:
      - filename: plt*.h5
        redistribute: 1
        prefetch: 2
        dsets:
          - {{name: /level_0/density, memory: 1}}
tracing:
  path: {TRACE_PATH}
  flight_len: 128
"""


def evolve(rho, t):
    lap = sum(np.roll(rho, s, a) for a in range(3) for s in (1, -1)) - 6 * rho
    return np.clip(rho + 0.1 * lap + 0.01 * np.sin(t + rho), 0.0, None)


def nyx(comm):
    state = {"rho": np.ones((GRID, GRID, GRID), np.float64),
             "t": np.zeros((), np.int64)}
    restored = comm.restore(state)
    if restored is not None:
        state = restored[1]
    for t in range(int(state["t"]), SNAPSHOTS):
        rho = evolve(state["rho"], t)
        with h5.File(f"plt{t:05d}.h5", "w") as f:
            f.create_dataset("/level_0/density", data=rho)
        state = {"rho": rho, "t": np.array(t + 1, np.int64)}
        comm.checkpoint(state)


def reeber(comm):
    state = {"n": np.zeros((), np.int64)}
    restored = comm.restore(state)
    if restored is not None:
        state = restored[1]
    n = int(state["n"])
    while True:
        f = h5.File("plt*.h5", "r")
        if f is None:
            break
        # this instance's share of the flattened density field (M->N)
        blocks = comm.reshard(np.asarray(f["/level_0/density"][...]).ravel())
        halo_cells = int(sum((np.asarray(b) > 1.01).sum() for b in blocks))
        n += 1
        comm.checkpoint({"n": np.array(n, np.int64)})


if __name__ == "__main__":
    funcs = {"nyx": nyx, "reeber": reeber}
    print("=== traced faulted run: reeber[1] dies in the delivered-but-"
          "unseen window at snapshot 2 ===")
    report = Wilkins(WORKFLOW, funcs).run(
        timeout=300,
        faults=FaultSpec(task="reeber", point="recv", step=2, instance=1))
    print("\n" + report.summary())

    spans = load_trace(TRACE_PATH)
    layers = span_categories(spans)
    print(f"\nexported {TRACE_PATH}: {report.trace_spans} spans, "
          f"layers={layers}")
    aborted = [s for s in spans if (s["args"] or {}).get("aborted")]
    print(f"recovered crash left {len(aborted)} aborted interval(s) and "
          f"{sum(1 for s in spans if s['cat'] == 'recovery')} recovery "
          f"span(s); flight dumps (terminal failures only): "
          f"{len(report.flight_recorder)}")
    assert report.trace_path == TRACE_PATH
    assert len(report.restarts) == 1
    assert {"vol", "channel", "prefetch", "reshard", "checkpoint",
            "recovery"} <= set(layers), layers
    print("\nopen the timeline at https://ui.perfetto.dev, or re-run the "
          "analysis offline:\n    PYTHONPATH=src python -m repro.obs "
          f"report {TRACE_PATH}")
