"""Quickstart: the paper's Listing 1 workflow, end to end, in ~60 lines.

One producer writes a grid and a particle list per timestep; two consumers
each subscribe to one dataset.  The task codes below do ordinary HDF5-style
I/O -- no workflow API calls -- and the YAML is byte-for-byte the shape of the
paper's Listing 1.

    PYTHONPATH=src python examples/quickstart.py

Before running a workflow, the pre-run analyzer ("wilkins check") builds
the task/port/edge graph from the YAML without executing anything and
flags deadlock cycles, flow-control hazards, illegal decompositions, and
policy errors -- every finding in one pass, anchored to the offending
YAML line:

    PYTHONPATH=src python -m repro.analysis check examples/quickstart.py
    PYTHONPATH=src python -m repro.analysis codes   # the full WLK registry
"""

import numpy as np

from repro.core import Wilkins, h5

WORKFLOW = """
tasks:
  - func: producer
    nprocs: 4
    outports:
      - filename: outfile.h5
        dsets:
          - {name: /group1/grid, file: 0, memory: 1}
          - {name: /group1/particles, file: 0, memory: 1}
  - func: consumer1
    nprocs: 5
    inports:
      - filename: outfile.h5
        dsets:
          - {name: /group1/grid, file: 0, memory: 1}
  - func: consumer2
    nprocs: 3
    inports:
      - filename: outfile.h5
        dsets:
          - {name: /group1/particles, file: 0, memory: 1}
"""


def producer():
    """An unmodified simulation: writes one file per timestep."""
    for t in range(5):
        with h5.File("outfile.h5", "w") as f:
            f.create_dataset("/group1/grid",
                             data=np.arange(1_000_000, dtype=np.uint64) + t)
            f.create_dataset("/group1/particles",
                             data=np.random.default_rng(t)
                             .random((1_000_000, 3)).astype(np.float32))


def consumer1():
    """Stateful analysis: runs once, loops over timesteps itself."""
    total = 0
    while True:
        f = h5.File("outfile.h5", "r")
        if f is None:          # producer says all-done (query protocol)
            break
        total += int(f["/group1/grid"][0])
    print(f"[consumer1] sum of grid[0] over timesteps = {total}")


def consumer2():
    """Stateless analysis: the driver relaunches it per timestep."""
    f = h5.File("outfile.h5", "r")
    if f is None:
        return
    parts = f["/group1/particles"][:]
    print(f"[consumer2] mean particle = {parts.mean(axis=0).round(3)}")


if __name__ == "__main__":
    w = Wilkins(WORKFLOW, {"producer": producer,
                           "consumer1": consumer1,
                           "consumer2": consumer2})
    report = w.run(timeout=120)
    print(report.summary())
