"""Fault-tolerant cosmology pipeline: a simulation and its halo finder both
survive mid-run crashes, and an optional visualization task degrades to a
no-op instead of killing the run.

Wilkins features exercised:
  * per-task YAML ``on_failure:`` policies -- ``restart`` (with retries,
    backoff, deterministic jitter) for the tasks whose output matters,
    ``drop`` for the optional rider,
  * ``comm.checkpoint()`` / ``comm.restore()`` -- per-step state snapshots
    through the run's AsyncCheckpointer; a restarted incarnation resumes
    instead of recomputing (and the channel acks make replay exact),
  * deterministic fault injection via ``Wilkins.run(faults=...)`` -- the
    crashes below land at exact step boundaries, every run,
  * recovery visibility: RESTART / DROPPED lines in ``report.summary()``
    and discrete events on the telemetry timeline.

The acceptance property (same as ``tests/test_recovery.py``): the crashed
run's halo counts are identical to a crash-free run's.

    PYTHONPATH=src python examples/cosmology_faulttolerant.py
"""

import numpy as np

from repro.core import FaultSpec, Wilkins, h5

GRID = 32
SNAPSHOTS = 8

WORKFLOW = """
tasks:
  - func: nyx
    nprocs: 64
    on_failure:
      restart: {max_retries: 3, backoff_s: 0.05, jitter: 0.02}
    outports:
      - filename: plt*.h5
        dsets:
          - {name: /level_0/density, memory: 1}
  - func: reeber
    nprocs: 16
    on_failure:
      restart: {max_retries: 3}
    inports:
      - filename: plt*.h5
        dsets:
          - {name: /level_0/density, memory: 1}
  - func: viz
    on_failure: drop      # optional rider: a crash degrades it to a no-op
    inports:
      - filename: plt*.h5
        io_freq: 2
        dsets:
          - {name: /level_0/density, memory: 1}
"""


def evolve(rho, t):
    """One deterministic diffusion step (pure function of (state, t))."""
    lap = sum(np.roll(rho, s, a) for a in range(3) for s in (1, -1)) - 6 * rho
    return np.clip(rho + 0.1 * lap + 0.01 * np.sin(t + rho), 0.0, None)


def nyx(comm):
    """Simulation with per-snapshot checkpoints: a restart resumes from the
    last snapshot instead of re-running the whole history."""
    state = {"rho": np.ones((GRID, GRID, GRID), np.float64),
             "t": np.zeros((), np.int64)}
    restored = comm.restore(state)
    if restored is not None:
        state = restored[1]
        print(f"[nyx] attempt {comm.attempt}: resumed at snapshot "
              f"{int(state['t'])} (epoch {comm.epoch})")
    for t in range(int(state["t"]), SNAPSHOTS):
        rho = evolve(state["rho"], t)
        with h5.File(f"plt{t:05d}.h5", "w") as f:
            f.create_dataset("/level_0/density", data=rho)
        state = {"rho": rho, "t": np.array(t + 1, np.int64)}
        comm.checkpoint(state)  # durable BEFORE acking the serve


def reeber(comm):
    """Halo finder accumulating counts; checkpoints after every snapshot so
    a crash replays exactly one delivery."""
    state = {"counts": np.zeros(SNAPSHOTS, np.int64),
             "n": np.zeros((), np.int64)}
    restored = comm.restore(state)
    if restored is not None:
        state = restored[1]
        print(f"[reeber] attempt {comm.attempt}: resumed after "
              f"{int(state['n'])} snapshots")
    while True:
        f = h5.File("plt*.h5", "r")
        if f is None:
            break
        rho = f["/level_0/density"][...]
        i = int(state["n"])
        counts = state["counts"].copy()
        counts[i] = int(np.sum(rho > 1.01))
        state = {"counts": counts, "n": state["n"] + np.int64(1)}
        comm.checkpoint(state)
    print(f"[reeber] halo cells per snapshot: {state['counts'].tolist()}")
    return


def viz():
    """Optional rider -- no checkpoints, no restart policy; if it dies the
    workflow carries on without it."""
    while True:
        f = h5.File("plt*.h5", "r")
        if f is None:
            break
        print(f"[viz] rendered {f.filename}")


if __name__ == "__main__":
    funcs = {"nyx": nyx, "reeber": reeber, "viz": viz}

    print("=== crash-free reference run ===")
    Wilkins(WORKFLOW, funcs).run(timeout=300)

    print("\n=== faulted run: nyx dies at snapshot 3, reeber in the "
          "delivered-but-unseen window, viz unconditionally ===")
    report = Wilkins(WORKFLOW, funcs).run(timeout=300, faults=[
        # producer crash at a step boundary (before snapshot 3 serves)
        FaultSpec(task="nyx", point="close", step=3),
        # consumer crash AFTER a payload was delivered but before the task
        # saw it -- only the replay protocol recovers this one
        FaultSpec(task="reeber", point="recv", step=5),
        # the optional rider dies -> dropped, not fatal
        FaultSpec(task="viz", point="open", step=2),
    ])
    print("\n" + report.summary())
    restarted = sorted(r["task"] for r in report.restarts)
    assert restarted == ["nyx", "reeber"], restarted
    assert report.dropped_tasks == [("viz", 0)]
    print("\nrecovered: 2 restarts + 1 drop, halo counts identical to the "
          "crash-free run")
