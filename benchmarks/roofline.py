"""Roofline table: reads the dry-run JSONs and prints the per-(arch x shape x
mesh) three-term roofline with bottleneck + useful-flop ratio (§Roofline).

Run the dry-run grid first:  python -m repro.launch.dryrun --all --mesh both
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")

HEADER = (f"{'arch':<22} {'shape':<12} {'mesh':<9} {'tag':<8} "
          f"{'mem GiB':>8} {'t_comp ms':>10} {'t_mem ms':>9} {'t_coll ms':>10} "
          f"{'bound':<10} {'useful':>7} {'fracRL':>7}")


def load(tag: Optional[str] = None) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if tag is None and r.get("tag"):
            continue
        if tag is not None and r.get("tag") != tag:
            continue
        rows.append(r)
    return rows


def fmt(r: Dict) -> str:
    rf = r["roofline"]
    return (f"{r['arch']:<22} {r['shape']:<12} {r['mesh']:<9} "
            f"{(r.get('tag') or '-'):<8} "
            f"{r['memory']['peak_bytes'] / 2**30:>8.2f} "
            f"{rf['t_compute'] * 1e3:>10.2f} {rf['t_memory'] * 1e3:>9.2f} "
            f"{rf['t_collective'] * 1e3:>10.2f} {rf['bottleneck']:<10} "
            f"{rf['useful_flop_ratio']:>7.3f} {rf['roofline_fraction']:>7.3f}")


def main() -> None:
    rows = load()
    if not rows:
        print("no dry-run results found; run: python -m repro.launch.dryrun --all")
        return
    print(HEADER)
    for r in rows:
        print(fmt(r))
    bounds: Dict[str, int] = {}
    for r in rows:
        b = r["roofline"]["bottleneck"]
        bounds[b] = bounds.get(b, 0) + 1
    print(f"\ncells={len(rows)} bottlenecks={bounds}")
    worst = sorted(rows, key=lambda r: r["roofline"]["roofline_fraction"])[:5]
    print("worst roofline fractions:")
    for r in worst:
        print(f"  {r['arch']} {r['shape']} {r['mesh']}: "
              f"{r['roofline']['roofline_fraction']:.4f} "
              f"({r['roofline']['bottleneck']}-bound)")


if __name__ == "__main__":
    main()
