"""Generate the EXPERIMENTS.md §Dry-run/§Roofline markdown tables from
results/dryrun/*.json.  Run after the dry-run grid:

    PYTHONPATH=src python -m benchmarks.make_tables > results/roofline_tables.md
"""

from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_all():
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def baseline_table(rows):
    print("| arch | shape | mesh | mem GiB | t_compute ms | t_memory ms | "
          "t_collective ms | bound | useful | frac(RL) |")
    print("|---|---|---|---:|---:|---:|---:|---|---:|---:|")
    for r in rows:
        if r.get("tag"):
            continue
        rf = r["roofline"]
        # decode/prefill cells are judged against the bandwidth roofline when
        # memory-bound; frac reported as useful-time / bound-time
        frac = rf["roofline_fraction"]
        if rf["bottleneck"] == "memory":
            frac = rf["t_memory"] / max(rf["t_compute"], rf["t_memory"],
                                        rf["t_collective"])
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {r['memory']['peak_bytes'] / 2**30:.2f} "
              f"| {rf['t_compute'] * 1e3:.2f} | {rf['t_memory'] * 1e3:.2f} "
              f"| {rf['t_collective'] * 1e3:.2f} | {rf['bottleneck']} "
              f"| {rf['useful_flop_ratio']:.3f} | {frac:.3f} |")


def variants_table(rows):
    cells = defaultdict(dict)
    for r in rows:
        key = (r["arch"], r["shape"], r["mesh"])
        cells[key][r.get("tag") or "baseline"] = r
    print("\n| arch | shape | mesh | variant | t_coll ms | vs baseline "
          "| bound | mem GiB |")
    print("|---|---|---|---|---:|---:|---|---:|")
    for key in sorted(cells):
        tags = cells[key]
        if len(tags) < 2 or "baseline" not in tags:
            continue
        base = tags["baseline"]["roofline"]["t_collective"]
        for tag, r in sorted(tags.items()):
            rf = r["roofline"]
            ratio = base / rf["t_collective"] if rf["t_collective"] else float("inf")
            print(f"| {key[0]} | {key[1]} | {key[2]} | {tag} "
                  f"| {rf['t_collective'] * 1e3:.2f} | {ratio:.1f}x "
                  f"| {rf['bottleneck']} "
                  f"| {r['memory']['peak_bytes'] / 2**30:.2f} |")


def main():
    rows = load_all()
    print("## Baseline roofline grid\n")
    baseline_table(rows)
    print("\n## Variant (hillclimb) cells\n")
    variants_table(rows)


if __name__ == "__main__":
    main()
