"""Generate the EXPERIMENTS.md §Dry-run/§Roofline markdown tables from
results/dryrun/*.json, plus the runtime-scheduler counter table from
BENCH_scheduler.json when present.  Run after the dry-run grid:

    PYTHONPATH=src python -m benchmarks.make_tables > results/roofline_tables.md
"""

from __future__ import annotations

import glob
import json
import os
from collections import defaultdict

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")
REPO_ROOT = os.path.join(os.path.dirname(__file__), "..")


def load_all():
    rows = []
    for path in sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def baseline_table(rows):
    print("| arch | shape | mesh | mem GiB | t_compute ms | t_memory ms | "
          "t_collective ms | bound | useful | frac(RL) |")
    print("|---|---|---|---:|---:|---:|---:|---|---:|---:|")
    for r in rows:
        if r.get("tag"):
            continue
        rf = r["roofline"]
        # decode/prefill cells are judged against the bandwidth roofline when
        # memory-bound; frac reported as useful-time / bound-time
        frac = rf["roofline_fraction"]
        if rf["bottleneck"] == "memory":
            frac = rf["t_memory"] / max(rf["t_compute"], rf["t_memory"],
                                        rf["t_collective"])
        print(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
              f"| {r['memory']['peak_bytes'] / 2**30:.2f} "
              f"| {rf['t_compute'] * 1e3:.2f} | {rf['t_memory'] * 1e3:.2f} "
              f"| {rf['t_collective'] * 1e3:.2f} | {rf['bottleneck']} "
              f"| {rf['useful_flop_ratio']:.3f} | {frac:.3f} |")


def variants_table(rows):
    cells = defaultdict(dict)
    for r in rows:
        key = (r["arch"], r["shape"], r["mesh"])
        cells[key][r.get("tag") or "baseline"] = r
    print("\n| arch | shape | mesh | variant | t_coll ms | vs baseline "
          "| bound | mem GiB |")
    print("|---|---|---|---|---:|---:|---|---:|")
    for key in sorted(cells):
        tags = cells[key]
        if len(tags) < 2 or "baseline" not in tags:
            continue
        base = tags["baseline"]["roofline"]["t_collective"]
        for tag, r in sorted(tags.items()):
            rf = r["roofline"]
            ratio = base / rf["t_collective"] if rf["t_collective"] else float("inf")
            print(f"| {key[0]} | {key[1]} | {key[2]} | {tag} "
                  f"| {rf['t_collective'] * 1e3:.2f} | {ratio:.1f}x "
                  f"| {rf['bottleneck']} "
                  f"| {r['memory']['peak_bytes'] / 2**30:.2f} |")


def scheduler_table():
    """Render BENCH_scheduler.json (the disparate-rate scheduler bench):
    per-run consumer blocked seconds, hit/miss counters, retune decisions,
    and the final autotuned depths."""
    # same default as common.write_json (BENCH_DIR, else cwd), with the
    # repo root as a fallback for runs launched from elsewhere
    candidates = [os.path.join(os.environ.get("BENCH_DIR", "."),
                               "BENCH_scheduler.json"),
                  os.path.join(REPO_ROOT, "BENCH_scheduler.json")]
    path = next((p for p in candidates if os.path.exists(p)), None)
    if path is None:
        return
    with open(path) as f:
        doc = json.load(f)
    print("\n## Runtime scheduler (disparate-rate bench)\n")
    print("| run | policy | hot blocked s | hot hits | hot misses "
          "| retunes | telemetry samples | wall s |")
    print("|---|---|---:|---:|---:|---:|---:|---:|")
    for tag in ("static", "adaptive"):
        r = doc.get(tag)
        if not r:
            continue
        print(f"| {tag} | {r['scheduler'].get('policy', '?')} "
              f"| {r['hot_blocked_s']:.3f} | {r['hot_hits']} "
              f"| {r['hot_misses']} | {r['retunes']} "
              f"| {r['telemetry_samples']} | {r['wall_s']:.2f} |")
    depths = doc.get("adaptive", {}).get("final_depths", {})
    if depths:
        print("\n| edge | final depth |")
        print("|---|---:|")
        for edge, depth in sorted(depths.items()):
            print(f"| {edge} | {depth} |")
    decisions = doc.get("adaptive", {}).get("scheduler", {}).get("decisions", [])
    if decisions:
        print("\n| retune | edge | depth | reason |")
        print("|---|---|---|---|")
        for i, d in enumerate(decisions):
            print(f"| {i} | {d['edge']} | {d['old']} -> {d['new']} "
                  f"| {d['reason']} |")


def obs_table():
    """Render BENCH_obs.json (the span-tracing overhead bench): tracing
    cost on/off plus the critical-path attribution's per-edge rollup."""
    candidates = [os.path.join(os.environ.get("BENCH_DIR", "."),
                               "BENCH_obs.json"),
                  os.path.join(REPO_ROOT, "BENCH_obs.json")]
    path = next((p for p in candidates if os.path.exists(p)), None)
    if path is None:
        return
    with open(path) as f:
        doc = json.load(f)
    print("\n## Observability (span-tracing bench)\n")
    print("| baseline s | traced s | overhead | zero-cost off | spans "
          "| layers | attribution |")
    print("|---:|---:|---:|---|---:|---|---|")
    att = ("consistent" if doc.get("attribution_sums_ok")
           else "INCONSISTENT")
    print(f"| {doc['baseline_s']:.3f} | {doc['traced_s']:.3f} "
          f"| {doc['overhead_x']:.3f}x "
          f"| {'yes' if doc.get('zero_cost_ok') else 'NO'} "
          f"| {doc.get('trace_spans', 0)} "
          f"| {','.join(doc.get('layers', []))} | {att} |")
    edges = doc.get("edges", {})
    if edges:
        print(f"\ncritical instance: `{doc.get('critical')}`\n")
        print("| edge | blocked s | prep s | MiB | plan hits | misses |")
        print("|---|---:|---:|---:|---:|---:|")
        for edge, row in sorted(edges.items()):
            print(f"| {edge} | {row['blocked_s']:.4f} "
                  f"| {row['prep_s']:.4f} "
                  f"| {row['bytes'] / 2**20:.2f} | {row['hits']} "
                  f"| {row['misses']} |")


def main():
    rows = load_all()
    print("## Baseline roofline grid\n")
    baseline_table(rows)
    print("\n## Variant (hillclimb) cells\n")
    variants_table(rows)
    scheduler_table()
    obs_table()


if __name__ == "__main__":
    main()
