"""Shared helpers for the benchmark suite (paper §4 setup, scaled to 1 host).

The paper's synthetic data: per producer process 10^6 grid points (u64) and
10^6 particles (3 x f32) = 19 MiB.  We keep the exact data model and scale
counts so each benchmark finishes in seconds on one CPU; every benchmark
prints ``name,value,unit,derived`` CSV rows so `benchmarks.run` can be diffed
run-over-run.
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List

import numpy as np

ROWS: List[str] = []


def emit(name: str, value: float, unit: str, derived: str = "") -> None:
    row = f"{name},{value:.6g},{unit},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def write_json(name: str, payload: Dict[str, Any], directory: str = None) -> str:
    """Persist a benchmark's results as ``BENCH_<name>.json`` so the perf
    trajectory is machine-readable run-over-run (``BENCH_DIR`` overrides the
    output directory; defaults to the repo root / cwd)."""
    directory = directory or os.environ.get("BENCH_DIR", ".")
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}", flush=True)
    return path


def synthetic_datasets(n_grid: int = 100_000, n_particles: int = 100_000,
                       t: int = 0):
    """The paper's grid (u64 scalars) + particles (3-vec f32) datasets."""
    grid = np.arange(n_grid, dtype=np.uint64) + t
    parts = np.full((n_particles, 3), float(t), np.float32)
    return grid, parts


def total_bytes(n_grid: int, n_particles: int) -> int:
    return n_grid * 8 + n_particles * 12


class Timer:
    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self.dt = time.monotonic() - self.t0
        return False
