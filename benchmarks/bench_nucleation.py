"""Paper Fig. 10 (materials science): NxN ensemble of MD simulations coupled
to in-situ feature detectors, with the subset-writers (nwriters=1) idiom.

The "LAMMPS" stand-in is a small JAX Lennard-Jones-flavoured particle
relaxation; the detector counts particles whose local order parameter (here:
neighbour count within a cutoff) crosses a threshold -- a stateless consumer,
exactly the paper's diamond-structure detector shape.  The paper's claim:
completion time is ~flat in the number of NxN ensemble instances (1.2%
difference between 1 and 64); we check 1 -> 4 here.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import h5, Wilkins

from .common import emit

N_ATOMS = 256
TIMESTEPS = 3
MD_COMPUTE_S = 0.05   # emulated per-timestep MD cost: overlappable across
                      # instances (this container has 1 core; real deployments
                      # give each ensemble instance its own 32 procs)


@jax.jit
def _md_step(pos, key):
    """Toy MD relaxation step: random kicks + pairwise soft repulsion."""
    kick = jax.random.normal(key, pos.shape) * 0.01
    d = pos[:, None, :] - pos[None, :, :]
    r2 = jnp.sum(d * d, axis=-1) + jnp.eye(pos.shape[0])
    force = jnp.sum(d / (r2[..., None] ** 2 + 0.1), axis=1)
    return pos + 0.001 * force + kick


@jax.jit
def _detect(pos, cutoff=0.3):
    """Count 'nucleated' atoms: >= 4 neighbours within the cutoff."""
    d = pos[:, None, :] - pos[None, :, :]
    r = jnp.sqrt(jnp.sum(d * d, axis=-1))
    neigh = jnp.sum((r < cutoff) & (r > 0), axis=1)
    return jnp.sum(neigh >= 4)


def run(n_instances: int) -> float:
    yaml = f"""
tasks:
  - func: freeze
    taskCount: {n_instances}
    nprocs: 32
    nwriters: 1  # LAMMPS gathers to rank 0 (paper Listing 4)
    outports:
      - filename: dump-h5md.h5
        dsets: [{{name: /particles/*, memory: 1}}]
  - func: detector
    taskCount: {n_instances}
    nprocs: 8
    inports:
      - filename: dump-h5md.h5
        dsets: [{{name: /particles/*, memory: 1}}]
"""
    def freeze(comm):
        key = jax.random.PRNGKey(comm.instance)
        pos = jax.random.uniform(key, (N_ATOMS, 3))
        for t in range(TIMESTEPS):
            key = jax.random.fold_in(key, t)
            pos = _md_step(pos, key)
            time.sleep(MD_COMPUTE_S)
            if comm.is_io_proc():      # only rank 0 writes (subset writers)
                with h5.File("dump-h5md.h5", "w") as f:
                    f.create_dataset("/particles/pos", data=np.asarray(pos))

    counts = []

    def detector():
        f = h5.File("dump-h5md.h5", "r")
        if f is None:
            return
        pos = jnp.asarray(f["/particles/pos"][:])
        counts.append(int(_detect(pos)))

    w = Wilkins(yaml, {"freeze": freeze, "detector": detector})
    t0 = time.monotonic()
    w.run(timeout=180)
    assert len(counts) == n_instances * TIMESTEPS
    return time.monotonic() - t0


def main() -> None:
    # warm the jits so instance-count scaling isn't skewed by compilation
    import jax.random as jr
    pos0 = jr.uniform(jr.PRNGKey(0), (N_ATOMS, 3))
    _md_step(pos0, jr.PRNGKey(1))
    _detect(pos0)

    run(1)  # full warmup pass (fold_in/uniform dispatch paths)

    t1 = run(1)
    emit("nucleation/nxn/1", t1, "s")
    t4 = run(4)
    emit("nucleation/nxn/4", t4, "s",
         f"vs 1 instance: {abs(t4 - t1) / t1 * 100:.1f}% (paper: 1.2% at 64x)")


if __name__ == "__main__":
    main()
