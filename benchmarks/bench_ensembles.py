"""Paper Figs. 7/8/9: ensemble coupling time for fan-out, fan-in, NxN.

Time to write/read the grid+particles between producer and consumer instances
while varying the instance count (paper: up to 256 instances at 2 procs each;
scaled here to 1-16 thread-instances and 10^4-point datasets).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import h5, Wilkins

from .common import emit, synthetic_datasets

N_GRID = 200_000


def run(n_prod: int, n_cons: int) -> float:
    yaml = f"""
tasks:
  - func: producer
    taskCount: {n_prod}
    outports:
      - filename: o.h5
        dsets:
          - {{name: /g, memory: 1}}
          - {{name: /p, memory: 1}}
  - func: consumer
    taskCount: {n_cons}
    inports:
      - filename: o.h5
        dsets:
          - {{name: /g, memory: 1}}
          - {{name: /p, memory: 1}}
"""
    def producer():
        with h5.File("o.h5", "w") as f:
            g, p = synthetic_datasets(N_GRID, N_GRID, 0)
            f.create_dataset("/g", data=g)
            f.create_dataset("/p", data=p)

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                return
            _ = f["/g"][:]

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer})
    t0 = time.monotonic()
    w.run(timeout=120)
    return time.monotonic() - t0


def main() -> None:
    for n in (1, 4, 16):
        emit(f"ensembles/fanout/1x{n}", run(1, n), "s",
             "paper Fig7: ~linear in consumers")
    for n in (1, 4, 16):
        emit(f"ensembles/fanin/{n}x1", run(n, 1), "s",
             "paper Fig8: ~linear in producers")
    for n in (1, 4, 16):
        emit(f"ensembles/nxn/{n}x{n}", run(n, n), "s",
             "paper Fig9: ~flat (1:1 pairing)")


if __name__ == "__main__":
    main()
