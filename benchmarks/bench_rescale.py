"""Elastic-rescale benchmark: what does resizing a live task cost?

A producer feeds an elastic two-instance consumer over a redistributing
memory edge (32 MiB/step, 4 MiB at smoke sizes); each instance accumulates
its slab and checkpoints it as a shard (``sharded_axes``).  Three runs:

* **crash-free reference** at the original size -- the byte-exactness and
  overhead baseline;
* **same-size restart** -- the consumer crashes mid-stream under a plain
  ``on_failure: restart`` policy: the recovery cost WITHOUT channel
  surgery, the fair comparator for the rescale path;
* **rescale** -- the same crash under ``rescale: {nslots: 1}``: supervised
  M->N surgery (checkpoint re-cut, channel rebuild, replay) shrinking the
  consumer 2->1.

Measured:

* **rescale latency** -- the surgery window itself, from the RescaleEvent
  (``request_rescale`` to ``finish_rescale``: quiesce, re-cut, rebuild,
  preload, relaunch);
* **byte-exactness** -- the resized run's concatenated accumulator equals
  the crash-free run's bit-for-bit (the tentpole's acceptance property);
* **overhead vs the same-size restart** -- rescale wall time against
  restart wall time: the surgery may cost the backoff + replay a restart
  also pays, plus a bounded re-cut, not a rerun of the workflow.

Writes ``BENCH_rescale.json`` and prints the usual CSV rows.
"""

from __future__ import annotations

import shutil
import tempfile
from typing import Any, Dict

import numpy as np

from repro.core import FaultSpec, Wilkins, h5, world
from repro.core.redistribute import even_blocks

from .common import Timer, emit, write_json

MIB = 1 << 20


def _yaml(policy: str) -> str:
    return f"""
tasks:
  - func: producer
    on_failure:
      restart: {{max_retries: 2}}
    outports:
      - filename: state.h5
        dsets:
          - {{name: /grid, memory: 1}}
  - func: consumer
    taskCount: 2
    on_failure:
      {policy}
    inports:
      - filename: state.h5
        redistribute: 1
        dsets:
          - {{name: /grid, memory: 1}}
"""


def _make_funcs(n_elems: int, steps: int, out: Dict[int, Any]):
    """Slab-accumulating pair (uint64 math: exact at any partition)."""

    def producer(comm):
        start = 0
        r = comm.restore({"step": np.zeros((), np.int64)})
        if r is not None:
            start = int(r[1]["step"])
        for t in range(start, steps):
            with h5.File("state.h5", "w") as f:
                f.create_dataset(
                    "/grid", data=np.arange(n_elems, dtype=np.uint64) + t)
            comm.checkpoint({"step": np.array(t + 1, np.int64)})

    def consumer():
        comm = world()
        spec = comm.resolve_redist_spec(port="state.h5")
        _, (rows,) = even_blocks((n_elems,), spec.nslots)[spec.slot]
        like = {"acc": np.zeros(rows, np.uint64),
                "n": np.zeros((), np.int64)}
        state = like
        r = comm.restore(like)
        if r is not None:
            state = r[1]
        acc = np.asarray(state["acc"]).copy()
        n = int(state["n"])
        while True:
            f = h5.File("state.h5", "r")
            if f is None:
                break
            acc = acc + f["/grid"][...]
            n += 1
            comm.checkpoint({"acc": acc, "n": np.array(n, np.int64)},
                            sharded_axes={"acc": 0})
        out[comm.instance] = (acc.copy(), n)

    return {"producer": producer, "consumer": consumer}


def _run(policy: str, n_elems: int, steps: int, faults=None):
    out: Dict[int, Any] = {}
    spill = tempfile.mkdtemp(prefix="wilkins_bench_rescale_")
    try:
        w = Wilkins(_yaml(policy), _make_funcs(n_elems, steps, out),
                    spill_dir=spill, record_events=True)
        with Timer() as t:
            rep = w.run(timeout=600, faults=faults)
    finally:
        shutil.rmtree(spill, ignore_errors=True)
    final = w.graph.tasks["consumer"].task_count
    acc = np.concatenate([out[j][0] for j in range(final)])
    assert all(out[j][1] == steps for j in range(final))
    return acc, rep, t.dt, final


def main(smoke: bool = False) -> Dict[str, Any]:
    bytes_per_step = (4 if smoke else 32) * MIB
    n_elems = bytes_per_step // 8  # uint64 grid
    steps = 4 if smoke else 8
    crash_step = steps // 2
    crash = FaultSpec(task="consumer", point="recv", step=crash_step,
                      instance=0)

    ref_acc, ref_rep, ref_s, _ = _run(
        "rescale: {nslots: 1, max_retries: 2}", n_elems, steps)
    res_acc, res_rep, res_s, res_n = _run(
        "rescale: {nslots: 1, max_retries: 2}", n_elems, steps, faults=crash)
    rst_acc, rst_rep, rst_s, _ = _run(
        "restart: {max_retries: 2}", n_elems, steps, faults=crash)

    byte_exact = (res_acc.tobytes() == ref_acc.tobytes()
                  and rst_acc.tobytes() == ref_acc.tobytes())
    assert len(res_rep.rescales) == 1 and res_n == 1
    ev = res_rep.rescales[0]
    rescale_latency_s = ev["latency_s"]
    steps_replayed = sum(c.stats.replayed for c in res_rep.channels)
    overhead_vs_restart_x = res_s / max(rst_s, 1e-9)
    # absolute slack on top of the ratio: at smoke sizes the whole run is
    # ~100 ms, so a pure ratio gate would measure scheduler noise
    overhead_ok = res_s <= 3.0 * rst_s + 1.0
    latency_ok = rescale_latency_s <= (2.0 if smoke else 10.0)

    emit("rescale_bytes_per_step", bytes_per_step, "B")
    emit("rescale_crash_free_s", ref_s, "s", f"steps={steps} nslots=2")
    emit("rescale_restart_s", rst_s, "s",
         f"same-size restart crash@recv step={crash_step}")
    emit("rescale_rescaled_s", res_s, "s",
         f"2->1 surgery crash@recv step={crash_step}")
    emit("rescale_latency_s", rescale_latency_s, "s",
         "request_rescale -> finish_rescale")
    emit("rescale_overhead_vs_restart", overhead_vs_restart_x, "x",
         "rescaled/restarted")
    emit("rescale_steps_replayed", steps_replayed, "steps")
    emit("rescale_byte_exact", int(byte_exact), "bool")

    results = {
        "bytes_per_step": bytes_per_step,
        "steps": steps,
        "crash_step": crash_step,
        "old_nslots": ev["old_nslots"],
        "new_nslots": ev["new_nslots"],
        "crash_free_s": ref_s,
        "restart_s": rst_s,
        "rescaled_s": res_s,
        "rescale_latency_s": rescale_latency_s,
        "latency_ok": latency_ok,
        "overhead_vs_restart_x": overhead_vs_restart_x,
        "overhead_ok": overhead_ok,
        "steps_replayed": int(steps_replayed),
        "rescales": len(res_rep.rescales),
        "rescales_crash_free": len(ref_rep.rescales),
        "byte_exact": bool(byte_exact),
    }
    write_json("rescale", results)
    return results


if __name__ == "__main__":
    main()
