"""M->N redistribution benchmark (the paper §3.2.2 data-movement lever).

Measures the planned transport path against the whole-file baseline on the
three axes the acceptance criteria name:

* ``mxn``     -- a 4->2 producer/consumer edge: bytes shipped with declared
  consumer ownership (``redistribute: 1``) vs the whole-file payloads the
  pre-plan transport moved, plus the plan-cache hit rate over the run
  (steady-state steps re-plan nothing).
* ``aligned`` -- src and dst decompositions line up: the aligned-boundary
  detector degenerates to CoW views, zero bytes copied and zero shipped.
* ``pack``    -- the JAX executor: a cached plan lowered to
  ``kernels.pack.pack_blocks`` scalar-prefetch DMA tiles (interpret mode on
  CPU) vs the numpy scatter executor, checked to the byte.
* ``pack_nd`` -- a rank-3 reshard on the kernel path: the non-decomposed
  axes flatten onto the 2-D kernels (no numpy fallback), byte-checked;
  ``--smoke`` gates that ``pack_mode`` stays non-None and bytes match.

Every row goes through ``common.emit`` and the whole result dict is persisted
as ``BENCH_redistribute.json`` via ``common.write_json``.

    PYTHONPATH=src python -m benchmarks.bench_redistribute [--smoke]
"""

from __future__ import annotations

import argparse
import time
from typing import Any, Dict

import numpy as np

from repro.core import Wilkins, h5, plan_cache, reset_plan_cache
from repro.core.datamodel import (BlockOwnership, reset_transport_stats,
                                  transport_stats)
from repro.core.redistribute import (CompiledPlan, even_blocks,
                                     execute_pack_jax_all)

from .common import Timer, emit, write_json

MIB = 1 << 20


def _mxn_yaml(n_prod: int, n_cons: int, cons_ranks: int,
              redistribute: bool, extra: str = "") -> str:
    redist = "redistribute: 1" if redistribute else "redistribute: 0"
    return f"""
tasks:
  - func: producer
    taskCount: {n_prod}
    nprocs: {n_prod}
    outports:
      - filename: o.h5
        dsets: [{{name: /grid, memory: 1}}]
  - func: consumer
    taskCount: {n_cons}
    nprocs: {cons_ranks}
    inports:
      - filename: o.h5
        {redist}
        {extra}
        dsets: [{{name: /grid, memory: 1}}]
"""


def _run_mxn(redistribute: bool, mib_per_step: float, steps: int,
             n_prod: int = 4, n_cons: int = 2) -> Dict[str, Any]:
    n = int(mib_per_step * MIB // 8)
    payload = np.arange(n, dtype=np.float64)
    own = BlockOwnership()
    for r, (s, sh) in enumerate(even_blocks((n,), n_prod)):
        own.add(r, s, sh)

    def producer():
        for t in range(steps):
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/grid", data=payload, ownership=own)

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            _ = float(f["/grid"][0])  # touch the owned slab

    w = Wilkins(_mxn_yaml(n_prod, n_cons, 2, redistribute),
                {"producer": producer, "consumer": consumer})
    reset_plan_cache()
    reset_transport_stats()
    with Timer() as t:
        rep = w.run(timeout=600)
    s = transport_stats().snapshot()
    pc = plan_cache().snapshot()
    return {
        "redistribute": redistribute,
        "n_prod": n_prod,
        "n_cons": n_cons,
        "steps": steps,
        "mib_per_step": mib_per_step,
        "served": rep.total_served,
        "bytes_shipped": rep.total_bytes_moved,
        "redist_planned_bytes": s["redist_planned_bytes"],
        "redist_shipped_bytes": s["redist_shipped_bytes"],
        "redist_baseline_bytes": s["redist_baseline_bytes"],
        "plan_cache": pc,
        "wall_s": t.dt,
    }


def bench_mxn(mib_per_step: float, steps: int) -> Dict[str, Any]:
    baseline = _run_mxn(False, mib_per_step, steps)
    planned = _run_mxn(True, mib_per_step, steps)
    reduction = baseline["bytes_shipped"] / max(1, planned["bytes_shipped"])
    hit_rate = planned["plan_cache"]["hit_rate"]
    for tag, r in (("whole_file", baseline), ("planned", planned)):
        emit(f"redistribute_mxn_{tag}_bytes_shipped", r["bytes_shipped"], "B",
             f"4->2 edge x {steps}steps x {mib_per_step}MiB")
        emit(f"redistribute_mxn_{tag}_wall", r["wall_s"], "s")
    emit("redistribute_mxn_bytes_reduction", reduction, "x",
         "whole-file bytes shipped / planned bytes shipped (>=2x acceptance)")
    emit("redistribute_plan_cache_hit_rate", hit_rate, "frac",
         ">=0.9 after step 1 acceptance")
    return {"whole_file": baseline, "planned": planned,
            "bytes_reduction_x": reduction,
            "plan_cache_hit_rate": hit_rate}


def bench_aligned(mib_per_step: float, steps: int, nranks: int = 2) -> Dict[str, Any]:
    """src decomposition == dst decomposition: views only, zero bytes copied."""
    n = int(mib_per_step * MIB // 8)
    payload = np.arange(n, dtype=np.float64)
    own = BlockOwnership()
    for r, (s, sh) in enumerate(even_blocks((n,), nranks)):
        own.add(r, s, sh)

    def producer():
        for t in range(steps):
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/grid", data=payload, ownership=own)

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            _ = float(f["/grid"][0])

    w = Wilkins(_mxn_yaml(1, 1, nranks, True),
                {"producer": producer, "consumer": consumer})
    reset_plan_cache()
    reset_transport_stats()
    baseline_copies = steps * payload.nbytes  # create_dataset snapshots
    with Timer() as t:
        w.run(timeout=600)
    s = transport_stats().snapshot()
    served = s["redist_aligned"] + s["redist_slabs"]
    ratio = s["redist_aligned"] / max(1, served)
    extra_copied = s["bytes_copied"] - baseline_copies
    emit("redistribute_aligned_ratio", ratio, "frac",
         f"{s['redist_aligned']}/{served} served datasets took the view path")
    emit("redistribute_aligned_bytes_copied", extra_copied, "B",
         "transport-side copies beyond dataset creation (0 acceptance)")
    return {"steps": steps, "mib_per_step": mib_per_step,
            "aligned_served": s["redist_aligned"], "slab_served": s["redist_slabs"],
            "aligned_ratio": ratio, "shipped_bytes": s["redist_shipped_bytes"],
            "transport_bytes_copied": extra_copied, "wall_s": t.dt}


def bench_pack(rows: int, cols: int, n_src: int = 4, n_dst: int = 3,
               iters: int = 5) -> Dict[str, Any]:
    """JAX pack executor vs numpy scatter on one cached plan, byte-checked."""
    import jax.numpy as jnp

    src_boxes = even_blocks((rows, cols), n_src)
    dst_boxes = even_blocks((rows, cols), n_dst)
    plan = CompiledPlan(src_boxes, dst_boxes, (rows, cols), np.float32)
    g = np.arange(rows * cols, dtype=np.float32).reshape(rows, cols)
    gj = jnp.asarray(g)

    with Timer() as t_np:
        for _ in range(iters):
            outs = plan.execute_global(g)
    packed = [np.asarray(a) for a in execute_pack_jax_all(plan, gj)]
    with Timer() as t_jax:
        for _ in range(iters):
            packed = [np.asarray(a) for a in execute_pack_jax_all(plan, gj)]
    for a, b in zip(outs, packed):
        np.testing.assert_array_equal(a, b)
    emit("redistribute_pack_numpy", t_np.dt / iters, "s",
         f"{rows}x{cols} {n_src}->{n_dst} scatter")
    emit("redistribute_pack_pallas", t_jax.dt / iters, "s",
         "pack_blocks scalar-prefetch DMA (interpret on CPU)")
    return {"rows": rows, "cols": cols, "n_src": n_src, "n_dst": n_dst,
            "numpy_s": t_np.dt / iters, "pallas_s": t_jax.dt / iters,
            "byte_exact": True}


def bench_pack_nd(n0: int, n1: int, n2: int, n_src: int = 4, n_dst: int = 2,
                  axis: int = 1, iters: int = 3) -> Dict[str, Any]:
    """Rank-3 reshard on the kernel path: the plan's non-decomposed axes are
    flattened onto the 2-D pack kernels (no numpy fallback), byte-checked
    against the numpy scatter executor.  This is the volumetric-field case
    (WarpX-class workloads) the 2-D-only lowering used to punt on.
    """
    import jax.numpy as jnp

    shape = (n0, n1, n2)
    src_boxes = even_blocks(shape, n_src, axis=axis)
    dst_boxes = even_blocks(shape, n_dst, axis=axis)
    plan = CompiledPlan(src_boxes, dst_boxes, shape, np.float32)
    g = np.arange(int(np.prod(shape)), dtype=np.float32).reshape(shape)
    gj = jnp.asarray(g)

    with Timer() as t_np:
        for _ in range(iters):
            outs = plan.execute_global(g)
    packed = [np.asarray(a) for a in execute_pack_jax_all(plan, gj)]
    with Timer() as t_jax:
        for _ in range(iters):
            packed = [np.asarray(a) for a in execute_pack_jax_all(plan, gj)]
    # a mismatch must flow into the --smoke gate, not crash the benchmark
    byte_exact = all(np.array_equal(a, b) for a, b in zip(outs, packed))
    emit("redistribute_pack3d_numpy", t_np.dt / iters, "s",
         f"{shape} axis-{axis} {n_src}->{n_dst} scatter")
    emit("redistribute_pack3d_pallas", t_jax.dt / iters, "s",
         f"flattened {plan.pack_mode} lowering (interpret on CPU)")
    return {"shape": list(shape), "axis": axis, "n_src": n_src,
            "n_dst": n_dst, "pack_mode": plan.pack_mode,
            "numpy_s": t_np.dt / iters, "pallas_s": t_jax.dt / iters,
            "byte_exact": byte_exact}


def _run_prefetch(prefetch_on: bool, mib_per_step: float, steps: int,
                  n_prod: int = 4, n_cons: int = 2,
                  compute_iters: int = 3) -> Dict[str, Any]:
    """One 4->2 run with reshard-consuming compute; prefetch on or off.

    Runs ``zero_copy=False`` so payload preparation does real slab copies
    (the serve-side work the executor is supposed to hide); the consumer
    reshards its received slab onto its logical ranks with
    ``TaskComm.reshard`` and computes on each block.
    """
    from repro.core import comm as comm_mod

    n = int(mib_per_step * MIB // 8)
    payload = np.arange(n, dtype=np.float64)
    own = BlockOwnership()
    for r, (s, sh) in enumerate(even_blocks((n,), n_prod)):
        own.add(r, s, sh)

    def producer():
        for t in range(steps):
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/grid", data=payload, ownership=own)

    def consumer(comm):
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            blocks = comm.reshard(f["/grid"])   # slab -> per-rank blocks
            for _ in range(compute_iters):      # consumer compute to overlap
                for b in blocks:
                    _ = np.tanh(b).sum()

    knob = "prefetch: 1" if prefetch_on else "prefetch: 0"
    w = Wilkins(_mxn_yaml(n_prod, n_cons, 2, True, extra=knob),
                {"producer": producer, "consumer": consumer},
                zero_copy=False)
    reset_plan_cache()
    reset_transport_stats()
    with Timer() as t:
        rep = w.run(timeout=600)
    s = transport_stats().snapshot()
    return {
        "prefetch": prefetch_on,
        "steps": steps,
        "mib_per_step": mib_per_step,
        "served": rep.total_served,
        "wall_s": t.dt,
        "prefetch_hits": s["prefetch_hits"],
        "prefetch_misses": s["prefetch_misses"],
        "prepared_s": s["prefetch_prepared_s"],
        "blocked_s": s["prefetch_blocked_s"],
    }


def bench_prefetch(mib_per_step: float, steps: int) -> Dict[str, Any]:
    """Async slab prefetch on the 4->2 edge: how much of the slab-serve time
    hides behind consumer compute (>= 0.30 acceptance)."""
    off = _run_prefetch(False, mib_per_step, steps)
    on = _run_prefetch(True, mib_per_step, steps)
    served = max(1, on["prefetch_hits"] + on["prefetch_misses"])
    hit_rate = on["prefetch_hits"] / served
    overlap = 0.0
    if on["prepared_s"] > 0:
        overlap = 1.0 - on["blocked_s"] / on["prepared_s"]
    emit("redistribute_prefetch_off_wall", off["wall_s"], "s",
         f"4->2 edge x {steps}steps x {mib_per_step}MiB, sync serve")
    emit("redistribute_prefetch_on_wall", on["wall_s"], "s",
         "payload futures on the prefetch executor")
    emit("redistribute_prefetch_hit_rate", hit_rate, "frac",
         "payload ready before the consumer asked")
    emit("redistribute_prefetch_overlap", overlap, "frac",
         "serve time hidden behind consumer compute (>=0.3 acceptance)")
    return {"off": off, "on": on, "hit_rate": hit_rate,
            "overlap_frac": overlap}


def main(smoke: bool = False) -> Dict[str, Any]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI smoke runs")
    ap.add_argument("--mib", type=float, default=None,
                    help="payload MiB per step for the M->N benchmark")
    args, _ = ap.parse_known_args([]) if smoke else ap.parse_known_args()
    smoke = smoke or args.smoke

    if smoke:
        mib, steps, rows, vol = 2.0, 12, 256, (32, 96, 8)
    else:
        mib, steps, rows, vol = (args.mib or 64.0), 20, 4096, (64, 512, 32)

    results = {
        "config": {"smoke": smoke, "mib_per_step": mib, "steps": steps},
        "mxn": bench_mxn(mib, steps),
        "aligned": bench_aligned(mib, steps),
        "pack": bench_pack(rows, 128),
        "pack_nd": bench_pack_nd(*vol),
        "prefetch": bench_prefetch(mib, steps),
    }
    write_json("redistribute", results)
    return results


if __name__ == "__main__":
    main()
