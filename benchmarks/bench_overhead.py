"""Paper Fig. 4: overhead of Wilkins vs the transport layer alone.

The paper couples producer/consumer with hand-written LowFive code (no
workflow system) and compares against Wilkins on top.  Here the "LowFive
alone" baseline drives a raw ``Channel`` + VOL pair by hand; the Wilkins run
uses the YAML + driver.  Weak scaling in *logical ranks*: data grows
proportionally (10^5 grid + particles per logical rank, paper uses 10^6 per
MPI process).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import h5, Wilkins
from repro.core.channel import Channel
from repro.core.datamodel import File
from repro.core.vol import VOL, pop_vol, push_vol

from .common import Timer, emit, synthetic_datasets, total_bytes

STEPS = 3


def lowfive_alone(n_ranks: int) -> float:
    """Hand-driven transport: producer VOL -> channel -> consumer reads."""
    ch = Channel("raw", ("p", 0), ("c", 0), "outfile.h5",
                 ["/group1/grid", "/group1/particles"])
    vol = VOL("p", nprocs=n_ranks)
    vol.outgoing.append(ch)
    import threading

    def consume():
        while True:
            f = ch.get()
            if f is None:
                return

    th = threading.Thread(target=consume, daemon=True)
    th.start()
    t0 = time.monotonic()
    for t in range(STEPS):
        grid, parts = synthetic_datasets(100_000 * n_ranks,
                                         100_000 * n_ranks, t)
        f = File("outfile.h5")
        f.create_dataset("/group1/grid", data=grid)
        f.create_dataset("/group1/particles", data=parts)
        vol.on_file_close(f)
    vol.finalize()
    th.join(timeout=30)
    return time.monotonic() - t0


def wilkins(n_ranks: int) -> float:
    yaml = f"""
tasks:
  - func: producer
    nprocs: {max(1, 3 * n_ranks // 4)}
    outports:
      - filename: outfile.h5
        dsets:
          - {{name: /group1/grid, memory: 1}}
          - {{name: /group1/particles, memory: 1}}
  - func: consumer
    nprocs: {max(1, n_ranks // 4)}
    inports:
      - filename: outfile.h5
        dsets:
          - {{name: /group1/grid, memory: 1}}
          - {{name: /group1/particles, memory: 1}}
"""
    def producer():
        for t in range(STEPS):
            with h5.File("outfile.h5", "w") as f:
                grid, parts = synthetic_datasets(100_000 * n_ranks,
                                                 100_000 * n_ranks, t)
                f.create_dataset("/group1/grid", data=grid)
                f.create_dataset("/group1/particles", data=parts)

    def consumer():
        while True:
            f = h5.File("outfile.h5", "r")
            if f is None:
                return

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer})
    with Timer() as t:
        w.run(timeout=120)
    return t.dt


def main() -> None:
    for n_ranks in (4, 16, 64):
        base = lowfive_alone(n_ranks)
        full = wilkins(n_ranks)
        mib = total_bytes(100_000 * n_ranks, 100_000 * n_ranks) * STEPS / 2**20
        emit(f"overhead/lowfive_alone/r{n_ranks}", base, "s", f"{mib:.1f}MiB")
        emit(f"overhead/wilkins/r{n_ranks}", full, "s", f"{mib:.1f}MiB")
        emit(f"overhead/ratio/r{n_ranks}", full / max(base, 1e-9), "x",
             "paper: ~1.02x at 1K procs")


if __name__ == "__main__":
    main()
