"""Observability overhead benchmark: what does tracing cost when ON, and
does it cost anything when OFF?

The workload is the 4->2 redistributing pipeline (4 producer instances
feeding 2 consumer instances through an M->N planned edge) with a small
per-step compute delay, so the measured quantity is the workflow's real
critical path, not pure hook overhead amplified by an empty loop.  Three
configurations, min-of-``repeats`` wall each:

* **baseline** -- tracing unset (the zero-cost default);
* **traced**   -- ``trace=True``: every layer records spans, the run ends
  with a critical-path attribution;
* **off-check** -- baseline again, asserting the process-wide
  ``SpanRecorder`` construction counter never moved (zero-cost is a
  structural property, not a timing one).

Gates (wired into ``run.py --smoke``):

* ``overhead_x <= 1.05`` -- tracing-on costs at most 5% wall;
* the traced run's attribution is non-empty and every instance's buckets
  sum to its window within 5%;
* spans cover >= 4 layers on this fault-free workload (vol, channel,
  prefetch, reshard).

Writes ``BENCH_obs.json`` and prints the usual CSV rows.
"""

from __future__ import annotations

import shutil
import tempfile
import time
from typing import Any, Dict, Optional

import numpy as np

from repro.core import Wilkins, h5
from repro.obs import span_categories
from repro.obs.recorder import created_count

from .common import Timer, emit, write_json

OBS_YAML = """
tasks:
  - func: producer
    taskCount: 4
    outports:
      - filename: field.h5
        dsets:
          - {name: /grid, memory: 1}
  - func: consumer
    taskCount: 2
    nprocs: 2
    inports:
      - filename: field.h5
        redistribute: 1
        prefetch: 2
        dsets:
          - {name: /grid, memory: 1}
"""


def _make_funcs(n_elems: int, steps: int, delay_s: float,
                out: Dict[str, Any]):
    def producer(comm):
        for t in range(steps):
            time.sleep(delay_s)
            with h5.File("field.h5", "w") as f:
                f.create_dataset(
                    "/grid", data=np.arange(n_elems, dtype=np.float64) + t)

    def consumer(comm):
        acc = 0.0
        n = 0
        while True:
            f = h5.File("field.h5", "r")
            if f is None:
                break
            blocks = comm.reshard(f["/grid"])
            time.sleep(delay_s)
            acc += float(sum(np.asarray(b).sum() for b in blocks))
            n += 1
        out[("consumer", comm.instance)] = (acc, n)

    return {"producer": producer, "consumer": consumer}


def _run(n_elems: int, steps: int, delay_s: float,
         trace: Optional[Any] = None):
    out: Dict[str, Any] = {}
    spill = tempfile.mkdtemp(prefix="wilkins_bench_obs_")
    try:
        w = Wilkins(OBS_YAML, _make_funcs(n_elems, steps, delay_s, out),
                    spill_dir=spill)
        with Timer() as t:
            rep = w.run(timeout=600, trace=trace)
    finally:
        shutil.rmtree(spill, ignore_errors=True)
    return out, rep, t.dt


def main(smoke: bool = False) -> Dict[str, Any]:
    n_elems = 1 << (14 if smoke else 18)
    steps = 4 if smoke else 8
    delay_s = 0.01
    repeats = 2

    n0 = created_count()
    base_s = min(_run(n_elems, steps, delay_s)[2] for _ in range(repeats))
    zero_cost_ok = created_count() == n0

    traced_s = float("inf")
    rep = None
    for _ in range(repeats):
        _, r, dt = _run(n_elems, steps, delay_s, trace=True)
        if dt < traced_s:
            traced_s, rep = dt, r

    overhead_x = traced_s / max(base_s, 1e-9)
    att = rep.critical_path
    att_nonempty = bool(att.get("instances")) and bool(att.get("edges"))
    att_sums_ok = att_nonempty
    for key, row in att.get("instances", {}).items():
        total = sum(row[b] for b in ("block", "prep", "reshard",
                                     "checkpoint", "recovery", "rescale",
                                     "compute"))
        if abs(total - row["window_s"]) > 0.05 * max(row["window_s"], 1e-9):
            att_sums_ok = False
    # layer coverage: a dedicated short traced run with an exported trace
    # (the timed runs above keep no span list on the report)
    spill = tempfile.mkdtemp(prefix="wilkins_bench_obs_layers_")
    try:
        out: Dict[str, Any] = {}
        w = Wilkins(OBS_YAML, _make_funcs(n_elems, 2, 0.0, out),
                    spill_dir=spill)
        import os
        path = os.path.join(spill, "trace.json")
        w.run(timeout=600, trace=path)
        from repro.obs import load_trace
        layers = span_categories(load_trace(path))
    finally:
        shutil.rmtree(spill, ignore_errors=True)
    layers_ok = len(layers) >= 4

    # tracing-on must not distort the measured workload either: the traced
    # run still sums its buckets to wall-clock reality
    ok = (overhead_x <= 1.05 and zero_cost_ok and att_nonempty
          and att_sums_ok and layers_ok)

    emit("obs_baseline_s", base_s, "s", f"steps={steps} untraced")
    emit("obs_traced_s", traced_s, "s", "trace=True")
    emit("obs_overhead", overhead_x, "x", "traced/baseline (gate <= 1.05)")
    emit("obs_zero_cost", int(zero_cost_ok), "bool",
         "no SpanRecorder constructed untraced")
    emit("obs_trace_spans", rep.trace_spans, "spans")
    emit("obs_layers", len(layers), "layers", ",".join(layers))
    emit("obs_attribution_ok", int(att_nonempty and att_sums_ok), "bool",
         "buckets sum to window within 5%")

    payload = {
        "baseline_s": base_s,
        "traced_s": traced_s,
        "overhead_x": overhead_x,
        "overhead_ok": overhead_x <= 1.05,
        "zero_cost_ok": zero_cost_ok,
        "trace_spans": rep.trace_spans,
        "layers": layers,
        "layers_ok": layers_ok,
        "attribution_nonempty": att_nonempty,
        "attribution_sums_ok": att_sums_ok,
        "critical": att.get("critical"),
        "edges": {k: {kk: vv for kk, vv in v.items()}
                  for k, v in att.get("edges", {}).items()},
        "ok": ok,
    }
    write_json("obs", payload)
    return payload


if __name__ == "__main__":
    main()
