"""Paper Table 3 (cosmology): Nyx + Reeber with flow control + custom actions.

The "Nyx" stand-in evolves a density field with a JAX diffusion+forcing step
and performs the paper's double open/close I/O idiom (first close = one-rank
metadata write, second = bulk parallel write); "Reeber" finds density peaks
above a cutoff (halo finding) and is deliberately slowed.  ``io_freq``
in {1, 2, 5, 10} reproduces the Table 3 sweep; the custom action script is
the paper's Listing 5 shape, loaded from an external file.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import h5, Wilkins

from .common import emit

GRID = 32
SNAPSHOTS = 20          # paper: Nyx produces 20 snapshots
NYX_COMPUTE_S = 0.01    # emulated PDE-solve time per snapshot
REEBER_SLOW_S = 0.20    # emulated (deliberately slowed) analysis time
# The paper slows Reeber 100x on purpose to make flow control visible; the
# jitted halo finder here takes ~50us once compiled, so the slowdown is an
# explicit sleep on top of the real computation.


@jax.jit
def _nyx_step(rho, key):
    """Toy density evolution: diffusion + multiplicative forcing."""
    lap = (jnp.roll(rho, 1, 0) + jnp.roll(rho, -1, 0) +
           jnp.roll(rho, 1, 1) + jnp.roll(rho, -1, 1) +
           jnp.roll(rho, 1, 2) + jnp.roll(rho, -1, 2) - 6 * rho)
    force = jax.random.normal(key, rho.shape) * 0.02
    return jnp.clip(rho + 0.1 * lap + force * rho, 0.0, None)


@jax.jit
def _halos(rho, cutoff=1.5):
    """Count cells above the density cutoff (halo proxy)."""
    return jnp.sum(rho > cutoff)


ACTIONS = """
def nyx(vol, rank):
    def afc_cb(f):
        if vol.file_close_counter % 2 == 1:
            vol.clear_files()   # 1st close: single-rank metadata write
        else:
            vol.serve_all(True, True)
            vol.clear_files()
            vol.broadcast_files()
    vol.set_after_file_close(afc_cb)
"""


def run(io_freq: int, workdir: str) -> float:
    with open(os.path.join(workdir, "actions.py"), "w") as f:
        f.write(ACTIONS)
    yaml = f"""
tasks:
  - func: nyx
    nprocs: 1024
    actions: ["actions", "nyx"]
    outports:
      - filename: plt*.h5
        dsets: [{{name: /level_0/density, memory: 1}}]
  - func: reeber
    nprocs: 64
    inports:
      - filename: plt*.h5
        io_freq: {io_freq}
        dsets: [{{name: /level_0/density, memory: 1}}]
"""
    def nyx():
        key = jax.random.PRNGKey(0)
        rho = jnp.ones((GRID, GRID, GRID))
        for t in range(SNAPSHOTS):
            key = jax.random.fold_in(key, t)
            rho = _nyx_step(rho, key)
            time.sleep(NYX_COMPUTE_S)
            # double open/close idiom (paper §4.2.2)
            with h5.File(f"plt{t:05d}.h5", "w") as f:
                f.create_dataset("/level_0/density",
                                 data=np.zeros(1, np.float32))  # metadata
            with h5.File(f"plt{t:05d}.h5", "w") as f:
                f.create_dataset("/level_0/density", data=np.asarray(rho))

    halos = []

    def reeber():
        while True:
            f = h5.File("plt*.h5", "r")
            if f is None:
                return
            rho = jnp.asarray(f["/level_0/density"][:])
            n = _halos(rho)
            time.sleep(REEBER_SLOW_S)         # deliberate slowdown (paper)
            halos.append(int(n))

    w = Wilkins(yaml, {"nyx": nyx, "reeber": reeber})
    t0 = time.monotonic()
    w.run(timeout=300)
    assert halos, "reeber analyzed nothing"
    return time.monotonic() - t0


def main() -> None:
    import tempfile

    # warm the jits so timing measures the workflow, not compilation
    _nyx_step(jnp.ones((GRID, GRID, GRID)), jax.random.PRNGKey(0))
    _halos(jnp.ones((GRID, GRID, GRID)))

    with tempfile.TemporaryDirectory() as d:
        os.chdir(d)
        t_all = run(1, d)
        emit("cosmo/all", t_all, "s", "paper: 5421s")
        for n in (2, 5, 10):
            t = run(n, d)
            emit(f"cosmo/some_n{n}", t, "s",
                 f"saving {t_all / max(t, 1e-9):.1f}x "
                 f"(paper: {5421 / [2754, 1084, 702][(2, 5, 10).index(n)]:.1f}x)")


if __name__ == "__main__":
    main()
