"""Schedule-explorer benchmark (satellite 5): exploration throughput on
the clean scenario corpus and time-to-first-bug on the seeded-race
fixture corpus.

    PYTHONPATH=src python -m benchmarks.bench_explore [--smoke]

Two result families, written to ``BENCH_explore.json``:

* ``corpus`` -- per clean scenario: schedules explored, schedules/sec,
  whether the bounded frontier was exhausted, and that zero WLK3xx
  findings surfaced (the same gate CI's ``explore`` job runs);
* ``races`` -- per seeded fixture: schedules and wall seconds until the
  re-introduced bug is found, and that its schedule ID replays the same
  finding (the determinism contract).

Smoke mode trims the clean-corpus budget so the whole stage stays in
single-digit seconds; the race fixtures always run to discovery (their
budgets are tiny by construction).
"""

from __future__ import annotations

import argparse
import glob
import importlib.util
import os
import time

from .common import emit, write_json

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_RACEDIR = os.path.join(_REPO_ROOT, "tests", "analysis_fixtures", "races")


def _load_fixture(path):
    name = "_bench_race_" + os.path.splitext(os.path.basename(path))[0]
    spec = importlib.util.spec_from_file_location(name, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def main(smoke: bool = False):
    os.environ["WILKINS_EXPLORE"] = "1"
    from repro.analysis.explore import build_scenario, explore, names, replay

    budget = 256 if smoke else 8000
    corpus = {}
    for name in names():
        t0 = time.monotonic()
        rep = explore(build_scenario(name), scenario=name,
                      max_schedules=budget)
        dt = max(1e-9, time.monotonic() - t0)
        corpus[name] = {
            "schedules": rep.schedules,
            "schedules_per_s": rep.schedules / dt,
            "complete": bool(rep.complete),
            "clean": not rep.found,
            "elapsed_s": dt,
        }
        emit(f"explore.{name}.schedules", rep.schedules, "schedules",
             "clean" if not rep.found else "FOUND")
        emit(f"explore.{name}.rate", rep.schedules / dt, "schedules/s")

    races = {}
    for path in sorted(glob.glob(os.path.join(_RACEDIR, "wlk*.py"))):
        stem = os.path.splitext(os.path.basename(path))[0]
        mod = _load_fixture(path)
        t0 = time.monotonic()
        rep = explore(mod.build, scenario=stem, max_schedules=mod.BUDGET)
        dt = time.monotonic() - t0
        found = bool(rep.found and
                     mod.CODE in {d.code for d in rep.findings})
        replayed = False
        if found:
            again = replay(mod.build, rep.schedule_id)
            replayed = mod.CODE in {d.code for d in again.findings}
        races[stem] = {
            "code": mod.CODE,
            "budget": mod.BUDGET,
            "schedules_to_bug": rep.schedules,
            "time_to_bug_s": dt,
            "found": found,
            "replay_reproduces": replayed,
        }
        emit(f"explore.{stem}.schedules_to_bug", rep.schedules, "schedules",
             mod.CODE if found else "MISSED")
        emit(f"explore.{stem}.time_to_bug", dt, "s")

    results = {
        "smoke": smoke,
        "budget": budget,
        "corpus": corpus,
        "races": races,
        "corpus_clean": all(c["clean"] for c in corpus.values()),
        "all_races_found": all(r["found"] and r["replay_reproduces"]
                               for r in races.values()),
    }
    write_json("explore", results)
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    res = main(smoke=ap.parse_args().smoke)
    raise SystemExit(0 if res["corpus_clean"] and res["all_races_found"]
                     else 1)
