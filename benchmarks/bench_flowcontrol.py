"""Paper Table 2 + Fig. 5: flow-control strategies vs slow consumers, plus
the adaptive-scheduler benchmark (``BENCH_scheduler.json``).

Producer computes for P seconds per timestep (10 timesteps); consumers are
2x/5x/10x slower.  Strategies: all (io_freq=1), some (io_freq=N matching the
slowdown), latest (io_freq=-1).  Scaled: P=0.08s (paper: 2s, 512 procs).
Also dumps the Fig. 5 Gantt event timeline as CSV.

``bench_scheduler`` measures the runtime-scheduling subsystem on a 2-edge
disparate-rate workflow (fast producer -> slow consumer, slow producer ->
fast consumer): a static depth-1 baseline vs ``scheduler: {policy: fair}``
with ``weight: 3`` and ``autotune:`` on the hot edge.  The --smoke gate
requires the autotuned run's consumer ``blocked_s`` to stay at or below the
static baseline, and the telemetry timeline to round-trip through JSON with
the same per-edge sample counts.
"""

from __future__ import annotations

import csv
import os
import time
from typing import Any, Dict

import numpy as np

from repro.core import h5, Wilkins
from repro.core.datamodel import BlockOwnership, reset_transport_stats
from repro.core.redistribute import even_blocks
from repro.core.scheduler import TelemetryTimeline

from .common import emit, synthetic_datasets, write_json

STEPS = 10
P_SLEEP = 0.08
MIB = 1 << 20


def run(io_freq: int, slow: float, record=False):
    yaml = f"""
tasks:
  - func: producer
    outports:
      - filename: o.h5
        dsets: [{{name: /g, memory: 1}}]
  - func: consumer
    inports:
      - filename: o.h5
        io_freq: {io_freq}
        dsets: [{{name: /g, memory: 1}}]
"""
    def producer():
        for t in range(STEPS):
            time.sleep(P_SLEEP)                      # compute
            with h5.File("o.h5", "w") as f:
                g, _ = synthetic_datasets(10_000, 0, t)
                f.create_dataset("/g", data=g)

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                return
            time.sleep(P_SLEEP * slow)               # analyze

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer},
                record_events=record)
    t0 = time.monotonic()
    rep = w.run(timeout=120)
    return time.monotonic() - t0, rep


def _disparate_yaml(adaptive: bool) -> str:
    """Two disparate-rate edges: hot (fast producer -> slow consumer prep)
    and cold (slow producer -> fast consumer).  The adaptive variant turns
    on the fair DWRR policy, a 3:1 weight, and depth autotuning on the hot
    edge; the baseline keeps today's static depth-1 FIFO everywhere."""
    if adaptive:
        sched = "scheduler: {policy: fair, tick_every: 2}"
        hot = ("weight: 3\n        prefetch: 1\n        "
               "autotune: {min: 1, max: 4}")
    else:
        sched = "scheduler: {policy: fifo}"
        hot = "weight: 1\n        prefetch: 1"
    return f"""
{sched}
tasks:
  - func: prod_fast
    nprocs: 2
    outports:
      - filename: fast.h5
        dsets: [{{name: /grid, memory: 1}}]
  - func: cons_slow
    nprocs: 2
    inports:
      - filename: fast.h5
        redistribute: 1
        queue_depth: 4
        {hot}
        dsets: [{{name: /grid, memory: 1}}]
  - func: prod_slow
    nprocs: 2
    outports:
      - filename: slow.h5
        dsets: [{{name: /grid, memory: 1}}]
  - func: cons_fast
    nprocs: 2
    inports:
      - filename: slow.h5
        redistribute: 1
        queue_depth: 2
        prefetch: 1
        dsets: [{{name: /grid, memory: 1}}]
"""


def _run_disparate(adaptive: bool, mib_per_step: float, steps: int
                   ) -> Dict[str, Any]:
    """One disparate-rate run; returns per-edge blocked/hit counters and the
    telemetry round-trip check.  ``zero_copy=False`` makes payload prep do a
    real slab copy -- the serve-side cost the depth autotuner must hide."""
    n = int(mib_per_step * MIB // 8)
    payload = np.arange(n, dtype=np.float64)
    own = BlockOwnership()
    for r, (s, sh) in enumerate(even_blocks((n,), 2)):
        own.add(r, s, sh)

    def prod_fast():
        for _ in range(steps):
            with h5.File("fast.h5", "w") as f:
                f.create_dataset("/grid", data=payload, ownership=own)

    def cons_slow():
        while True:
            f = h5.File("fast.h5", "r")
            if f is None:
                return
            _ = float(f["/grid"][0])

    def prod_slow():
        for _ in range(steps):
            time.sleep(0.005)
            with h5.File("slow.h5", "w") as f:
                f.create_dataset("/grid", data=payload, ownership=own)

    def cons_fast():
        while True:
            f = h5.File("slow.h5", "r")
            if f is None:
                return
            _ = float(f["/grid"][0])

    w = Wilkins(_disparate_yaml(adaptive),
                {"prod_fast": prod_fast, "cons_slow": cons_slow,
                 "prod_slow": prod_slow, "cons_fast": cons_fast},
                zero_copy=False)
    reset_transport_stats()
    t0 = time.monotonic()
    rep = w.run(timeout=300)
    wall = time.monotonic() - t0

    def edge_sum(task, field):
        return sum(getattr(c.stats, field) for c in w.channels
                   if c.consumer[0] == task)

    tl_roundtrip = False
    if rep.timeline is not None:
        back = TelemetryTimeline.from_json(rep.timeline.to_json())
        tl_roundtrip = (back.per_edge_counts()
                        == rep.timeline.per_edge_counts())
    return {
        "adaptive": adaptive,
        "steps": steps,
        "mib_per_step": mib_per_step,
        "wall_s": wall,
        "hot_blocked_s": edge_sum("cons_slow", "prefetch_blocked_s"),
        "hot_hits": edge_sum("cons_slow", "prefetch_hits"),
        "hot_misses": edge_sum("cons_slow", "prefetch_misses"),
        "cold_blocked_s": edge_sum("cons_fast", "prefetch_blocked_s"),
        "scheduler": rep.scheduler,
        "final_depths": rep.scheduler.get("depths", {}),
        "retunes": len(rep.scheduler.get("decisions", [])),
        "telemetry_samples": rep.scheduler.get("telemetry_samples", 0),
        "telemetry_roundtrip_ok": tl_roundtrip,
    }


def bench_scheduler(smoke: bool = False) -> Dict[str, Any]:
    """Static depth-1 baseline vs fair policy + depth autotuning on the
    disparate-rate workflow; emits the --smoke gate inputs and persists
    everything as BENCH_scheduler.json."""
    # static blocked_s grows ~linearly in steps while the autotuned run
    # stops missing once depth converges, so longer runs widen the gate
    # margin; smoke stays a few seconds
    mib, steps = (4.0, 24) if smoke else (16.0, 40)
    static = _run_disparate(False, mib, steps)
    adaptive = _run_disparate(True, mib, steps)
    if adaptive["hot_blocked_s"] > static["hot_blocked_s"]:
        # timing gate: one retry absorbs a noisy neighbour on a loaded CI
        # box (a genuine regression fails both attempts)
        static = _run_disparate(False, mib, steps)
        adaptive = _run_disparate(True, mib, steps)
    emit("scheduler_static_blocked_s", static["hot_blocked_s"], "s",
         f"hot edge, depth-1 fifo, {steps} steps x {mib}MiB")
    emit("scheduler_autotuned_blocked_s", adaptive["hot_blocked_s"], "s",
         "fair policy, weight 3:1, autotune [1,4] "
         "(<= static baseline acceptance)")
    emit("scheduler_autotuned_retunes", adaptive["retunes"], "decisions",
         str([f"{d['edge']}:{d['old']}->{d['new']}"
              for d in adaptive["scheduler"].get("decisions", [])][:6]))
    emit("scheduler_telemetry_roundtrip",
         int(adaptive["telemetry_roundtrip_ok"]), "bool",
         f"{adaptive['telemetry_samples']} samples export->load")
    results = {"static": static, "adaptive": adaptive,
               "blocked_improved": (adaptive["hot_blocked_s"]
                                    <= static["hot_blocked_s"] + 1e-9),
               "telemetry_roundtrip_ok": adaptive["telemetry_roundtrip_ok"]}
    write_json("scheduler", results)
    return results


def main() -> None:
    results = {}
    for slow, freq in ((2, 2), (5, 5), (10, 10)):
        t_all, _ = run(1, slow)
        t_some, _ = run(freq, slow)
        t_latest, _ = run(-1, slow)
        results[slow] = (t_all, t_some, t_latest)
        emit(f"flowcontrol/all/{slow}x", t_all, "s")
        emit(f"flowcontrol/some_n{freq}/{slow}x", t_some, "s",
             f"saving {t_all / max(t_some, 1e-9):.1f}x (paper: up to 4.7x)")
        emit(f"flowcontrol/latest/{slow}x", t_latest, "s",
             f"saving {t_all / max(t_latest, 1e-9):.1f}x (paper: up to 4.6x)")

    # Fig 5: Gantt events for the 5x case under 'all'
    _, rep = run(1, 5, record=True)
    out = os.path.join(os.path.dirname(__file__), "..", "results",
                       "gantt_5x_all.csv")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w", newline="") as f:
        wcsv = csv.writer(f)
        wcsv.writerow(["t", "channel", "who", "what"])
        for row in rep.gantt_events():
            wcsv.writerow(row)
    emit("flowcontrol/gantt_events", len(rep.gantt_events()), "events",
         os.path.abspath(out))

    bench_scheduler()


if __name__ == "__main__":
    main()
