"""Paper Table 2 + Fig. 5: flow-control strategies vs slow consumers.

Producer computes for P seconds per timestep (10 timesteps); consumers are
2x/5x/10x slower.  Strategies: all (io_freq=1), some (io_freq=N matching the
slowdown), latest (io_freq=-1).  Scaled: P=0.08s (paper: 2s, 512 procs).
Also dumps the Fig. 5 Gantt event timeline as CSV.
"""

from __future__ import annotations

import csv
import os
import time

import numpy as np

from repro.core import h5, Wilkins

from .common import emit, synthetic_datasets

STEPS = 10
P_SLEEP = 0.08


def run(io_freq: int, slow: float, record=False):
    yaml = f"""
tasks:
  - func: producer
    outports:
      - filename: o.h5
        dsets: [{{name: /g, memory: 1}}]
  - func: consumer
    inports:
      - filename: o.h5
        io_freq: {io_freq}
        dsets: [{{name: /g, memory: 1}}]
"""
    def producer():
        for t in range(STEPS):
            time.sleep(P_SLEEP)                      # compute
            with h5.File("o.h5", "w") as f:
                g, _ = synthetic_datasets(10_000, 0, t)
                f.create_dataset("/g", data=g)

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                return
            time.sleep(P_SLEEP * slow)               # analyze

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer},
                record_events=record)
    t0 = time.monotonic()
    rep = w.run(timeout=120)
    return time.monotonic() - t0, rep


def main() -> None:
    results = {}
    for slow, freq in ((2, 2), (5, 5), (10, 10)):
        t_all, _ = run(1, slow)
        t_some, _ = run(freq, slow)
        t_latest, _ = run(-1, slow)
        results[slow] = (t_all, t_some, t_latest)
        emit(f"flowcontrol/all/{slow}x", t_all, "s")
        emit(f"flowcontrol/some_n{freq}/{slow}x", t_some, "s",
             f"saving {t_all / max(t_some, 1e-9):.1f}x (paper: up to 4.7x)")
        emit(f"flowcontrol/latest/{slow}x", t_latest, "s",
             f"saving {t_all / max(t_latest, 1e-9):.1f}x (paper: up to 4.6x)")

    # Fig 5: Gantt events for the 5x case under 'all'
    _, rep = run(1, 5, record=True)
    out = os.path.join(os.path.dirname(__file__), "..", "results",
                       "gantt_5x_all.csv")
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w", newline="") as f:
        wcsv = csv.writer(f)
        wcsv.writerow(["t", "channel", "who", "what"])
        for row in rep.gantt_events():
            wcsv.writerow(row)
    emit("flowcontrol/gantt_events", len(rep.gantt_events()), "events",
         os.path.abspath(out))


if __name__ == "__main__":
    main()
