"""Recovery benchmark: what does a mid-run crash cost?

A producer->consumer pipeline ships 64 MiB/step (8 MiB at smoke sizes), both
tasks under ``on_failure: restart`` with per-step checkpoints.  One run is
crash-free; the other injects a deterministic consumer crash in the
delivered-but-unseen window at the middle step.  Measured:

* **recovery latency** -- restart event to the recovered incarnation's next
  payload receipt (channel event timeline, same monotonic clock);
* **steps replayed** -- payloads requeued from the replay buffer (the work
  the crash forced the transport to redo);
* **byte-exactness** -- the recovered run's final accumulator must equal the
  crash-free run's bit-for-bit (the tentpole's acceptance property);
* **overhead** -- recovered wall time vs crash-free wall time (the smoke
  gate bounds it: a restart may cost a backoff + one replayed step, not a
  rerun of the workflow).

Writes ``BENCH_recovery.json`` and prints the usual CSV rows.
"""

from __future__ import annotations

import shutil
import tempfile
from typing import Any, Dict

import numpy as np

from repro.core import FaultSpec, Wilkins, h5

from .common import Timer, emit, write_json

MIB = 1 << 20

RECOVERY_YAML = """
tasks:
  - func: producer
    on_failure:
      restart: {max_retries: 2}
    outports:
      - filename: state.h5
        dsets:
          - {name: /grid, memory: 1}
  - func: consumer
    on_failure:
      restart: {max_retries: 2}
    inports:
      - filename: state.h5
        dsets:
          - {name: /grid, memory: 1}
"""


def _make_funcs(n_elems: int, steps: int, out: Dict[str, Any]):
    """Checkpoint-every-step producer/consumer pair (uint64 math: exact)."""

    def producer(comm):
        start = 0
        r = comm.restore({"step": np.zeros((), np.int64)})
        if r is not None:
            start = int(r[1]["step"])
        for t in range(start, steps):
            with h5.File("state.h5", "w") as f:
                f.create_dataset(
                    "/grid", data=np.arange(n_elems, dtype=np.uint64) + t)
            comm.checkpoint({"step": np.array(t + 1, np.int64)})

    def consumer(comm):
        like = {"acc": np.zeros(n_elems, np.uint64),
                "n": np.zeros((), np.int64)}
        state = like
        r = comm.restore(like)
        if r is not None:
            state = r[1]
        while True:
            f = h5.File("state.h5", "r")
            if f is None:
                break
            state = {"acc": state["acc"] + f["/grid"][...],
                     "n": state["n"] + np.int64(1)}
            comm.checkpoint(state)
        out["acc"] = np.asarray(state["acc"])
        out["n"] = int(state["n"])

    return {"producer": producer, "consumer": consumer}


def _run(n_elems: int, steps: int, faults=None):
    out: Dict[str, Any] = {}
    spill = tempfile.mkdtemp(prefix="wilkins_bench_recovery_")
    try:
        w = Wilkins(RECOVERY_YAML, _make_funcs(n_elems, steps, out),
                    spill_dir=spill, record_events=True)
        with Timer() as t:
            rep = w.run(timeout=600, faults=faults)
    finally:
        shutil.rmtree(spill, ignore_errors=True)
    return out, rep, t.dt


def _recovery_latency_s(rep) -> float:
    """Restart event to the recovered incarnation's first receipt (the
    channel event ring and the RestartEvent share one monotonic clock)."""
    t0 = rep.restarts[0]["t"]
    recvs = [t for c in rep.channels
             for (t, who, what) in c.stats.events
             if who == "consumer" and what == "recv" and t > t0]
    return (min(recvs) - t0) if recvs else float("nan")


def main(smoke: bool = False) -> Dict[str, Any]:
    bytes_per_step = (8 if smoke else 64) * MIB
    n_elems = bytes_per_step // 8  # uint64 grid
    steps = 4 if smoke else 8
    crash_step = steps // 2

    ref_out, ref_rep, ref_s = _run(n_elems, steps)
    rec_out, rec_rep, rec_s = _run(
        n_elems, steps,
        faults=FaultSpec(task="consumer", point="recv", step=crash_step))

    byte_exact = (ref_out["n"] == rec_out["n"] == steps
                  and np.array_equal(ref_out["acc"], rec_out["acc"]))
    steps_replayed = sum(c.stats.replayed for c in rec_rep.channels)
    latency_s = _recovery_latency_s(rec_rep)
    overhead_x = rec_s / max(ref_s, 1e-9)
    # absolute slack on top of the ratio: at smoke sizes the crash-free run
    # is ~100 ms, so a pure ratio gate would measure scheduler noise
    overhead_ok = rec_s <= 3.0 * ref_s + 1.0

    emit("recovery_bytes_per_step", bytes_per_step, "B")
    emit("recovery_crash_free_s", ref_s, "s", f"steps={steps}")
    emit("recovery_recovered_s", rec_s, "s",
         f"crash@recv step={crash_step}")
    emit("recovery_overhead", overhead_x, "x", "recovered/crash_free")
    emit("recovery_latency_s", latency_s, "s",
         "restart event -> next receipt")
    emit("recovery_steps_replayed", steps_replayed, "steps")
    emit("recovery_byte_exact", int(byte_exact), "bool")

    results = {
        "bytes_per_step": bytes_per_step,
        "steps": steps,
        "crash_step": crash_step,
        "crash_free_s": ref_s,
        "recovered_s": rec_s,
        "overhead_x": overhead_x,
        "overhead_ok": overhead_ok,
        "recovery_latency_s": latency_s,
        "steps_replayed": int(steps_replayed),
        "restarts": len(rec_rep.restarts),
        "restarts_crash_free": len(ref_rep.restarts),
        "byte_exact": bool(byte_exact),
    }
    write_json("recovery", results)
    return results


if __name__ == "__main__":
    main()
