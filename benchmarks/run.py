"""Benchmark suite entry point: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--smoke]

  overhead     -> paper Fig. 4  (Wilkins vs transport-alone, weak scaling)
  flowcontrol  -> paper Table 2 + Fig. 5 (all/some/latest, Gantt CSV)
  ensembles    -> paper Figs. 7/8/9 (fan-out / fan-in / NxN)
  nucleation   -> paper Fig. 10 (materials-science NxN ensemble, nwriters=1)
  cosmo        -> paper Table 3 (Nyx+Reeber, custom actions + io_freq sweep)
  transport    -> zero-copy fast path (CoW fan-out, mmap spill, queue_depth)
  roofline     -> §Roofline table from the dry-run grid (not a paper artifact)

``--smoke`` is the tier-1 entry point: it runs the pytest suite and then a
small transport bench, and fails if either fails.

Every benchmark prints ``name,value,unit,derived`` CSV rows; the transport
bench additionally writes machine-readable ``BENCH_transport.json``.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time
import traceback

SUITES = ("overhead", "flowcontrol", "ensembles", "nucleation", "cosmo",
          "transport", "roofline")

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _smoke() -> int:
    """Tier-1 gate: pytest suite + transport bench at smoke sizes."""
    env = dict(os.environ)
    src = os.path.join(_REPO_ROOT, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    if src not in sys.path:  # the in-process bench import needs it too
        sys.path.insert(0, src)
    print("==== smoke: pytest ====", flush=True)
    rc = subprocess.call([sys.executable, "-m", "pytest", "-x", "-q"],
                         cwd=_REPO_ROOT, env=env)
    if rc != 0:
        print("==== smoke: pytest FAILED ====", flush=True)
        return rc
    print("==== smoke: bench_transport ====", flush=True)
    from . import bench_transport
    results = bench_transport.main(smoke=True)
    ratio = results["fanout"]["copy_reduction_x"]
    print(f"==== smoke: copy_reduction={ratio:.1f}x ====", flush=True)
    return 0 if ratio >= 2.0 else 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=SUITES, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="run the tier-1 pytest suite + a quick transport "
                         "bench and exit")
    args = ap.parse_args()
    if args.smoke:
        return _smoke()
    suites = [args.only] if args.only else list(SUITES)

    cwd = os.getcwd()
    failures = 0
    for name in suites:
        print(f"\n==== {name} ====", flush=True)
        t0 = time.monotonic()
        try:
            if name == "roofline":
                from . import roofline as mod
            else:
                mod = __import__(f"benchmarks.bench_{name}",
                                 fromlist=["main"])
            mod.main()
            print(f"==== {name} done in {time.monotonic() - t0:.1f}s ====",
                  flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"==== {name} FAILED ====", flush=True)
        finally:
            os.chdir(cwd)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
