"""Benchmark suite entry point: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--smoke]

  overhead     -> paper Fig. 4  (Wilkins vs transport-alone, weak scaling)
  flowcontrol  -> paper Table 2 + Fig. 5 (all/some/latest, Gantt CSV)
  ensembles    -> paper Figs. 7/8/9 (fan-out / fan-in / NxN)
  nucleation   -> paper Fig. 10 (materials-science NxN ensemble, nwriters=1)
  cosmo        -> paper Table 3 (Nyx+Reeber, custom actions + io_freq sweep)
  transport    -> zero-copy fast path (CoW fan-out, mmap spill, queue_depth)
  redistribute -> M->N planned transport (plan cache, slab shipping, aligned
                  fast path, Pallas pack executor)
  recovery     -> fault-tolerant execution (mid-run crash, checkpointed
                  restart, replay; byte-exact recovery + latency/overhead)
  rescale      -> elastic M->N rescale (supervised shrink mid-run: checkpoint
                  re-cut, channel rebuild, replay; byte-exact + surgery
                  latency + overhead vs a same-size restart)
  explore      -> deterministic schedule explorer (clean-corpus throughput,
                  time-to-first-bug on the seeded-race fixtures)
  obs          -> span-tracing overhead (zero-cost off, <= 5% on) and
                  critical-path attribution consistency
  roofline     -> §Roofline table from the dry-run grid (not a paper artifact)

``--smoke`` is the tier-1 entry point: it first runs the pre-run analyzer
self-check (``repro.analysis`` over every example workflow plus the lock-
discipline AST lint over ``src/repro`` -- any error-severity finding fails
the gate), then the pytest suite, a small
transport bench, a small redistribution bench, and the scheduler bench, and
fails if any fails (gates: fan-out copy reduction >= 2x, M->N bytes-shipped
reduction >= 2x, plan-cache hit rate >= 0.9, zero aligned-path copies,
prefetch overlap >= 0.30, a byte-exact 3-D reshard on the flattened
pack-kernel path, the autotuned disparate-rate run's consumer blocked_s at
or below the static-depth baseline, a telemetry JSON round trip, a
byte-exact mid-run crash recovery with bounded overhead, a byte-exact
elastic 2->1 rescale with bounded surgery latency, and the span-tracing
overhead gate: zero-cost when off, <= 5% wall when on, attribution
buckets summing to each instance's window).
``WILKINS_SMOKE_SKIP_PYTEST=1`` skips the pytest stage (CI runs the suite
as its own fast/slow job steps).

Every benchmark prints ``name,value,unit,derived`` CSV rows; the transport
and redistribution benches additionally write machine-readable
``BENCH_transport.json`` / ``BENCH_redistribute.json``.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time
import traceback

SUITES = ("overhead", "flowcontrol", "ensembles", "nucleation", "cosmo",
          "transport", "redistribute", "recovery", "rescale", "explore",
          "obs", "roofline")

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _smoke() -> int:
    """Tier-1 gate: pytest suite + transport bench at smoke sizes.

    Set ``WILKINS_SMOKE_SKIP_PYTEST=1`` to skip the pytest stage (CI runs
    the suite as its own job step right before the smoke benches, split
    into fast / ``-m slow`` jobs; re-running it here would double the
    walltime).
    """
    env = dict(os.environ)
    src = os.path.join(_REPO_ROOT, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    if src not in sys.path:  # the in-process bench import needs it too
        sys.path.insert(0, src)
    print("==== smoke: analyzer self-check ====", flush=True)
    import glob
    from repro.analysis.cli import main as _analysis_main
    examples = sorted(glob.glob(os.path.join(_REPO_ROOT, "examples", "*.py")))
    rc = _analysis_main(["check", *examples])
    if rc == 0:
        rc = _analysis_main(["lint", os.path.join(src, "repro")])
    if rc != 0:
        print("==== smoke: analyzer FAILED ====", flush=True)
        return rc
    skip_pytest = os.environ.get("WILKINS_SMOKE_SKIP_PYTEST", "")
    if skip_pytest.strip().lower() not in ("", "0", "false", "no"):
        print("==== smoke: pytest SKIPPED (WILKINS_SMOKE_SKIP_PYTEST) ====",
              flush=True)
    else:
        print("==== smoke: pytest ====", flush=True)
        rc = subprocess.call([sys.executable, "-m", "pytest", "-x", "-q"],
                             cwd=_REPO_ROOT, env=env)
        if rc != 0:
            print("==== smoke: pytest FAILED ====", flush=True)
            return rc
    print("==== smoke: bench_transport ====", flush=True)
    from . import bench_transport
    results = bench_transport.main(smoke=True)
    ratio = results["fanout"]["copy_reduction_x"]
    print(f"==== smoke: copy_reduction={ratio:.1f}x ====", flush=True)
    if ratio < 2.0:
        return 1
    print("==== smoke: bench_redistribute ====", flush=True)
    from . import bench_redistribute
    rr = bench_redistribute.main(smoke=True)
    shipped = rr["mxn"]["bytes_reduction_x"]
    hit_rate = rr["mxn"]["plan_cache_hit_rate"]
    aligned_copied = rr["aligned"]["transport_bytes_copied"]
    overlap = rr["prefetch"]["overlap_frac"]
    nd = rr["pack_nd"]
    print(f"==== smoke: redistribute bytes_reduction={shipped:.1f}x "
          f"plan_cache_hit_rate={hit_rate:.2f} "
          f"aligned_bytes_copied={aligned_copied} "
          f"prefetch_overlap={overlap:.2f} "
          f"pack3d_mode={nd['pack_mode']} pack3d_exact={nd['byte_exact']} "
          f"====", flush=True)
    print("==== smoke: bench_scheduler ====", flush=True)
    from . import bench_flowcontrol
    sr = bench_flowcontrol.bench_scheduler(smoke=True)
    print(f"==== smoke: scheduler "
          f"static_blocked={sr['static']['hot_blocked_s']:.3f}s "
          f"autotuned_blocked={sr['adaptive']['hot_blocked_s']:.3f}s "
          f"telemetry_roundtrip={sr['telemetry_roundtrip_ok']} "
          f"====", flush=True)
    print("==== smoke: bench_recovery ====", flush=True)
    from . import bench_recovery
    rec = bench_recovery.main(smoke=True)
    print(f"==== smoke: recovery byte_exact={rec['byte_exact']} "
          f"restarts={rec['restarts']} replayed={rec['steps_replayed']} "
          f"latency={rec['recovery_latency_s']:.3f}s "
          f"overhead={rec['overhead_x']:.2f}x ====", flush=True)
    print("==== smoke: bench_rescale ====", flush=True)
    from . import bench_rescale
    rsc = bench_rescale.main(smoke=True)
    print(f"==== smoke: rescale byte_exact={rsc['byte_exact']} "
          f"{rsc['old_nslots']}->{rsc['new_nslots']} "
          f"replayed={rsc['steps_replayed']} "
          f"latency={rsc['rescale_latency_s']:.3f}s "
          f"overhead_vs_restart={rsc['overhead_vs_restart_x']:.2f}x ====",
          flush=True)
    print("==== smoke: bench_explore ====", flush=True)
    from . import bench_explore
    # the explorer flips WILKINS_EXPLORE for its own process; scrub it so
    # later stages (and reruns) see plain primitives again
    try:
        xp = bench_explore.main(smoke=True)
    finally:
        os.environ.pop("WILKINS_EXPLORE", None)
    print(f"==== smoke: explore corpus_clean={xp['corpus_clean']} "
          f"races_found={xp['all_races_found']} ====", flush=True)
    print("==== smoke: bench_obs ====", flush=True)
    from . import bench_obs
    ob = bench_obs.main(smoke=True)
    print(f"==== smoke: obs overhead={ob['overhead_x']:.3f}x "
          f"zero_cost={ob['zero_cost_ok']} spans={ob['trace_spans']} "
          f"layers={len(ob['layers'])} "
          f"attribution_ok={ob['attribution_nonempty'] and ob['attribution_sums_ok']} "
          f"====", flush=True)
    # gates: M->N shipped-bytes reduction, steady-state plan reuse, aligned
    # zero-copy, the reshard+prefetch pipeline hiding >= 30% of slab-serve
    # time behind consumer compute on the 4->2 edge, the 3-D reshard
    # staying on the flattened kernel path byte-exactly (no numpy fallback),
    # the autotuned disparate-rate run blocking its consumer no longer than
    # the static-depth baseline, the telemetry JSON round-tripping, and the
    # elastic 2->1 rescale landing byte-exact with a bounded surgery window
    ok = (shipped >= 2.0 and hit_rate >= 0.9 and aligned_copied == 0
          and overlap >= 0.30
          and nd["pack_mode"] is not None and nd["byte_exact"]
          and sr["blocked_improved"] and sr["telemetry_roundtrip_ok"]
          and rec["byte_exact"] and rec["restarts"] == 1
          and rec["restarts_crash_free"] == 0
          and rec["steps_replayed"] >= 1 and rec["overhead_ok"]
          and rsc["byte_exact"] and rsc["rescales"] == 1
          and rsc["rescales_crash_free"] == 0
          and rsc["latency_ok"] and rsc["overhead_ok"]
          and xp["corpus_clean"] and xp["all_races_found"]
          and ob["ok"])
    return 0 if ok else 1


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=SUITES, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="run the tier-1 pytest suite + a quick transport "
                         "bench and exit")
    args = ap.parse_args()
    if args.smoke:
        return _smoke()
    suites = [args.only] if args.only else list(SUITES)

    cwd = os.getcwd()
    failures = 0
    for name in suites:
        print(f"\n==== {name} ====", flush=True)
        t0 = time.monotonic()
        try:
            if name == "roofline":
                from . import roofline as mod
            else:
                mod = __import__(f"benchmarks.bench_{name}",
                                 fromlist=["main"])
            mod.main()
            print(f"==== {name} done in {time.monotonic() - t0:.1f}s ====",
                  flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"==== {name} FAILED ====", flush=True)
        finally:
            os.chdir(cwd)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
