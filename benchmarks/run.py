"""Benchmark suite entry point: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

  overhead     -> paper Fig. 4  (Wilkins vs transport-alone, weak scaling)
  flowcontrol  -> paper Table 2 + Fig. 5 (all/some/latest, Gantt CSV)
  ensembles    -> paper Figs. 7/8/9 (fan-out / fan-in / NxN)
  nucleation   -> paper Fig. 10 (materials-science NxN ensemble, nwriters=1)
  cosmo        -> paper Table 3 (Nyx+Reeber, custom actions + io_freq sweep)
  roofline     -> §Roofline table from the dry-run grid (not a paper artifact)

Every benchmark prints ``name,value,unit,derived`` CSV rows.
"""

from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

SUITES = ("overhead", "flowcontrol", "ensembles", "nucleation", "cosmo",
          "roofline")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=SUITES, default=None)
    args = ap.parse_args()
    suites = [args.only] if args.only else list(SUITES)

    cwd = os.getcwd()
    failures = 0
    for name in suites:
        print(f"\n==== {name} ====", flush=True)
        t0 = time.monotonic()
        try:
            if name == "roofline":
                from . import roofline as mod
            else:
                mod = __import__(f"benchmarks.bench_{name}",
                                 fromlist=["main"])
            mod.main()
            print(f"==== {name} done in {time.monotonic() - t0:.1f}s ====",
                  flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"==== {name} FAILED ====", flush=True)
        finally:
            os.chdir(cwd)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
