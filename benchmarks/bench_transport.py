"""Transport fast-path benchmark (the paper's Fig. 4 overhead lever).

Measures the zero-copy data path against the legacy materialize-per-channel
path on the three axes the tentpole targets:

* ``fanout``  -- 1 producer -> N consumers in memory mode: bytes/copies
  materialized by the transport (``repro.core.datamodel.transport_stats``),
  producer/consumer wait, and wall time, for ``zero_copy`` on vs off.
* ``spill``   -- the ``file: 1`` container: raw + ``np.memmap`` load vs a
  full-read load (``mmap=False``), save/load latency and bytes copied.
* ``pipeline``-- ``queue_depth`` sweep: producer wait with a slow consumer
  (depth >= 2 lets the producer run ahead; depth 1 is the paper's rendezvous).

Every row goes through ``common.emit`` and the whole result dict is persisted
as ``BENCH_transport.json`` via ``common.write_json``.

    PYTHONPATH=src python -m benchmarks.bench_transport [--smoke]
"""

from __future__ import annotations

import argparse
import os
import tempfile
import time
from typing import Any, Dict

import numpy as np

from repro.core import Wilkins, h5
from repro.core.datamodel import File, reset_transport_stats, transport_stats

from .common import Timer, emit, write_json

MIB = 1 << 20


# ---------------------------------------------------------------------------
# 1 producer -> N consumers fan-out
# ---------------------------------------------------------------------------
def _fanout_yaml(consumers: int, queue_depth: int = 1) -> str:
    return f"""
tasks:
  - func: producer
    outports:
      - filename: o.h5
        dsets: [{{name: /grid, memory: 1}}]
  - func: consumer
    taskCount: {consumers}
    inports:
      - filename: o.h5
        queue_depth: {queue_depth}
        dsets: [{{name: /grid, memory: 1}}]
"""


def run_fanout(zero_copy: bool, mib_per_step: float, steps: int,
               consumers: int = 4) -> Dict[str, Any]:
    n = int(mib_per_step * MIB // 8)
    payload = np.arange(n, dtype=np.uint64)

    def producer():
        for t in range(steps):
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/grid", data=payload)

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            # touch the data like a real analysis task (no mutation)
            _ = int(f["/grid"][0])

    w = Wilkins(_fanout_yaml(consumers),
                {"producer": producer, "consumer": consumer},
                zero_copy=zero_copy)
    reset_transport_stats()
    with Timer() as t:
        rep = w.run(timeout=600)
    s = transport_stats().snapshot()
    return {
        "zero_copy": zero_copy,
        "consumers": consumers,
        "steps": steps,
        "mib_per_step": mib_per_step,
        "bytes_copied": s["bytes_copied"],
        "copies": s["copies"],
        "views": s["views"],
        "bytes_moved": rep.total_bytes_moved,
        "served": rep.total_served,
        "producer_wait_s": sum(c.stats.producer_wait_s for c in rep.channels),
        "consumer_wait_s": sum(c.stats.consumer_wait_s for c in rep.channels),
        "wall_s": t.dt,
    }


def bench_fanout(mib_per_step: float, steps: int, consumers: int) -> Dict[str, Any]:
    legacy = run_fanout(False, mib_per_step, steps, consumers)
    fast = run_fanout(True, mib_per_step, steps, consumers)
    ratio = legacy["bytes_copied"] / max(1, fast["bytes_copied"])
    for tag, r in (("legacy", legacy), ("zero_copy", fast)):
        emit(f"transport_fanout_{tag}_bytes_copied", r["bytes_copied"], "B",
             f"{consumers}cons x {steps}steps x {mib_per_step}MiB")
        emit(f"transport_fanout_{tag}_wall", r["wall_s"], "s")
        emit(f"transport_fanout_{tag}_producer_wait", r["producer_wait_s"], "s")
    emit("transport_fanout_copy_reduction", ratio, "x",
         "legacy bytes_copied / zero_copy bytes_copied (>=2x acceptance)")
    return {"legacy": legacy, "zero_copy": fast, "copy_reduction_x": ratio}


# ---------------------------------------------------------------------------
# spill container: raw + memmap vs full-read
# ---------------------------------------------------------------------------
def bench_spill(mib: float) -> Dict[str, Any]:
    n = int(mib * MIB // 8)
    f = File("spill.h5")
    d = f.create_dataset("/grid", data=np.arange(n, dtype=np.float64))
    d.attrs["t"] = 1
    out: Dict[str, Any] = {"mib": mib}
    with tempfile.TemporaryDirectory() as tmp:
        with Timer() as t:
            path = f.save(tmp)
        out["save_s"] = t.dt
        emit("transport_spill_save", t.dt, "s", f"{mib}MiB raw container")

        for tag, mmap in (("mmap", True), ("copy", False)):
            reset_transport_stats()
            with Timer() as t:
                g = File.load(path, mmap=mmap)
                first = float(g["/grid"][0])  # touch a page
            assert first == 0.0
            s = transport_stats().snapshot()
            out[f"load_{tag}_s"] = t.dt
            out[f"load_{tag}_bytes_copied"] = s["bytes_copied"]
            emit(f"transport_spill_load_{tag}", t.dt, "s",
                 f"bytes_copied={s['bytes_copied']}")
            del g
    return out


# ---------------------------------------------------------------------------
# queue_depth pipelining
# ---------------------------------------------------------------------------
def bench_pipeline(steps: int, consumer_sleep: float) -> Dict[str, Any]:
    out: Dict[str, Any] = {"steps": steps, "consumer_sleep_s": consumer_sleep}
    for depth in (1, 2, 4):
        def producer():
            for t in range(steps):
                with h5.File("o.h5", "w") as f:
                    f.create_dataset("/g", data=np.array([t]))

        def consumer():
            while True:
                f = h5.File("o.h5", "r")
                if f is None:
                    break
                time.sleep(consumer_sleep)

        w = Wilkins(_fanout_yaml(1, queue_depth=depth),
                    {"producer": producer, "consumer": consumer})
        with Timer() as t:
            rep = w.run(timeout=600)
        wait = sum(c.stats.producer_wait_s for c in rep.channels)
        out[f"depth{depth}_producer_wait_s"] = wait
        out[f"depth{depth}_wall_s"] = t.dt
        out[f"depth{depth}_served"] = rep.total_served
        emit(f"transport_pipeline_depth{depth}_producer_wait", wait, "s",
             f"{steps} steps, consumer {consumer_sleep * 1e3:.0f}ms/step")
    return out


def main(smoke: bool = False) -> Dict[str, Any]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CI smoke runs")
    ap.add_argument("--mib", type=float, default=None,
                    help="payload MiB per step for the fan-out benchmark")
    args, _ = ap.parse_known_args([]) if smoke else ap.parse_known_args()
    smoke = smoke or args.smoke

    if smoke:
        mib, steps, spill_mib = 4.0, 2, 4.0
    else:
        mib, steps, spill_mib = (args.mib or 100.0), 3, 64.0

    results = {
        "config": {"smoke": smoke, "fanout_mib_per_step": mib,
                   "fanout_steps": steps, "spill_mib": spill_mib},
        "fanout": bench_fanout(mib, steps, consumers=4),
        "spill": bench_spill(spill_mib),
        "pipeline": bench_pipeline(steps=6, consumer_sleep=0.02),
    }
    write_json("transport", results)
    return results


if __name__ == "__main__":
    main()
