from .sharding import (
    ShardingRules,
    DEFAULT_RULES,
    SERVE_RULES,
    use_mesh,
    current_mesh,
    constrain,
    logical_to_spec,
    mesh_sharding,
    tree_shardings,
)

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "SERVE_RULES",
    "use_mesh",
    "current_mesh",
    "constrain",
    "logical_to_spec",
    "mesh_sharding",
    "tree_shardings",
]
