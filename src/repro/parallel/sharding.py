"""Logical-axis sharding: one model codebase, any mesh.

Model code annotates activations/params with *logical* axis names
("batch", "fsdp", "tensor", "expert", "seq").  A ``ShardingRules`` table maps
logical names to mesh axis names; ``use_mesh`` installs a mesh + rules
ambiently so the same model code runs unsharded on 1 CPU device and fully
sharded on the (2, 16, 16) production mesh.

Baseline rules (paper-faithful data/tensor layout):
    batch  -> (pod, data)     fsdp   -> (pod, data)
    tensor -> model           expert -> model        seq -> unsharded
The §Perf hillclimb swaps rule tables (e.g. sequence-parallel maps
seq -> model), never model code.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "SERVE_RULES",
    "use_mesh",
    "current_mesh",
    "current_rules",
    "constrain",
    "logical_to_spec",
    "mesh_sharding",
    "tree_shardings",
]

MeshAxes = Union[None, str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingRules:
    rules: Tuple[Tuple[str, MeshAxes], ...] = (
        ("batch", ("pod", "data")),
        ("fsdp", ("pod", "data")),
        ("tensor", "model"),
        ("expert", "model"),
        ("seq", None),
        ("kv", None),
        ("kvseq", None),
    )
    # FSDP weight gathering: when True, model code re-constrains each weight
    # to be *replicated over the fsdp axes* right before use, so GSPMD
    # all-gathers the (small) weight instead of all-reducing the (huge)
    # partial-sum activations it otherwise produces by contracting over the
    # fsdp-sharded dim.  Off in the paper-faithful baseline; the §Perf
    # hillclimb turns it on.
    weight_gather: bool = False

    def lookup(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        for k, v in self.rules:
            if k == logical:
                return v
        return None

    def with_(self, weight_gather: Optional[bool] = None,
              **kw: MeshAxes) -> "ShardingRules":
        d = dict(self.rules)
        d.update(kw)
        wg = self.weight_gather if weight_gather is None else weight_gather
        return ShardingRules(tuple(d.items()), weight_gather=wg)


DEFAULT_RULES = ShardingRules()

# Serving layout: KV caches are *sequence*-sharded over the model axis
# (context parallelism) -- kv-head counts (8) don't divide the 16-way model
# axis, cache length always does.  Weights keep the fsdp x tensor layout.
SERVE_RULES = DEFAULT_RULES.with_(kvseq="model")

# Named rule tables for the §Perf hillclimb.  Model code never changes --
# each variant is one swap of the logical->mesh mapping (+ weight gathering).
RULE_VARIANTS: Dict[str, ShardingRules] = {
    # paper-faithful baselines
    "baseline": DEFAULT_RULES,
    "serve_baseline": SERVE_RULES,
    # FSDP weight gathering: all-gather weights instead of all-reducing
    # partial-sum activations when contracting over the fsdp-sharded dim
    "wg": DEFAULT_RULES.with_(weight_gather=True),
    "serve_wg": SERVE_RULES.with_(weight_gather=True),
    # + sequence parallelism: residual-stream activations sharded over the
    # model axis between TP regions (all-reduce -> reduce-scatter+all-gather)
    "sp": DEFAULT_RULES.with_(weight_gather=True, seq="model"),
    # pure data parallelism over all 256/512 chips (small models): no tensor
    # axis -> the per-layer TP activation all-reduces disappear entirely;
    # params stay fully sharded (ZeRO-3) and are gathered per use
    "dp": DEFAULT_RULES.with_(
        weight_gather=True,
        batch=("pod", "data", "model"),
        fsdp=("pod", "data", "model"),
        tensor=None, expert=None),
    # decode with weights replicated over the data axis (no per-token weight
    # all-gather; TP only) -- the standard inference layout when they fit
    "serve_repl": SERVE_RULES.with_(fsdp=None),
    # MoE expert parallelism: experts sharded over the model axis, the expert
    # FFN dim over data (so no contraction dim of the expert matmuls is
    # sharded), dense/attention weights TP'd over data.  Tokens move to
    # experts (all-to-all-sized traffic) instead of expert weights moving to
    # tokens -- the Megatron-MoE layout.
    "moe_ep": DEFAULT_RULES.with_(
        weight_gather=True, fsdp=None, tensor="data", expert="model"),
    # same layout, but the dispatch itself runs through the explicit
    # shard_map schedule (models/moe_a2a.py) instead of einsum+GSPMD
    "moe_a2a": DEFAULT_RULES.with_(
        weight_gather=True, fsdp=None, tensor="data", expert="model"),
}

_state = threading.local()


def _ctx() -> Tuple[Optional[Mesh], ShardingRules]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", DEFAULT_RULES)


@contextmanager
def use_mesh(mesh: Optional[Mesh], rules: ShardingRules = DEFAULT_RULES):
    """Install mesh+rules ambiently (and as the JAX mesh context)."""
    prev = _ctx()
    _state.mesh, _state.rules = mesh, rules
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _state.mesh, _state.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _ctx()[0]


def current_rules() -> Optional[ShardingRules]:
    mesh, rules = _ctx()
    return rules if mesh is not None else None


def _filter_axes(mesh: Mesh, axes: MeshAxes) -> MeshAxes:
    """Drop mesh-axis names not present in this mesh (pod on single-pod)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.axis_names else None
    kept = tuple(a for a in axes if a in mesh.axis_names)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def logical_to_spec(logical_axes: Sequence[Optional[str]],
                    mesh: Optional[Mesh] = None,
                    rules: Optional[ShardingRules] = None) -> P:
    m, r = _ctx()
    mesh = mesh or m
    rules = rules or r
    parts = []
    used: set = set()
    for ax in logical_axes:
        mapped = rules.lookup(ax)
        if mesh is not None:
            mapped = _filter_axes(mesh, mapped)
        # an axis name may appear only once in a PartitionSpec
        if mapped is not None:
            flat = (mapped,) if isinstance(mapped, str) else mapped
            if any(a in used for a in flat):
                mapped = None
            else:
                used.update(flat)
        parts.append(mapped)
    return P(*parts)


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint under the ambient mesh; no-op without one."""
    mesh, rules = _ctx()
    if mesh is None:
        return x
    spec = logical_to_spec(logical_axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def weight(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """Weight access point: under ``weight_gather`` rules, re-constrain the
    weight to be replicated over its fsdp axes (GSPMD inserts a weight
    all-gather; grads come back as reduce-scatter) -- proper FSDP semantics.
    Otherwise identity."""
    mesh, rules = _ctx()
    if mesh is None or not rules.weight_gather:
        return x
    axes = tuple(None if a == "fsdp" else a for a in logical_axes)
    return constrain(x, axes)


def mesh_sharding(mesh: Mesh, logical_axes: Sequence[Optional[str]],
                  rules: ShardingRules = DEFAULT_RULES) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, mesh, rules))


def tree_shardings(mesh: Mesh, logical_tree: Any,
                   rules: ShardingRules = DEFAULT_RULES) -> Any:
    """Map a pytree of logical-axis tuples to NamedShardings.

    Leaves are tuples of logical axis names (or None).  A leaf that is a
    tuple-of-strings/None is treated as the spec for one array.
    """

    def is_leaf(x):
        return x is None or (
            isinstance(x, tuple)
            and all(e is None or isinstance(e, str) for e in x)
        )

    def conv(leaf):
        if leaf is None:
            return NamedSharding(mesh, P())
        return mesh_sharding(mesh, leaf, rules)

    return jax.tree.map(conv, logical_tree, is_leaf=is_leaf)
