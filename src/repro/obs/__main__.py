"""CLI: offline analysis of an exported trace.

    python -m repro.obs report trace.json [--json]

Loads a Perfetto ``trace.json`` written by ``export_trace`` (round-trips
the recorder coordinates stashed in event args), runs the critical-path
analyzer, and prints the attribution tables -- or the raw report as JSON
with ``--json``.
"""

from __future__ import annotations

import argparse
import json
import sys

from .critical import attribute, format_report
from .export import load_trace
from .recorder import span_categories


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="repro.obs")
    sub = p.add_subparsers(dest="cmd", required=True)
    rep = sub.add_parser("report", help="critical-path report from a trace")
    rep.add_argument("trace", help="trace.json written by export_trace")
    rep.add_argument("--json", action="store_true", dest="as_json",
                     help="emit the raw attribution report as JSON")
    ns = p.parse_args(argv)

    spans = load_trace(ns.trace)
    if not spans:
        print(f"{ns.trace}: no spans", file=sys.stderr)
        return 1
    report = attribute(spans)
    if ns.as_json:
        json.dump(report, sys.stdout, indent=2)
        print()
    else:
        cats = span_categories(spans)
        print(f"{ns.trace}: {len(spans)} spans across layers "
              f"{', '.join(cats)}")
        print(format_report(report))
    return 0


if __name__ == "__main__":
    sys.exit(main())
