"""Critical-path analysis over recorded spans.

Attributes each task instance's wall-clock window -- and each step's
latency on the slowest ("critical") instance -- to WHERE the time went:

``block``      rendezvous waits (``channel.offer`` / ``channel.get`` block
               intervals, ``vol.open`` mux waits)
``prep``       prefetch preparation the consumer actually blocked on
``reshard``    pack/numpy redistribute executes
``checkpoint`` checkpoint save/restore
``recovery``   restart surgery + replay
``rescale``    rescale surgery stages
``compute``    everything else (the remainder)

The algorithm is precedence subtraction, not DAG search: for one instance,
take its window ``[min t0, max t1]``, then claim intervals category by
category in the precedence order above, subtracting what earlier
categories already claimed (a reshard running inside a blocked ``get`` is
charged to ``block`` once, never twice).  ``compute`` is the unclaimed
remainder, so per-instance attribution sums to the window EXACTLY by
construction -- the 5% acceptance tolerance only absorbs clock jitter
between the window edges and the step boundaries.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

__all__ = ["attribute", "critical_path", "per_edge", "format_report"]

#: claim precedence (outer blocking states absorb nested work)
PRECEDENCE = ("block", "prep", "reshard", "checkpoint", "recovery", "rescale")

#: span category -> attribution bucket
_BUCKET = {"channel": "block", "vol": "block", "prefetch": "prep",
           "reshard": "reshard", "checkpoint": "checkpoint",
           "recovery": "recovery", "rescale": "rescale"}


def _bucket_of(s: Dict[str, Any]) -> Optional[str]:
    """Attribution bucket for one span; lifecycle spans (e.g. ``vol.close``,
    which *contains* serve work and nested rendezvous waits) claim nothing
    themselves -- their blocking portion arrives via the nested spans."""
    if s["cat"] == "vol" and not s["name"].endswith(".wait"):
        return None
    return _BUCKET.get(s["cat"])

Interval = Tuple[float, float]


def _merge(ivs: List[Interval]) -> List[Interval]:
    if not ivs:
        return []
    ivs = sorted(ivs)
    out = [ivs[0]]
    for a, b in ivs[1:]:
        la, lb = out[-1]
        if a <= lb:
            out[-1] = (la, max(lb, b))
        else:
            out.append((a, b))
    return out


def _subtract(iv: Interval, claimed: List[Interval]) -> List[Interval]:
    """Parts of ``iv`` not covered by the merged, sorted ``claimed``."""
    a, b = iv
    out: List[Interval] = []
    for ca, cb in claimed:
        if cb <= a:
            continue
        if ca >= b:
            break
        if ca > a:
            out.append((a, ca))
        a = max(a, cb)
        if a >= b:
            break
    if a < b:
        out.append((a, b))
    return out


def _total(ivs: List[Interval]) -> float:
    return sum(b - a for a, b in ivs)


def _claim(spans: List[Dict[str, Any]], window: Interval) -> Dict[str, float]:
    """Precedence-subtraction attribution of one window."""
    by_bucket: Dict[str, List[Interval]] = {}
    wa, wb = window
    for s in spans:
        if s["ph"] != "X":
            continue
        bucket = _bucket_of(s)
        if bucket is None:
            continue
        a, b = max(s["t0"], wa), min(s["t1"], wb)
        if b > a:
            by_bucket.setdefault(bucket, []).append((a, b))
    claimed: List[Interval] = []
    out = {b: 0.0 for b in PRECEDENCE}
    for bucket in PRECEDENCE:
        fresh: List[Interval] = []
        for iv in _merge(by_bucket.get(bucket, [])):
            fresh.extend(_subtract(iv, claimed))
        out[bucket] = _total(fresh)
        claimed = _merge(claimed + fresh)
    out["compute"] = max(0.0, (wb - wa) - _total(claimed))
    return out


def _by_instance(spans: List[Dict[str, Any]]
                 ) -> Dict[Tuple[str, int], List[Dict[str, Any]]]:
    out: Dict[Tuple[str, int], List[Dict[str, Any]]] = {}
    for s in spans:
        if s["cat"] in ("counter", "timeline") or s["task"] in (
                "counters", "pool"):
            continue
        out.setdefault((s["task"], s["instance"]), []).append(s)
    return out


def attribute(spans: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Full attribution report (plain dict, JSON-serializable).

    ``instances``: per (task, instance) window + bucket seconds (summing to
    the window exactly); ``steps``: the same restricted to each step's
    interval on the critical instance; ``edges``: per-edge block/prep/bytes
    rollup; ``critical``: the instance whose window is longest.
    """
    groups = _by_instance(spans)
    instances: Dict[str, Any] = {}
    for (task, inst), group in sorted(groups.items()):
        xs = [s for s in group if s["ph"] == "X"]
        if not xs:
            continue
        window = (min(s["t0"] for s in xs), max(s["t1"] for s in xs))
        att = _claim(group, window)
        instances[f"{task}[{inst}]"] = {
            "task": task, "instance": inst,
            "window_s": window[1] - window[0], **att}
    critical = max(instances, key=lambda k: instances[k]["window_s"],
                   default=None)
    steps: Dict[str, Any] = {}
    if critical is not None:
        task = instances[critical]["task"]
        inst = instances[critical]["instance"]
        group = groups[(task, inst)]
        by_step: Dict[int, List[Interval]] = {}
        for s in group:
            if s["ph"] == "X" and s["step"] is not None:
                by_step.setdefault(int(s["step"]), []).append(
                    (s["t0"], s["t1"]))
        bounds = sorted((step, min(a for a, _ in ivs), max(b for _, b in ivs))
                        for step, ivs in by_step.items())
        for i, (step, a, b) in enumerate(bounds):
            # a step lasts until the next step's first span begins
            end = bounds[i + 1][1] if i + 1 < len(bounds) else b
            end = max(end, b)
            att = _claim(group, (a, end))
            steps[str(step)] = {"latency_s": end - a, **att}
    return {"instances": instances, "steps": steps,
            "edges": per_edge(spans), "critical": critical}


def critical_path(spans: List[Dict[str, Any]]) -> Optional[str]:
    """``"task[instance]"`` with the longest span window, or ``None``."""
    return attribute(spans)["critical"]


def per_edge(spans: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Per-edge rollup of hand-off costs: producer/consumer blocked time,
    prep time blocked on, bytes moved, plan-cache hits/misses seen."""
    out: Dict[str, Dict[str, float]] = {}
    for s in spans:
        if s["ph"] != "X":
            continue
        args = s["args"] or {}
        edge = args.get("edge")
        if edge is None:
            continue
        row = out.setdefault(edge, {"blocked_s": 0.0, "prep_s": 0.0,
                                    "bytes": 0, "hits": 0, "misses": 0})
        dt = s["t1"] - s["t0"]
        bucket = _bucket_of(s)
        if bucket == "prep" and s["name"].endswith(".prep"):
            row["prep_s"] += dt        # pool-side preparation work
        elif bucket in ("block", "prep"):
            row["blocked_s"] += dt     # consumer/producer blocked on the edge
        if "bytes" in args:
            row["bytes"] += int(args["bytes"])
        if args.get("cache") == "hit":
            row["hits"] += 1
        elif args.get("cache") == "miss":
            row["misses"] += 1
    return out


def format_report(report: Dict[str, Any]) -> str:
    """Human-readable attribution tables for summary() / the CLI."""
    lines: List[str] = []
    cols = PRECEDENCE + ("compute",)
    if report["instances"]:
        lines.append("critical-path attribution (s):")
        head = f"  {'instance':<22}" + "".join(f"{c:>11}" for c in
                                               ("window",) + cols)
        lines.append(head)
        for key, row in report["instances"].items():
            mark = " *" if key == report["critical"] else ""
            lines.append(
                f"  {key + mark:<22}" + f"{row['window_s']:>11.4f}"
                + "".join(f"{row[c]:>11.4f}" for c in cols))
    if report["steps"]:
        lines.append(f"per-step attribution on {report['critical']} (s):")
        lines.append(f"  {'step':<22}" + "".join(
            f"{c:>11}" for c in ("latency",) + cols))
        for step, row in report["steps"].items():
            lines.append(
                f"  {step:<22}" + f"{row['latency_s']:>11.4f}"
                + "".join(f"{row[c]:>11.4f}" for c in cols))
    if report["edges"]:
        lines.append("per-edge hand-off costs:")
        lines.append(f"  {'edge':<22}{'blocked_s':>11}{'prep_s':>11}"
                     f"{'MiB':>9}{'hit':>5}{'miss':>6}")
        for edge, row in sorted(report["edges"].items()):
            lines.append(
                f"  {edge:<22}{row['blocked_s']:>11.4f}{row['prep_s']:>11.4f}"
                f"{row['bytes'] / 2**20:>9.2f}{row['hits']:>5d}"
                f"{row['misses']:>6d}")
    return "\n".join(lines)
