"""repro.obs -- run-wide span tracing, Perfetto export, critical-path
analysis, and the failure flight recorder.

Opt in per run (``tracing: {...}`` in the workflow YAML or
``Wilkins.run(trace=...)``); when off, no recorder exists and every hook
site is a single ``None`` test.  See DESIGN.md "Observability & tracing".
"""

from .recorder import (CATEGORIES, SpanRecorder, TraceConfig, created_count,
                       flow_id, span_categories)
from .export import export_trace, load_trace, merge_timeline, to_chrome
from .critical import attribute, critical_path, format_report, per_edge

__all__ = [
    "CATEGORIES", "SpanRecorder", "TraceConfig", "created_count", "flow_id",
    "span_categories", "export_trace", "load_trace", "merge_timeline",
    "to_chrome", "attribute", "critical_path", "format_report", "per_edge",
]
