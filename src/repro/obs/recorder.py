"""Run-wide span tracing: the lock-sharded ``SpanRecorder`` and its YAML
config (``tracing: {...}`` / ``Wilkins.run(trace=...)``).

Zero-cost-when-off contract: the recorder follows the driver-attachment
pattern of the scheduler/supervisor -- every instrumented layer holds a
nullable ``tracer`` reference that defaults to ``None`` and is wired only
when the run opted in.  An untraced run performs ONE attribute load + None
test per hook site and allocates nothing (the zero-cost test counts
``SpanRecorder`` constructions process-wide).

Lock discipline: every shard lock comes from ``make_lock`` at the ``leaf``
rank (50, innermost), so ``record()`` may be called while holding any core
lock -- ``vol.serve`` (10), ``supervisor`` (20), ``channel.cv`` (30) --
without a rank inversion, and the lockcheck/explore harnesses stay sound.
A shard holder never takes another lock, so no cycle is possible either.

Span model (flat dicts, no open-span handles): every ``record()`` call is
final -- instrumented sites time their interval locally and report it
closed, with an ``aborted`` arg when the interval ended in an interrupt /
poison / crash instead of a delivery.  There is nothing to leak across a
restart or rescale; the span-lifecycle test asserts exactly that.

The **flight recorder** is a bounded per-shard ring of the most recent
spans; ``mark_failure(reason)`` snapshots the merged ring into
``failure_dumps`` so every failure path (task failure, restart exhaustion,
stall declaration, join timeout) ships the last N spans of what every
instance was doing, alongside the chained error.
"""

from __future__ import annotations

import threading
import time
import zlib
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

from ..analysis.lockcheck import make_lock

__all__ = ["TraceConfig", "SpanRecorder", "flow_id", "span_categories"]

#: span taxonomy -- one category per instrumented layer (DESIGN.md
#: "Observability & tracing" documents the member spans of each)
CATEGORIES = ("vol", "channel", "prefetch", "reshard", "checkpoint",
              "recovery", "rescale", "task", "counter", "timeline")

# process-wide construction counter: the zero-cost test asserts an untraced
# run leaves it unchanged (no recorder, hence no spans, was ever allocated)
_created_lock = make_lock("leaf:obs_created")
_CREATED = 0


def created_count() -> int:
    with _created_lock:
        return _CREATED


def flow_id(channel_name: str, seq: int) -> int:
    """Deterministic flow-arrow id for one (edge, seq) hand-off: the
    producer's ``offer`` span and the consumer's ``get`` span compute the
    same id independently, matching the ``hb_publish``/``hb_consume``
    happens-before identity ``("chan", id(ch), seq)`` used by the explorer
    (but stable across processes, so exported traces keep their arrows)."""
    return ((zlib.crc32(channel_name.encode()) & 0x7FFFFFFF) << 24) | (
        seq & 0xFFFFFF)


class TraceConfig:
    """Parsed ``tracing:`` block (or the ``Wilkins.run(trace=...)`` value).

    Accepted YAML spellings::

        tracing: true                      # defaults
        tracing: {path: trace.json}        # auto-export on run end
        tracing:
          path: trace.json
          flight_len: 256                  # failure-ring length (spans)
          max_spans: 200000                # retained-span cap (ring keeps
                                           # the newest past it)
          shards: 8                        # recorder lock shards (pow. of 2)
    """

    KEYS = ("path", "flight_len", "max_spans", "shards")

    def __init__(self, path: Optional[str] = None, flight_len: int = 256,
                 max_spans: int = 200_000, shards: int = 8,
                 explicit: bool = False):
        if flight_len < 1:
            raise ValueError(f"tracing flight_len must be >= 1, got {flight_len}")
        if max_spans < 1:
            raise ValueError(f"tracing max_spans must be >= 1, got {max_spans}")
        if shards < 1 or (shards & (shards - 1)) != 0:
            raise ValueError(
                f"tracing shards must be a power of two >= 1, got {shards}")
        self.path = path
        self.flight_len = int(flight_len)
        self.max_spans = int(max_spans)
        self.shards = int(shards)
        self.explicit = explicit

    @classmethod
    def from_yaml(cls, doc: Any) -> Optional["TraceConfig"]:
        """``None`` when the workflow declared no ``tracing:`` block (the
        zero-cost default); otherwise a validated config with unknown keys
        rejected by name (same contract as ``SchedulerConfig.from_yaml``)."""
        if doc is None:
            return None
        if doc is True:
            return cls(explicit=True)
        if doc is False:
            return None
        if not isinstance(doc, dict):
            raise ValueError(
                f"tracing: must be a boolean or a mapping "
                f"{{{', '.join(cls.KEYS)}}}, got {doc!r}")
        unknown = set(doc) - set(cls.KEYS)
        if unknown:
            raise ValueError(
                f"unknown tracing keys {sorted(unknown)} "
                f"(expected {', '.join(cls.KEYS)})")
        return cls(path=doc.get("path"),
                   flight_len=int(doc.get("flight_len", 256)),
                   max_spans=int(doc.get("max_spans", 200_000)),
                   shards=int(doc.get("shards", 8)),
                   explicit=True)

    @classmethod
    def coerce(cls, trace: Any) -> Optional["TraceConfig"]:
        """Normalize the ``Wilkins.run(trace=...)`` argument: ``None``/False
        -> off, ``True`` -> defaults, a path string -> auto-export there, a
        dict -> the YAML spelling, a ``TraceConfig`` -> itself."""
        if trace is None or trace is False:
            return None
        if isinstance(trace, cls):
            return trace
        if trace is True:
            return cls(explicit=True)
        if isinstance(trace, str):
            return cls(path=trace, explicit=True)
        if isinstance(trace, dict):
            return cls.from_yaml(trace)
        raise ValueError(
            f"trace= must be None/bool/path/dict/TraceConfig, got {trace!r}")


class _Shard:
    __slots__ = ("lock", "spans", "ring", "dropped")

    def __init__(self, index: int, flight_len: int):
        self.lock = make_lock(f"leaf:obs[{index}]")
        self.spans: List[Dict[str, Any]] = []
        self.ring: deque = deque(maxlen=flight_len)
        self.dropped = 0


class SpanRecorder:
    """Thread-safe span sink, sharded by recording thread.

    ``record`` (closed interval), ``instant`` (point event) and ``counter``
    (gauge sample) all append one flat dict; shard choice is
    ``thread_ident & (nshards - 1)`` so concurrent task threads almost never
    contend on one lock.  ``spans()`` merges the shards sorted by start
    time; ``flight()`` merges the bounded recent-history rings.
    """

    def __init__(self, config: Optional[TraceConfig] = None):
        global _CREATED
        self.config = config or TraceConfig()
        n = self.config.shards
        self._mask = n - 1
        self._shards = [_Shard(i, self.config.flight_len) for i in range(n)]
        self._per_shard_cap = max(self.config.flight_len,
                                  self.config.max_spans // n)
        self.failure_dumps: List[Dict[str, Any]] = []
        self._dump_lock = make_lock("leaf:obs_dumps")
        self.t_origin = time.monotonic()
        with _created_lock:
            _CREATED += 1

    # ------------------------------------------------------------- recording
    def record(self, cat: str, name: str, task: str, instance: int,
               t0: float, t1: float, step: Optional[int] = None,
               flow: Optional[Tuple[str, int]] = None, **args: Any) -> None:
        """One closed duration span (Perfetto "X").  ``flow`` is
        ``("s", id)`` on the producing side of a hand-off and ``("f", id)``
        on the consuming side; the exporter turns the pair into an arrow."""
        self._push({"ph": "X", "cat": cat, "name": name, "task": task,
                    "instance": instance, "t0": t0, "t1": t1, "step": step,
                    "flow": flow, "args": args or None})

    def instant(self, cat: str, name: str, task: str, instance: int,
                t: Optional[float] = None, **args: Any) -> None:
        """One point event (Perfetto "i")."""
        if t is None:
            t = time.monotonic()
        self._push({"ph": "i", "cat": cat, "name": name, "task": task,
                    "instance": instance, "t0": t, "t1": t, "step": None,
                    "flow": None, "args": args or None})

    def counter(self, name: str, value: float, t: Optional[float] = None,
                task: str = "counters", instance: int = 0) -> None:
        """One gauge sample on counter track ``name`` (Perfetto "C")."""
        if t is None:
            t = time.monotonic()
        self._push({"ph": "C", "cat": "counter", "name": name, "task": task,
                    "instance": instance, "t0": t, "t1": t, "step": None,
                    "flow": None, "args": {"value": value}})

    def _push(self, span: Dict[str, Any]) -> None:
        sh = self._shards[threading.get_ident() & self._mask]
        with sh.lock:
            if len(sh.spans) < self._per_shard_cap:
                sh.spans.append(span)
            else:
                sh.dropped += 1
            sh.ring.append(span)

    # -------------------------------------------------------- flight recorder
    def flight(self) -> List[Dict[str, Any]]:
        """The most recent spans across all shards (bounded, end-time
        ordered) -- what every instance was doing just now."""
        out: List[Dict[str, Any]] = []
        for sh in self._shards:
            with sh.lock:
                out.extend(sh.ring)
        out.sort(key=lambda s: s["t1"])
        return out[-self.config.flight_len:]

    def mark_failure(self, reason: str, task: str = "?",
                     instance: int = -1) -> Dict[str, Any]:
        """Snapshot the flight ring for a failure path.  Bounded: only the
        first 8 dumps of a run are kept (a cascading failure re-dumps the
        same recent history anyway)."""
        dump = {"t": time.monotonic(), "reason": reason, "task": task,
                "instance": instance, "spans": self.flight()}
        with self._dump_lock:
            if len(self.failure_dumps) < 8:
                self.failure_dumps.append(dump)
        self.instant("recovery", "flight.dump", task, instance,
                     reason=reason)
        return dump

    def dumps(self) -> List[Dict[str, Any]]:
        with self._dump_lock:
            return list(self.failure_dumps)

    # ------------------------------------------------------------- snapshots
    def spans(self) -> List[Dict[str, Any]]:
        """Every retained span, merged across shards, start-time ordered."""
        out: List[Dict[str, Any]] = []
        for sh in self._shards:
            with sh.lock:
                out.extend(sh.spans)
        out.sort(key=lambda s: (s["t0"], s["t1"]))
        return out

    @property
    def dropped(self) -> int:
        return sum(sh.dropped for sh in self._shards)

    def __len__(self) -> int:
        n = 0
        for sh in self._shards:
            with sh.lock:
                n += len(sh.spans)
        return n

    def __repr__(self) -> str:
        return (f"<SpanRecorder spans={len(self)} dropped={self.dropped} "
                f"dumps={len(self.failure_dumps)}>")


def span_categories(spans: List[Dict[str, Any]]) -> List[str]:
    """Distinct non-synthetic categories present (layer-coverage checks)."""
    return sorted({s["cat"] for s in spans
                   if s["cat"] not in ("counter", "timeline")})
