"""Chrome/Perfetto trace export (Trace Event JSON) and its round-trip loader.

One ``trace.json`` artifact per run, loadable in https://ui.perfetto.dev or
chrome://tracing:

* one **track per task instance** (pid = task, tid = instance, named via
  ``M`` metadata events); prefetch-pool preps get their own ``pool``
  process so overlapping worker spans never stack onto a task's track;
* **flow arrows** from a producer's ``channel.offer`` span to the
  consumer's ``channel.get``/``vol.open`` span for the same (edge, seq)
  hand-off (``ph: s``/``f`` pairs keyed by :func:`..recorder.flow_id`);
* **counter tracks** for queue depth / in-flight preps / cumulative bytes
  (sampled by the channel hooks and, when a ``TelemetryTimeline`` is
  merged, by the scheduler's per-tick rows);
* ``TelemetryTimeline`` lifecycle events (restart / drop / rescale /
  stall) merged as **instant events** on the affected task's track -- one
  unified timeline artifact instead of two half-views.

``load_trace`` inverts ``to_chrome`` back into recorder-style span dicts
(category, task, instance, monotonic seconds), which is what the critical
-path analyzer and the ``python -m repro.obs report`` CLI consume -- the
exported file IS the offline analysis input, there is no second format.
"""

from __future__ import annotations

import json
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = ["to_chrome", "export_trace", "load_trace", "merge_timeline"]

#: timeline event kinds that carry a task coordinate and become instants
_TIMELINE_INSTANTS = ("restart", "drop", "rescale", "stall")


def merge_timeline(timeline: Any) -> List[Dict[str, Any]]:
    """Convert a ``TelemetryTimeline`` into recorder-style span dicts:
    lifecycle events -> ``ph: i`` on the task's track, sampled per-edge
    rows -> ``ph: C`` counter samples (queue depth + in-flight preps)."""
    out: List[Dict[str, Any]] = []
    if timeline is None:
        return out
    for ev in timeline.events():
        kind = ev.get("kind")
        if kind not in _TIMELINE_INSTANTS:
            continue
        args = {k: v for k, v in ev.items() if k not in ("t", "kind")}
        out.append({"ph": "i", "cat": "timeline", "name": f"timeline.{kind}",
                    "task": str(ev.get("task", "?")),
                    "instance": int(ev.get("instance", 0)),
                    "t0": ev["t"], "t1": ev["t"], "step": None,
                    "flow": None, "args": args or None})
    for row in timeline.samples():
        edge = row.get("edge", "?")
        t = row["t"]
        for field, track in (("queue_len", "qdepth"),
                             ("inflight", "inflight")):
            if field in row:
                out.append({"ph": "C", "cat": "counter",
                            "name": f"{track}:{edge}", "task": "counters",
                            "instance": 0, "t0": t, "t1": t, "step": None,
                            "flow": None, "args": {"value": row[field]}})
    return out


def _tracks(spans: Iterable[Dict[str, Any]]) -> Dict[str, int]:
    """Stable pid assignment: one process per task name, sorted."""
    tasks = sorted({s["task"] for s in spans})
    return {task: i + 1 for i, task in enumerate(tasks)}


def to_chrome(spans: List[Dict[str, Any]],
              timeline: Any = None) -> Dict[str, Any]:
    """Recorder span dicts -> a Chrome Trace Event JSON document."""
    spans = list(spans) + merge_timeline(timeline)
    if not spans:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    t_origin = min(s["t0"] for s in spans)
    pids = _tracks(spans)
    events: List[Dict[str, Any]] = []
    for task, pid in pids.items():
        events.append({"ph": "M", "name": "process_name", "pid": pid,
                       "tid": 0, "args": {"name": task}})
    seen_tids = set()

    def us(t: float) -> float:
        return round((t - t_origin) * 1e6, 3)

    for s in spans:
        pid = pids[s["task"]]
        tid = int(s["instance"]) + 1
        if (pid, tid) not in seen_tids and s["ph"] != "C":
            seen_tids.add((pid, tid))
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid,
                           "args": {"name": f"{s['task']}[{s['instance']}]"}})
        args = dict(s["args"] or {})
        if s["step"] is not None:
            args["step"] = s["step"]
        # recorder coordinates ride along so load_trace can invert exactly
        args["_cat"] = s["cat"]
        args["_task"] = s["task"]
        args["_instance"] = s["instance"]
        if s["ph"] == "X":
            events.append({"ph": "X", "name": s["name"], "cat": s["cat"],
                           "pid": pid, "tid": tid, "ts": us(s["t0"]),
                           "dur": round((s["t1"] - s["t0"]) * 1e6, 3),
                           "args": args})
            flow = s.get("flow")
            if flow is not None:
                role, fid = flow
                ev = {"ph": role, "name": "handoff", "cat": "flow",
                      "id": int(fid), "pid": pid, "tid": tid,
                      "ts": us(s["t1"] if role == "s" else s["t0"])}
                if role == "f":
                    ev["bp"] = "e"  # bind to the enclosing slice
                events.append(ev)
        elif s["ph"] == "i":
            events.append({"ph": "i", "name": s["name"], "cat": s["cat"],
                           "pid": pid, "tid": tid, "ts": us(s["t0"]),
                           "s": "t", "args": args})
        elif s["ph"] == "C":
            events.append({"ph": "C", "name": s["name"], "pid": pid,
                           "tid": 0, "ts": us(s["t0"]),
                           "args": {"value": s["args"]["value"]}})
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"t_origin_monotonic": t_origin,
                          "exporter": "repro.obs"}}


def export_trace(path: str, recorder: Any, timeline: Any = None) -> str:
    """Write one unified ``trace.json`` (spans + merged telemetry)."""
    spans = recorder.spans() if hasattr(recorder, "spans") else list(recorder)
    doc = to_chrome(spans, timeline=timeline)
    with open(path, "w") as f:
        json.dump(doc, f)
        f.write("\n")
    return path


def load_trace(path: str) -> List[Dict[str, Any]]:
    """Invert an exported ``trace.json`` back into recorder-style span
    dicts (times relative to the export origin, in seconds)."""
    with open(path) as f:
        doc = json.load(f)
    t_origin = float(doc.get("otherData", {}).get("t_origin_monotonic", 0.0))
    flows: Dict[Tuple[int, int, float], Tuple[str, int]] = {}
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") in ("s", "f"):
            flows[(ev["pid"], ev["tid"], ev["ts"])] = (ev["ph"], ev["id"])
    out: List[Dict[str, Any]] = []
    for ev in doc.get("traceEvents", []):
        ph = ev.get("ph")
        if ph not in ("X", "i", "C"):
            continue
        args = dict(ev.get("args") or {})
        if ph == "C":
            name = ev["name"]
            t = t_origin + ev["ts"] / 1e6
            out.append({"ph": "C", "cat": "counter", "name": name,
                        "task": "counters", "instance": 0, "t0": t, "t1": t,
                        "step": None, "flow": None,
                        "args": {"value": args.get("value")}})
            continue
        cat = args.pop("_cat", ev.get("cat", "?"))
        task = args.pop("_task", "?")
        instance = int(args.pop("_instance", ev.get("tid", 1) - 1))
        step = args.pop("step", None)
        t0 = t_origin + ev["ts"] / 1e6
        t1 = t0 + (ev.get("dur", 0.0) / 1e6 if ph == "X" else 0.0)
        flow: Optional[Tuple[str, int]] = None
        if ph == "X":
            for ts_key in (round((t1 - t_origin) * 1e6, 3),
                           round((t0 - t_origin) * 1e6, 3)):
                hit = flows.get((ev["pid"], ev["tid"], ts_key))
                if hit is not None:
                    flow = hit
                    break
        out.append({"ph": "X" if ph == "X" else "i", "cat": cat,
                    "name": ev["name"], "task": task, "instance": instance,
                    "t0": t0, "t1": t1, "step": step, "flow": flow,
                    "args": args or None})
    out.sort(key=lambda s: (s["t0"], s["t1"]))
    return out
