"""Batched serving engine: slot-based continuous batching over a static
KV-cache, greedy/temperature sampling, family-agnostic.

Serving steps (``prefill`` fills slot caches from a prompt; ``decode`` emits
one token for every live slot) are jitted once per shape.  Requests are
admitted into free slots as they arrive -- a decode step always runs the full
slot batch, finished slots are masked.  This is continuous batching in the
static-shape style TPUs require (no dynamic shapes; occupancy is a mask).

The engine is also a Wilkins *task*: ``examples/serve_inflight.py`` couples a
trainer producing checkpoints to this engine consuming them in situ (weight
hot-swap at file granularity, flow control ``latest`` -- the freshest weights
win, old checkpoints are dropped).
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import get_family

__all__ = ["Request", "ServeConfig", "Engine"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0     # 0 = greedy
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


@dataclass(frozen=True)
class ServeConfig:
    max_slots: int = 8
    max_len: int = 512
    cache_dtype: str = "bfloat16"


class Engine:
    def __init__(self, cfg, serve_cfg: ServeConfig, params=None, key=None):
        self.cfg = cfg
        self.scfg = serve_cfg
        self.fam = get_family(cfg)
        if params is None:
            params = self.fam.init(
                key if key is not None else jax.random.PRNGKey(0), cfg)
        self.params = params
        self._caches = [None] * serve_cfg.max_slots
        self._slot_req: List[Optional[Request]] = [None] * serve_cfg.max_slots
        self._queue: List[Request] = []
        self._decode_jit = jax.jit(
            lambda p, tok, cache: self.fam.decode_step(p, self.cfg, tok, cache))
        self._rng = np.random.default_rng(0)

    # ------------------------------------------------------------- weights
    def swap_params(self, params) -> None:
        """Hot-swap weights (in-situ checkpoint consumption)."""
        self.params = params

    # ------------------------------------------------------------- requests
    def submit(self, req: Request) -> None:
        req.t_submit = time.monotonic()
        self._queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.scfg.max_slots):
            if self._slot_req[slot] is None and self._queue:
                req = self._queue.pop(0)
                self._slot_req[slot] = req
                cache = self.fam.init_cache(
                    self.cfg, 1, self.scfg.max_len,
                    dtype=jnp.dtype(self.scfg.cache_dtype))
                batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
                if self.cfg.family == "vlm":
                    batch["vision_embeds"] = jnp.zeros(
                        (1, self.cfg.vision_tokens, self.cfg.d_model),
                        jnp.dtype(self.cfg.dtype))
                if self.cfg.family == "encdec":
                    batch["frames"] = jnp.zeros(
                        (1, self.cfg.source_len, self.cfg.d_model),
                        jnp.dtype(self.cfg.dtype))
                logits, cache = self.fam.prefill(self.params, self.cfg, batch, cache)
                tok = self._sample(logits[:, -1], req.temperature)
                req.out_tokens.append(int(tok[0]))
                req.t_first = time.monotonic()
                self._caches[slot] = (cache, tok)

    def _sample(self, logits: jnp.ndarray, temperature: float) -> np.ndarray:
        logits = np.asarray(logits, np.float32)
        if temperature <= 0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        z = logits / temperature
        z = z - z.max(axis=-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(axis=-1, keepdims=True)
        return np.array(
            [self._rng.choice(p.shape[-1], p=row) for row in p], np.int32)

    # ---------------------------------------------------------------- step
    def step(self) -> int:
        """Admit waiting requests, run one decode step for live slots.
        Returns the number of live slots."""
        self._admit()
        live = 0
        for slot, req in enumerate(self._slot_req):
            if req is None:
                continue
            cache, tok = self._caches[slot]
            logits, cache = self._decode_jit(
                self.params, tok.reshape(1, 1).astype(jnp.int32), cache)
            nxt = self._sample(np.asarray(logits)[:, -1], req.temperature)
            req.out_tokens.append(int(nxt[0]))
            self._caches[slot] = (cache, jnp.asarray(nxt))
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                req.t_done = time.monotonic()
                self._slot_req[slot] = None
                self._caches[slot] = None
            else:
                live += 1
        return live + sum(1 for r in self._queue)

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self._queue:
                return
        raise RuntimeError("serve loop did not drain")
