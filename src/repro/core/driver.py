"""Wilkins-master: the generic workflow driver (paper §3.3, §3.5).

The driver (i) reads the workflow YAML and builds the matched graph,
(ii) partitions global resources into restricted per-task worlds,
(iii) creates the channels for every matched edge x ensemble-instance pair
with the configured transport mode and flow control, (iv) installs a VOL
object per task instance and loads custom actions, and (v) launches the task
callables and runs them to completion -- relaunching stateless consumers while
matched producers still have data (the query protocol) and restarting failed
tasks up to a restart budget (fault tolerance).

Users never modify this code; everything is driven by the YAML plus optional
external action scripts -- exactly the paper's usability contract.

Execution model notes (hardware adaptation, see DESIGN.md): task instances run
as Python threads (Henson-style cooperative coroutines are used by the tests
for determinism where needed).  SPMD rank parallelism *within* a task is
carried by the data model (BlockOwnership on datasets + the M->N
redistribution planner) and by the task's restricted JAX device group, rather
than by OS processes.
"""

from __future__ import annotations

import os
import threading
import time
import traceback
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from ..analysis.lockcheck import make_lock
from ..obs.critical import attribute, format_report
from ..obs.export import export_trace
from ..obs.recorder import SpanRecorder, TraceConfig
from . import actions as actions_mod
from .channel import Channel, PrefetchPool
from .comm import TaskComm, pop_comm, push_comm
from .datamodel import transport_stats
from .graph import WorkflowGraph
from .recovery import (FailurePolicy, FaultPlan, RecoveryContext,
                       RescaleEvent, RescaleInterrupt, RunSupervisor,
                       StallEvent, SupersededError, TaskState)
from .redistribute import RedistSpec, plan_cache
from .scheduler import SchedulerRuntime, TelemetryTimeline
from .vol import VOL, pop_vol, push_vol

__all__ = ["Wilkins", "WorkflowReport", "TaskFailure"]

# Monotonic id per Wilkins instance: checkpoint roots are keyed by
# (driver, run) so two drivers sharing a spill_dir stay isolated.
_driver_seq_lock = make_lock("leaf:driver_seq")
_driver_seq = 0


def _next_driver_seq() -> int:
    global _driver_seq
    with _driver_seq_lock:
        _driver_seq += 1
        return _driver_seq


@dataclass
class TaskFailure:
    task: str
    instance: int
    attempt: int
    error: str


@dataclass
class WorkflowReport:
    wall_time_s: float = 0.0
    task_times: Dict[Tuple[str, int], float] = field(default_factory=dict)
    task_launches: Dict[Tuple[str, int], int] = field(default_factory=dict)
    channels: List[Channel] = field(default_factory=list)
    failures: List[TaskFailure] = field(default_factory=list)
    # end-of-run snapshots of the PROCESS-WIDE transport / plan-cache
    # counters (prefetch hit/miss + overlap seconds, redistribution bytes,
    # compiled-plan reuse) -- filled by ``Wilkins.run`` on success and on
    # both failure paths, so ``err.report.summary()`` shows them too
    transport: Dict[str, Any] = field(default_factory=dict)
    plan_cache: Dict[str, Any] = field(default_factory=dict)
    # runtime-scheduling snapshot (policy, step/tick counts, autotuner
    # decisions, final per-edge depths) and the telemetry timeline ring --
    # exportable as JSON via ``timeline.export(path)`` for offline replay
    scheduler: Dict[str, Any] = field(default_factory=dict)
    timeline: Optional[TelemetryTimeline] = None
    # recovery outcomes: one dict per RestartEvent (task/instance/attempt/
    # epoch/reason), the instances a `drop` policy degraded to no-ops, and
    # async prep errors nobody re-raised (drained from the prefetch pool at
    # teardown -- the shutdown-race audit; never silently dropped)
    restarts: List[Dict[str, Any]] = field(default_factory=list)
    dropped_tasks: List[Tuple[str, int]] = field(default_factory=list)
    prefetch_errors: List[Tuple[Optional[str], str]] = field(default_factory=list)
    # elastic rescale outcomes: one dict per RescaleEvent (old/new sizes,
    # trigger, consistent-cut step, end-to-end surgery latency) and one per
    # StallEvent the health watchdog declared (silent window vs timeout and
    # the action the policy took)
    rescales: List[Dict[str, Any]] = field(default_factory=list)
    stalls: List[Dict[str, Any]] = field(default_factory=list)
    # observability (repro.obs, traced runs only): the critical-path
    # attribution report (``obs.critical.attribute`` over this run's spans),
    # the flight-recorder failure dumps (most recent spans at each failure),
    # and where/how-much the Perfetto export wrote
    critical_path: Dict[str, Any] = field(default_factory=dict)
    flight_recorder: List[Dict[str, Any]] = field(default_factory=list)
    trace_path: Optional[str] = None
    trace_spans: int = 0

    @property
    def total_bytes_moved(self) -> int:
        return sum(c.stats.bytes_moved for c in self.channels)

    @property
    def total_served(self) -> int:
        return sum(c.stats.served for c in self.channels)

    @property
    def total_dropped(self) -> int:
        return sum(c.stats.dropped for c in self.channels)

    def gantt_events(self) -> List[Tuple[float, str, str, str]]:
        out = []
        for c in self.channels:
            for (t, who, what) in c.stats.events:
                out.append((t, c.name, who, what))
        return sorted(out)

    def summary(self) -> str:
        lines = [
            f"wall_time_s={self.wall_time_s:.3f}",
            f"served={self.total_served} dropped={self.total_dropped} "
            f"bytes={self.total_bytes_moved}",
        ]
        t = self.transport
        if t:
            lines.append(
                f"prefetch: hits={t['prefetch_hits']} "
                f"misses={t['prefetch_misses']} "
                f"cancelled={t.get('prefetch_cancelled', 0)} "
                f"prepared_s={t['prefetch_prepared_s']:.3f} "
                f"blocked_s={t['prefetch_blocked_s']:.3f}")
            lines.append(
                f"redist: planned={t['redist_planned_bytes']} "
                f"shipped={t['redist_shipped_bytes']} "
                f"baseline={t['redist_baseline_bytes']} "
                f"aligned={t['redist_aligned']} slabs={t['redist_slabs']} "
                f"reshard_pack={t['reshard_pack']} "
                f"reshard_numpy={t['reshard_numpy']}")
        pc = self.plan_cache
        if pc:
            lines.append(
                f"plan_cache: size={pc['size']} hits={pc['hits']} "
                f"misses={pc['misses']} evictions={pc['evictions']} "
                f"hit_rate={pc['hit_rate']:.2f}")
        sc = self.scheduler
        if sc:
            lines.append(
                f"scheduler: policy={sc['policy']} steps={sc['steps']} "
                f"ticks={sc['ticks']} retunes={len(sc['decisions'])} "
                f"telemetry_samples={sc['telemetry_samples']}")
            for d in sc["decisions"]:
                lines.append(
                    f"  retune {d['edge']}: depth {d['old']}->{d['new']} "
                    f"({d['reason']})")
        replayed = sum(c.stats.replayed for c in self.channels)
        deduped = sum(c.stats.deduped for c in self.channels)
        retries = sum(c.stats.prep_retries for c in self.channels)
        if (self.restarts or self.dropped_tasks or replayed or deduped
                or retries or self.rescales or self.stalls):
            lines.append(
                f"recovery: restarts={len(self.restarts)} "
                f"dropped_tasks={len(self.dropped_tasks)} replayed={replayed} "
                f"deduped={deduped} prep_retries={retries} "
                f"rescales={len(self.rescales)} stalls={len(self.stalls)}")
        for (task, inst), secs in sorted(self.task_times.items()):
            lines.append(
                f"  {task}[{inst}]: {secs:.3f}s launches={self.task_launches.get((task, inst), 1)}"
            )
        for f in self.failures:
            lines.append(f"  FAILURE {f.task}[{f.instance}] attempt={f.attempt}: {f.error}")
        for r in self.restarts:
            lines.append(
                f"  RESTART {r['task']}[{r['instance']}] after attempt="
                f"{r['attempt']} -> epoch={r['epoch']}: {r['reason']}")
        for task, inst in self.dropped_tasks:
            lines.append(f"  DROPPED {task}[{inst}] (on_failure: drop)")
        for r in self.rescales:
            lines.append(
                f"  RESCALE {r['task']}: nslots {r['old_nslots']}->"
                f"{r['new_nslots']} nprocs {r['old_nprocs']}->"
                f"{r['new_nprocs']} trigger={r['trigger']} "
                f"cut_step={r['cut_step']} latency={r['latency_s']:.3f}s"
                + (f" ({r['reason']})" if r.get("reason") else ""))
        for s in self.stalls:
            lines.append(
                f"  STALL {s['task']}[{s['instance']}] "
                f"silent={s['silent_s']:.2f}s timeout={s['timeout_s']}s "
                f"-> {s['action']}")
        for edge, msg in self.prefetch_errors:
            lines.append(f"  PREFETCH-ERROR edge={edge}: {msg}")
        if self.trace_spans:
            lines.append(
                f"trace: spans={self.trace_spans}"
                + (f" -> {self.trace_path}" if self.trace_path else ""))
        for d in self.flight_recorder:
            lines.append(
                f"  FLIGHT-DUMP {d['task']}[{d['instance']}] "
                f"({len(d['spans'])} recent spans): {d['reason']}")
        if self.critical_path.get("instances"):
            lines.append(format_report(self.critical_path))
        return "\n".join(lines)


class Wilkins:
    """The workflow runtime. Construct with YAML + task callables, then run().

    Parameters
    ----------
    config:        YAML path, YAML string, or parsed dict (paper Listing 1/2/4/6).
    funcs:         mapping from task ``func`` name to a Python callable.  A
                   callable may take zero args (fully unmodified code reading
                   its world via ``repro.core.comm.world()``) or one arg (the
                   TaskComm).
    devices:       optional list of JAX devices to partition among tasks
                   proportionally to nprocs (restricted worlds).
    spill_dir:     directory for the ``file: 1`` transport path.
    record_events: keep per-channel event timelines (Gantt / Fig. 5).
    max_restarts:  per-instance restart budget on task failure (fault tolerance).
    action_dirs:   extra directories to search for custom action scripts.
    zero_copy:     transport fast path (default True): channels ship CoW
                   dataset views and fan-out shares one filtered payload.
                   False restores the legacy materialize-per-channel copies
                   (the benchmark baseline).  See DESIGN.md.

    ``run()`` owns the prefetch-executor lifecycle: a fresh ``PrefetchPool``
    sized to the workflow's total per-edge prefetch depth is injected into
    this run's channels at start and shut down (queued preps cancelled,
    channels detached) on success and error paths alike -- per run, so
    concurrent runs in one process never cancel each other's preps.
    """

    def __init__(
        self,
        config: Union[str, Dict[str, Any]],
        funcs: Dict[str, Callable],
        devices: Optional[Sequence[Any]] = None,
        spill_dir: Optional[str] = None,
        record_events: bool = False,
        max_restarts: int = 0,
        action_dirs: Sequence[str] = (),
        zero_copy: bool = True,
    ):
        self.graph = config if isinstance(config, WorkflowGraph) else WorkflowGraph.from_yaml(config)
        self.funcs = dict(funcs)
        missing = [t for t in self.graph.tasks if t not in self.funcs]
        if missing:
            raise ValueError(f"no callable provided for tasks: {missing}")
        self.spill_dir = spill_dir or os.path.join("/tmp", f"wilkins_spill_{os.getpid()}")
        self.record_events = record_events
        self.max_restarts = max_restarts
        self.action_dirs = list(action_dirs)
        self.zero_copy = zero_copy

        # Per-task failure policies: YAML ``on_failure:`` wins; a task that
        # declared nothing inherits the legacy ``max_restarts`` budget as an
        # UNMANAGED restart (relaunch the callable in place, no channel
        # quarantine / checkpoint restore -- bit-for-bit the pre-recovery
        # behaviour), or plain ``fail`` when that budget is 0.
        self.policies: Dict[str, FailurePolicy] = {}
        for name, t in self.graph.tasks.items():
            if "on_failure" in t.raw:
                self.policies[name] = t.on_failure
            elif max_restarts > 0:
                self.policies[name] = FailurePolicy(
                    kind="restart", max_retries=max_restarts, managed=False)
            else:
                self.policies[name] = FailurePolicy()

        self.device_groups = self._partition_devices(devices)
        self.channels: List[Channel] = []
        self.vols: Dict[Tuple[str, int], VOL] = {}
        # per-run scheduling state (set for the duration of ``run``): step
        # events from the VOLs / TaskComms tick the autotuner + telemetry
        self._sched_runtime: Optional[SchedulerRuntime] = None
        # per-instance checkpoint surfaces (wired onto TaskComms per run)
        self._recovery_ctx: Dict[Tuple[str, int], RecoveryContext] = {}
        self._run_seq = 0  # distinguishes checkpoint roots across run() calls
        # ...and across Wilkins INSTANCES: two drivers sharing the default
        # per-pid spill dir must never restore each other's checkpoints
        self._driver_seq = _next_driver_seq()
        # run-scoped elastic-rescale surfaces (set for the duration of
        # ``run``): the supervisor/report/pool/checkpoint-root the surgery
        # module reaches back into, plus the threads it spawns for the new
        # instances (joined by ``run`` after the original cohort)
        self._run_supervisor: Optional[RunSupervisor] = None
        self._run_report: Optional[WorkflowReport] = None
        self._run_pool: Optional[PrefetchPool] = None
        self._run_tracer: Optional[SpanRecorder] = None
        self._ck_root = ""
        self._extra_threads: List[threading.Thread] = []
        self._extra_lock = make_lock("leaf:driver_extra")
        self._spawn_extra: Optional[Callable[[str, int, int], None]] = None
        self._build()

    # ------------------------------------------------------------ resources
    def _partition_devices(
        self, devices: Optional[Sequence[Any]]
    ) -> Dict[Tuple[str, int], Optional[List[Any]]]:
        """Slice the global device list into disjoint restricted worlds,
        proportionally to nprocs (the PMPI-partitioning analogue)."""
        groups: Dict[Tuple[str, int], Optional[List[Any]]] = {}
        instances: List[Tuple[str, int, int]] = []  # (task, inst, nprocs)
        for name, t in self.graph.tasks.items():
            for i in range(t.task_count):
                instances.append((name, i, t.nprocs))
        if devices is None:
            for name, i, _ in instances:
                groups[(name, i)] = None
            return groups
        devices = list(devices)
        total_procs = sum(n for _, _, n in instances) or 1
        off = 0
        for k, (name, i, n) in enumerate(instances):
            share = max(1, (len(devices) * n) // total_procs)
            if k == len(instances) - 1:
                grp = devices[off:]
            else:
                grp = devices[off : off + share]
            off = min(off + share, len(devices) - (len(instances) - 1 - k))
            groups[(name, i)] = grp or devices[-1:]
        return groups

    # ------------------------------------------------------------ wiring
    def _build(self) -> None:
        for edge in self.graph.edges:
            ptask = self.graph.tasks[edge.producer]
            ctask = self.graph.tasks[edge.consumer]
            for pi, ci in edge.instance_links(ptask.task_count, ctask.task_count):
                # M->N redistribution: an inport with declared ownership gets
                # a RedistSpec describing which blocks THIS consumer instance
                # (and its logical ranks / subset writers) owns; the channel
                # consults the plan cache and ships only those blocks.
                redist = None
                if edge.redistribute:
                    redist = RedistSpec(
                        axis=edge.redist_axis,
                        nslots=ctask.task_count,
                        slot=ci,
                        nranks=ctask.io_procs,
                    )
                ch = Channel(
                    name=f"{edge.producer}[{pi}]->{edge.consumer}[{ci}]:{edge.filename_pattern}",
                    producer=(edge.producer, pi),
                    consumer=(edge.consumer, ci),
                    filename_pattern=edge.filename_pattern,
                    dset_patterns=edge.dset_patterns,
                    mode=edge.mode,
                    io_freq=edge.io_freq,
                    spill_dir=self.spill_dir,
                    record_events=self.record_events,
                    queue_depth=edge.queue_depth,
                    zero_copy=self.zero_copy,
                    redistribute=redist,
                    prefetch=edge.prefetch,
                    weight=edge.weight,
                    autotune=edge.autotune,
                )
                self.channels.append(ch)

        rank_offset = 0
        for name, t in self.graph.tasks.items():
            for i in range(t.task_count):
                vol = VOL(name, instance=i, nprocs=t.nprocs, io_procs=t.io_procs)
                for ch in self.channels:
                    if ch.producer == (name, i):
                        vol.outgoing.append(ch)
                    if ch.consumer == (name, i):
                        vol.incoming.append(ch)
                # memory/file VOL properties per matched port (driver sets
                # these from YAML; LowFive equivalent of set_memory/set_file)
                for ch in vol.outgoing + vol.incoming:
                    if ch.mode == "memory":
                        vol.set_memory(ch.filename_pattern)
                    else:
                        vol.set_file(ch.filename_pattern)
                # declared producer ownership (YAML `outports: {ownership:}`):
                # datasets written through this VOL get per-rank blocks
                # stamped at close, so M->N planning sees the real source
                # decomposition without task-code changes
                for port in t.outports:
                    if port.ownership:
                        vol.set_ownership(port.filename, port.own_axis,
                                          port.own_nranks or t.io_procs)
                self.vols[(name, i)] = vol
                rank_offset += t.nprocs

    # ------------------------------------------------------------ execution
    def _make_comm(self, name: str, inst: int) -> TaskComm:
        t = self.graph.tasks[name]
        # Wire the task's RedistSpecs so task code can `comm.reshard(...)`
        # without touching plans: consumer inport specs are exact (their slot
        # IS this instance); a producer feeding a redistributing port gets
        # the consumer's decomposition with ``slot=-1`` -- the producer has
        # no "mine", so reshard demands ranks="all" (or explicit ids)
        # instead of silently returning one consumer instance's blocks.
        specs: Dict[str, RedistSpec] = {}
        for ch in self.channels:
            if ch.redistribute is not None and ch.producer == (name, inst):
                specs.setdefault(ch.filename_pattern,
                                 replace(ch.redistribute, slot=-1))
        for ch in self.channels:
            if ch.redistribute is not None and ch.consumer == (name, inst):
                specs[ch.filename_pattern] = ch.redistribute
        return TaskComm(
            task=name,
            instance=inst,
            rank=0,
            size=t.nprocs,
            io_procs=t.io_procs,
            devices=self.device_groups.get((name, inst)),
            redist_specs=specs,
            scheduler=self._sched_runtime,
            supervisor=self._run_supervisor,
            tracer=self._run_tracer,
        )

    def _run_instance(self, name: str, inst: int, report: WorkflowReport,
                      sup: RunSupervisor, gen: int = 0) -> None:
        """Supervised task lifecycle: RUNNING -> (FAILED -> RESTARTING)* ->
        DONE | DROPPED, per the task's ``on_failure`` policy.

        The outer loop is the restart loop (one iteration per incarnation);
        the inner loop is the query-protocol relaunch loop for stateless
        consumers (§3.5.1) -- unchanged from the pre-recovery driver.  A
        MANAGED restart quarantines this instance's channels under a fresh
        epoch and resets the VOL before relaunching, so the new incarnation
        re-rendezvouses cleanly and replays from its last checkpoint; the
        legacy unmanaged budget (``Wilkins(max_restarts=N)``) relaunches in
        place with no surgery, exactly as before.

        ``gen`` is the task generation this thread was spawned for: a
        completed rescale bumps it, fencing every older thread -- a fenced
        thread's failures and results are moot and it exits quietly.  The
        VOL/channel/recovery tables are re-fetched every incarnation because
        a rescale swaps the dict entries under this thread.
        """
        t0 = time.monotonic()
        launches = 0
        vol: Optional[VOL] = None
        attempt = sup.attempt(name, inst)
        first = True
        try:
            while True:  # restart loop: one iteration per incarnation
                t = self.graph.tasks[name]
                vol = self.vols[(name, inst)]
                fn = self.funcs[name]
                policy = sup.policy_for(name)
                rc = self._recovery_ctx.get((name, inst))
                sup.mark(name, inst, TaskState.RUNNING)
                if first and t.actions is not None:
                    action = actions_mod.load_action(t.actions, self.action_dirs)
                    action(vol, 0)
                first = False
                comm = self._make_comm(name, inst)
                if rc is not None:
                    rc.attempt = attempt
                    rc.epoch = sup.epoch(name, inst)
                    comm.recovery = rc
                try:
                    sup.fire(name, inst, "start", attempt)
                    while True:  # query-protocol relaunch loop
                        launches += 1
                        push_vol(vol)
                        push_comm(comm)
                        try:
                            if _takes_arg(fn):
                                fn(comm)
                            else:
                                fn()
                        finally:
                            pop_comm()
                            pop_vol()
                        # Query protocol (§3.5.1): if this task consumes and
                        # any matched producer is still live or has pending
                        # data, the consumer is stateless -- relaunch it for
                        # the next datum.  Only PURE consumers participate: a
                        # task that also produces (intermediate / steering
                        # node in a cycle) is stateful by construction --
                        # relaunching it would livelock the cycle.
                        if vol.incoming and not vol.outgoing and any(
                            (not c.is_done()) or c.peek_pending()
                            for c in vol.incoming
                        ):
                            continue
                        break
                except RescaleInterrupt:
                    # not a failure: a pending resize pulled us out of the
                    # callable.  Arrive at the op; the LAST arriver leads the
                    # surgery, everyone else just retires.  A vanished op
                    # means the surgery already sealed -- we're a zombie.
                    op = sup.pending_rescale(name)
                    if op is not None and sup.arrive(op, inst):
                        sup.lead(op)
                    return
                except SupersededError:
                    # fenced zombie (e.g. a stalled thread that woke after
                    # its task was resized away from it): exit quietly
                    return
                except Exception as e:
                    if sup.is_superseded(name, gen) or sup.is_fenced(name, inst):
                        return  # a rescale retired this incarnation already
                    report.failures.append(
                        TaskFailure(name, inst, attempt,
                                    f"{type(e).__name__}: {e}")
                    )
                    sup.mark(name, inst, TaskState.FAILED)
                    if policy.kind == "rescale" and attempt < policy.max_retries:
                        cur = sup.task_counts.get(name, t.task_count)
                        if policy.nslots is not None and policy.nslots != cur:
                            # relaunch at a different instance count: full
                            # channel surgery.  This crashed thread is fenced
                            # out of the required set; it leads only when no
                            # live sibling remains to arrive last.
                            op, lead = sup.request_rescale(
                                name, nslots=policy.nslots,
                                nprocs=policy.nprocs, trigger="policy",
                                reason=f"{type(e).__name__}: {e}",
                                fence_instance=inst)
                            if lead:
                                sup.lead(op)
                            return
                        # nprocs-only: a managed restart that also moves the
                        # logical rank count -- no topology change, no barrier
                        self._apply_nprocs_rescale(name, inst, policy, e,
                                                   vol, sup, report, attempt)
                        delay = policy.backoff(name, inst, attempt)
                        if delay > 0:
                            time.sleep(delay)
                        attempt += 1
                        continue
                    if policy.kind == "restart" and attempt < policy.max_retries:
                        if policy.managed:
                            ev = sup.begin_restart(name, inst, e, vol=vol)
                            report.restarts.append(ev.as_dict())
                            sched = self._sched_runtime
                            if sched is not None:
                                sched.notify_restart(name, inst, attempt,
                                                     ev.epoch, ev.reason)
                            delay = policy.backoff(name, inst, attempt)
                            if delay > 0:
                                time.sleep(delay)
                        attempt += 1
                        continue
                    if policy.kind == "drop":
                        # optional task: degrade its edges to no-ops and let
                        # the rest of the workflow run to completion
                        sup.drop(name, inst)
                        report.dropped_tasks.append((name, inst))
                        sched = self._sched_runtime
                        if sched is not None:
                            sched.timeline.record_event(
                                "drop", task=name, instance=inst,
                                attempt=attempt,
                                reason=f"{type(e).__name__}: {e}")
                        return
                    tr = sup.tracer
                    if tr is not None:
                        why = ("restarts exhausted"
                               if policy.kind in ("restart", "rescale")
                               and policy.max_retries > 0 else "task failure")
                        tr.mark_failure(
                            f"{why}: {type(e).__name__}: {e}", name, inst)
                        # the runner's generic dump would re-snapshot the
                        # same history -- mark this error as already dumped
                        e._flight_dumped = True  # type: ignore[attr-defined]
                    raise  # fail (or retries exhausted): chain per PR 3
                op = sup.mark_done_or_join(name, inst)
                if op is not None:
                    # finished exactly as a rescale landed: the op still
                    # needs this instance out of the way -- count the clean
                    # exit as the arrival (and lead if we were the last)
                    if sup.arrive(op, inst):
                        sup.lead(op)
                return
        finally:
            if vol is not None:
                vol.finalize()
            report.task_times[(name, inst)] = time.monotonic() - t0
            report.task_launches[(name, inst)] = launches

    def _apply_nprocs_rescale(self, name: str, inst: int,
                              policy: FailurePolicy, error: BaseException,
                              vol: VOL, sup: RunSupervisor,
                              report: WorkflowReport, attempt: int) -> None:
        """``rescale: {nprocs: K}`` with no instance-count change: a managed
        restart that also moves the task's logical rank count.

        No barrier and no channel rebuild -- the topology is unchanged; only
        the per-rank decompositions are re-pointed: the producer-side
        declared ownership (``VOL._ownership``) and the consumer-side frozen
        ``RedistSpec`` rank counts, on EVERY instance of the task.  Sibling
        channels of one edge share a slot decomposition, so a per-instance
        change would mix rank counts within one plan; the all-instance
        change only re-subdivides future slabs' ownership maps -- the slab
        bytes per slot are a function of ``nslots`` alone and do not move.
        """
        t = self.graph.tasks[name]
        t1 = time.monotonic()
        ev0 = sup.begin_restart(name, inst, error, vol=vol)
        report.restarts.append(ev0.as_dict())
        sched = self._sched_runtime
        if sched is not None:
            sched.notify_restart(name, inst, attempt, ev0.epoch, ev0.reason)
        old_np = sup.task_nprocs.get(name, t.nprocs)
        new_np = policy.nprocs
        if new_np is None or new_np == old_np:
            return
        old_io = t.nwriters if t.nwriters is not None else old_np
        new_io = t.nwriters if t.nwriters is not None else new_np
        t.nprocs = new_np
        for (tn, _i), v in self.vols.items():
            if tn == name:
                v.nprocs = new_np
                v.io_procs = new_io
                v.update_ownership_nranks(old_io, new_io)
        for ch in self.channels:
            if ch.consumer[0] == name and ch.redistribute is not None:
                ch.redistribute = replace(ch.redistribute, nranks=new_io)
        sup.task_nprocs[name] = new_np
        rc = self._recovery_ctx.get((name, inst))
        cut = rc.latest_step() if rc is not None else None
        ev = RescaleEvent(time.monotonic(), name, t.task_count, t.task_count,
                          old_np, new_np, "policy",
                          cut if cut is not None else -1,
                          time.monotonic() - t1,
                          f"{type(error).__name__}: {error}")
        sup.rescales.append(ev)
        report.rescales.append(ev.as_dict())
        if sched is not None:
            sched.notify_rescale(name, t.task_count, t.task_count, old_np,
                                 new_np, "policy", ev.cut_step, ev.latency_s,
                                 ev.reason)

    def _execute_rescale(self, op: Any) -> None:
        """Surgery executor the supervisor's ``lead(op)`` dispatches to."""
        from .rescale import execute_rescale
        execute_rescale(self, op)

    def _validate_rescale_request(self, task: str,
                                  nslots: Optional[int] = None,
                                  nprocs: Optional[int] = None) -> None:
        """Validator for programmatic ``RunSupervisor.rescale`` / YAML-free
        triggers: same structural rules the graph enforces at parse time for
        declared ``on_failure: {rescale: ...}`` policies -- one shared
        implementation in ``analysis.rules``."""
        from ..analysis import rules
        rules.validate_rescale_request(self.graph, task,
                                       nslots=nslots, nprocs=nprocs)

    def run(self, timeout: Optional[float] = None,
            faults: Optional[Any] = None,
            trace: Optional[Any] = None) -> WorkflowReport:
        """Run the workflow to completion.

        ``faults`` threads a deterministic fault-injection plan through the
        run: a ``recovery.FaultPlan``, a single ``FaultSpec`` (or its dict
        spelling), or a list of either.  Injected crashes take the same
        failure paths real errors do -- policies, quarantine, poison pills
        and all -- which is what makes every recovery path testable without
        flaky sleeps.

        ``trace`` opts this run into span tracing (``True`` for defaults, a
        path string to auto-export a Perfetto ``trace.json`` there, a dict
        in the YAML ``tracing:`` spelling, or a ``TraceConfig``); it wins
        over the workflow's ``tracing:`` block.  Both absent is the
        zero-cost default: no recorder is allocated and every hook site
        stays one attribute load + None test."""
        report = WorkflowReport(channels=self.channels)
        threads: List[threading.Thread] = []
        errors: List[BaseException] = []
        tcfg = TraceConfig.coerce(trace) or self.graph.tracing
        tracer: Optional[SpanRecorder] = (
            SpanRecorder(tcfg) if tcfg is not None else None)
        self._run_tracer = tracer

        # The run's supervisor: lifecycle states, epochs, fault firing, and
        # the channel surgery for restart / drop / rescale / permanent
        # failure.  It knows the live instance count per task (rescales move
        # it) and the stall-watchdog windows; the driver installs itself as
        # the surgery executor and rescale validator.
        stall_timeouts = {name: t.stall_timeout_s
                          for name, t in self.graph.tasks.items()
                          if t.stall_timeout_s is not None}
        sup = RunSupervisor(
            self.policies, self.channels,
            faults=FaultPlan.coerce(faults),
            task_counts={name: t.task_count
                         for name, t in self.graph.tasks.items()},
            stall_timeouts=stall_timeouts)
        sup.task_nprocs = {name: t.nprocs
                           for name, t in self.graph.tasks.items()}
        sup.on_rescale = self._execute_rescale
        sup.validate_rescale = self._validate_rescale_request
        sup.tracer = tracer
        self._run_supervisor = sup
        self._run_report = report
        self._extra_threads = []
        extra_lock = self._extra_lock

        def runner(name: str, inst: int, gen: int = 0) -> None:
            try:
                self._run_instance(name, inst, report, sup, gen=gen)
            except BaseException as e:
                if sup.is_superseded(name, gen):
                    return  # a rescale retired this incarnation mid-failure
                errors.append(e)
                if tracer is not None and not getattr(
                        e, "_flight_dumped", False):
                    tracer.mark_failure(
                        f"task failure: {type(e).__name__}: {e}", name, inst)
                # poison our outgoing channels FIRST: consumers blocked in
                # get() raise a ChannelError naming us instead of waiting
                # out their timeout (finalize()'s producer-done races this,
                # but get() checks poison before done, so the error wins)
                sup.poison(name, inst, e)
                # unblock everyone coupled to us (a shrink may have dropped
                # this instance's VOL from the table -- nothing to unblock)
                vol = self.vols.get((name, inst))
                if vol is not None:
                    vol.finalize()

        def spawn_extra(name: str, inst: int, gen: int) -> None:
            # fresh threads for a rescaled task's new instances; run() joins
            # them after the original cohort (they may spawn more in turn)
            th = threading.Thread(
                target=runner, args=(name, inst, gen),
                name=f"wilkins-{name}-{inst}-g{gen}", daemon=True)
            with extra_lock:
                self._extra_threads.append(th)
            th.start()

        self._spawn_extra = spawn_extra

        # Prefetch executor lifecycle is tied to THIS run: a fresh pool
        # sized to the run's total per-edge depth is injected into this
        # run's channels up front and torn down (queued preps cancelled,
        # channels detached) on success and error paths alike -- the old
        # process-wide executor was never shut down, so its non-daemon
        # workers leaked across runs and a prep stuck in I/O could hang
        # interpreter exit.  The pool is PER RUN, not the module global:
        # concurrent Wilkins runs in one process must not cancel each
        # other's in-flight preps.
        # The run's scheduler: builds the pool's queue policy from the YAML
        # ``scheduler:`` block, counts step events from the VOLs/TaskComms,
        # and fires the depth autotuner + telemetry sampler every
        # ``tick_every`` events.  Pool sizing uses each edge's MAX depth
        # (autotune upper bound), so a retune upward never starves for
        # workers mid-run.
        sched = SchedulerRuntime(self.graph.scheduler, self.channels)
        self._sched_runtime = sched
        # Per-step hooks are wired only when the workflow opted in (an
        # explicit ``scheduler:`` block, or an autotuned edge that needs
        # ticks to retune): a legacy workflow pays zero per-step cost --
        # its report still carries the snapshot and one teardown sample.
        if self.graph.scheduler.explicit or any(
                ch.autotune is not None for ch in self.channels):
            for vol in self.vols.values():
                vol.scheduler = sched
        # Tracing wiring (traced runs only): the VOLs, the channels and the
        # supervisor all hold the one run-scoped recorder; TaskComms pick it
        # up per incarnation via ``_make_comm``, rescale surgery re-wires
        # the rebuilt channels/VOLs from ``sup.tracer``.
        if tracer is not None:
            for vol in self.vols.values():
                vol.tracer = tracer
            for ch in self.channels:
                ch.set_tracer(tracer)
        # Recovery wiring, gated on actually being able to recover (managed
        # restart/drop policies or an injected fault plan): VOLs get the
        # supervisor (fault points + epoch stamping), channels get the fault
        # hook for async preps, prep-error retry, and -- on edges into a
        # managed-restart consumer -- the replay buffer.  Every instance
        # gets a RecoveryContext so ``comm.checkpoint()/restore()`` work
        # (they are cheap, lazy, and no-ops-by-absence standalone).
        recovery_on = sup.recovery_active
        if recovery_on:
            for vol in self.vols.values():
                vol.supervisor = sup
            for ch in self.channels:
                ch.set_supervisor(sup)
                ch.set_prep_retry(True)
                cpol = sup.policy_for(ch.consumer[0])
                if cpol.kind == "restart" and cpol.managed:
                    ch.set_replay(True)
                elif cpol.kind == "rescale":
                    # a resize re-cuts steps the consumer may already have
                    # checkpointed past: replay tracking plus the retention
                    # ring (acked payloads) back the consistent-cut replay
                    ch.set_replay(True)
                    ch.set_retention(True)
        self._recovery_ctx = {}
        # per-run checkpoint root: a second run() of the same Wilkins must
        # start fresh, not restore the previous run's checkpoints
        self._run_seq += 1
        ck_root = os.path.join(
            self.spill_dir, f"ckpt_d{self._driver_seq}_run{self._run_seq}")
        self._ck_root = ck_root  # rescale surgery re-cuts shards under here
        for (name, i), vol in self.vols.items():
            self._recovery_ctx[(name, i)] = RecoveryContext(
                name, i, os.path.join(ck_root, f"{name}_{i}"),
                incoming=vol.incoming, outgoing=vol.outgoing)
        total_depth = sum(ch.max_prefetch_depth for ch in self.channels)
        pool: Optional[PrefetchPool] = None
        if total_depth:
            pool = PrefetchPool(max_workers=max(2, min(16, total_depth)),
                                thread_name_prefix="wilkins-prefetch-run",
                                policy=sched.make_policy())
            for ch in self.channels:
                ch.set_prefetch_pool(pool)
        self._run_pool = pool
        # Health watchdog: one daemon scanning heartbeats when any managed
        # task declared ``stall_timeout_s``.  Stalls take the task's policy
        # (rescale away from the fenced instance, or drop); the 2-strike
        # hysteresis lives in ``sup.scan_stalls`` -- slow-but-progressing
        # tasks heartbeat through channel waits and are never declared.
        watchdog_stop = threading.Event()
        watchdog_thread: Optional[threading.Thread] = None
        if stall_timeouts and recovery_on:
            wd_interval = max(0.05,
                              min(1.0, min(stall_timeouts.values()) / 2.0))

            def watchdog() -> None:
                while not watchdog_stop.wait(wd_interval):
                    for (task, i, silent, wd_timeout) in sup.scan_stalls():
                        pol = sup.policy_for(task)
                        action = "rescale" if pol.kind == "rescale" else "drop"
                        sev = StallEvent(time.monotonic(), task, i, silent,
                                         wd_timeout, action)
                        sup.record_stall(sev)
                        report.stalls.append(sev.as_dict())
                        sched.notify_stall(task, i, silent, wd_timeout,
                                           action)
                        if tracer is not None:
                            tracer.mark_failure(
                                f"stall declared: silent {silent:.2f}s > "
                                f"{wd_timeout}s -> {action}", task, i)
                        try:
                            if pol.kind == "rescale":
                                # resize away from the stalled instance; the
                                # watchdog leads only when no live sibling
                                # remains to arrive last
                                op, lead = sup.request_rescale(
                                    task, nslots=pol.nslots,
                                    nprocs=pol.nprocs, trigger="stall",
                                    reason=f"stalled {silent:.2f}s > "
                                           f"{wd_timeout}s (instance {i})",
                                    fence_instance=i)
                                if lead:
                                    sup.lead(op)
                            else:  # drop
                                sup.drop(task, i)
                                report.dropped_tasks.append((task, i))
                        except BaseException as e:
                            errors.append(e)

            watchdog_thread = threading.Thread(
                target=watchdog, name="wilkins-watchdog", daemon=True)
            watchdog_thread.start()
        t0 = time.monotonic()
        try:
            for name, t in self.graph.tasks.items():
                for i in range(t.task_count):
                    th = threading.Thread(
                        target=runner, args=(name, i), name=f"wilkins-{name}-{i}", daemon=True
                    )
                    threads.append(th)
            for th in threads:
                th.start()
            # One global deadline across ALL joins: a per-thread timeout would
            # let a hung workflow take N_threads x timeout to fail.
            deadline = None if timeout is None else time.monotonic() + timeout
            hung: List[str] = []
            for th in threads:
                remaining = None
                if deadline is not None:
                    remaining = max(0.0, deadline - time.monotonic())
                th.join(timeout=remaining)
                if th.is_alive():
                    hung.append(th.name)
            # Drain the threads rescale surgeries spawned for new instances
            # (a rescaled task may rescale again, spawning more -- loop to a
            # fixed point) under the same global deadline.
            joined: set = set()
            while not hung:
                with extra_lock:
                    extra = [th for th in self._extra_threads
                             if th not in joined]
                if not extra:
                    break
                for th in extra:
                    remaining = None
                    if deadline is not None:
                        remaining = max(0.0, deadline - time.monotonic())
                    th.join(timeout=remaining)
                    joined.add(th)
                    if th.is_alive():
                        hung.append(th.name)
            report.wall_time_s = time.monotonic() - t0
            # Tear the prefetch pool down HERE (not only in the finally) so
            # any prep exception the shutdown raced -- erroring on a worker
            # after the consumers already exited -- lands on the report
            # instead of vanishing with the daemon worker.
            if pool is not None:
                pool.shutdown()
                report.prefetch_errors = [
                    (edge, f"{type(e).__name__}: {e}")
                    for edge, e in pool.drain_errors(timeout=5.0)]
            sched.close()  # final telemetry sample before the snapshot
            report.transport = transport_stats().snapshot()
            report.plan_cache = plan_cache().snapshot()
            report.scheduler = sched.snapshot()
            report.scheduler["recovery"] = sup.snapshot()
            report.timeline = sched.timeline
            # Both failure paths carry the partial WorkflowReport (channel
            # stats, gantt events, per-task failures) as ``err.report``, and
            # every secondary task error stays reachable via the __context__
            # chain -- raising only errors[0] used to silently discard the rest.
            if hung:
                if tracer is not None:
                    tracer.mark_failure(f"join timeout: {hung}")
                err: BaseException = TimeoutError(
                    f"task threads did not finish before the deadline: {hung}")
                err = _chain_errors(err, errors)
                err.report = report  # type: ignore[attr-defined]
                raise err
            if errors:
                primary = _chain_errors(errors[0], errors[1:])
                primary.report = report  # type: ignore[attr-defined]
                raise primary
            return report
        finally:
            if watchdog_thread is not None:
                watchdog_stop.set()
                watchdog_thread.join(timeout=5.0)
            # scheduler teardown mirrors the pool's: close on success and
            # error paths alike, and always feed the report (the error paths
            # attach the partial report to the raised exception above, so
            # err.report.summary() shows scheduler state too)
            sched.close()
            if not report.scheduler:
                report.scheduler = sched.snapshot()
                report.scheduler["recovery"] = sup.snapshot()
                report.timeline = sched.timeline
            # An exception between the joins and the success-path snapshot
            # block (shutdown races, KeyboardInterrupt) would leave the
            # report attached to the chained error without its transport /
            # plan-cache counters -- re-snapshot here, under the stats'
            # own locks, exactly like the scheduler above.
            if not report.transport:
                report.transport = transport_stats().snapshot()
                report.plan_cache = plan_cache().snapshot()
            for vol in self.vols.values():
                vol.scheduler = None
                vol.supervisor = None
                vol.tracer = None
            self._sched_runtime = None
            if pool is not None:
                pool.shutdown()
                if not report.prefetch_errors:
                    report.prefetch_errors = [
                        (edge, f"{type(e).__name__}: {e}")
                        for edge, e in pool.drain_errors(timeout=5.0)]
                for ch in self.channels:
                    ch.set_prefetch_pool(None)
            if recovery_on:
                for ch in self.channels:
                    ch.set_supervisor(None)
                    ch.set_prep_retry(False)
                    ch.set_replay(False)
                    ch.set_retention(False)
            if tracer is not None:
                # Finalize the trace on success and error paths alike: the
                # returned report (or ``err.report`` -- same object) carries
                # the span count, flight dumps, attribution and export path;
                # mutating it here is visible to the caller even after the
                # ``return report`` above.
                for ch in self.channels:
                    ch.set_tracer(None)
                sup.tracer = None
                spans = tracer.spans()
                report.trace_spans = len(spans)
                report.flight_recorder = tracer.dumps()
                report.critical_path = attribute(spans)
                if tracer.config.path:
                    report.trace_path = export_trace(
                        tracer.config.path, tracer, timeline=sched.timeline)
            self._run_tracer = None
            self._run_supervisor = None
            self._run_report = None
            self._run_pool = None
            self._spawn_extra = None


def _chain_errors(
    primary: BaseException, rest: Sequence[BaseException]
) -> BaseException:
    """Attach ``rest`` to ``primary``'s ``__context__`` chain (exception-group
    semantics on the implicit-chaining mechanism: ``raise primary`` shows
    every secondary as 'During handling of ... another exception occurred').

    Cycle-safe: an error already reachable from the chain is not re-linked.
    """
    seen: set = set()

    def _tail(e: BaseException) -> BaseException:
        seen.add(id(e))
        while e.__context__ is not None and id(e.__context__) not in seen:
            e = e.__context__
            seen.add(id(e))
        return e

    tail = _tail(primary)
    for e in rest:
        if id(e) in seen:
            continue
        tail.__context__ = e
        tail = _tail(e)
    return primary


def _takes_arg(fn: Callable) -> bool:
    import inspect

    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return False
    params = [
        p
        for p in sig.parameters.values()
        if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
        and p.default is p.empty
    ]
    return len(params) >= 1
