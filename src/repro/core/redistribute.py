"""M->N data redistribution -- the LowFive data-redistribution layer.

A producer running M (logical) ranks owns a dataset as M hyperslab blocks; a
consumer running N ranks wants it as N blocks.  LowFive plans which pieces of
which producer block each consumer rank needs and moves exactly those bytes.
We reproduce that planner (pure index arithmetic, testable to the byte) plus
the executors the transport hot path runs:

* ``CompiledPlan``   -- a plan compiled once into per-dst *coalesced* slab
  descriptors (adjacent transfers merged into contiguous runs) with an
  aligned-boundary detector: when every dst block coincides with exactly one
  src block the exchange degenerates to CoW views (zero bytes copied).
* ``PlanCache``      -- process-wide LRU keyed on (src blocks, dst blocks,
  shape, dtype); steady-state steps re-plan nothing (metadata is per-shape,
  not per-step).  ``Channel`` consults it on every served dataset.
* scatter executor   -- ``CompiledPlan.execute`` writes straight into
  preallocated per-rank destination blocks from per-rank source blocks; no
  global-array materialization, one numpy slice copy per coalesced run.
* JAX pack executor  -- ``execute_pack_jax`` lowers a cached plan's runs
  to ``kernels.pack`` scalar-prefetch DMA tiles (interpret mode on CPU,
  Mosaic on TPU) for device-resident reshard.  Rank>2 plans decomposed
  along ONE axis are lowered by *flattening* the non-decomposed axes into a
  virtual row/column dimension (``PackGeometry``) -- the kernels stay 2-D;
  only genuinely cross-axis N-D decompositions fall back to the numpy
  scatter executors.  ``slab_box`` runs the same gathers in *slab-local*
  source coordinates, so a consumer holding only its received slab (not the
  global extent) still reshards on device.
* ``reshard_jax``    -- resharding a ``jax.Array`` from the producer task's
  mesh layout onto the consumer task's mesh (``device_put`` with a target
  ``NamedSharding``; on a real pod XLA turns this into ICI transfers).

Subset writers (paper §3.2.2): ``gather_to_writers`` collapses an M-block
ownership onto the first k ranks, reproducing the LAMMPS rank-0 gather.
``RedistSpec`` is the per-channel declaration (decomposition axis + rank
counts from the consumer's YAML) the driver wires from the workflow graph.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..analysis.lockcheck import make_lock
from .datamodel import BlockOwnership, Dataset

__all__ = [
    "even_blocks",
    "intersect",
    "Transfer",
    "plan_redistribution",
    "coalesce_transfers",
    "CompiledPlan",
    "PackGeometry",
    "PlanCache",
    "plan_cache",
    "reset_plan_cache",
    "RedistSpec",
    "redistribute_numpy",
    "redistribute_cached",
    "execute_pack_jax",
    "execute_pack_jax_all",
    "gather_to_writers",
    "reshard_jax",
]

Box = Tuple[Tuple[int, ...], Tuple[int, ...]]  # (starts, shape)


def even_blocks(shape: Sequence[int], nranks: int, axis: int = 0) -> List[Box]:
    """Even 1-D decomposition along ``axis`` (LowFive's default layout)."""
    shape = tuple(int(s) for s in shape)
    n = shape[axis]
    base, rem = divmod(n, nranks)
    out: List[Box] = []
    off = 0
    for r in range(nranks):
        cnt = base + (1 if r < rem else 0)
        starts = tuple(off if a == axis else 0 for a in range(len(shape)))
        bshape = tuple(cnt if a == axis else s for a, s in enumerate(shape))
        out.append((starts, bshape))
        off += cnt
    return out


def intersect(a: Box, b: Box) -> Optional[Box]:
    """Intersection of two boxes in global index space, or None."""
    starts, shape = [], []
    for (as_, ash), (bs_, bsh) in zip(zip(*a), zip(*b)):
        lo = max(as_, bs_)
        hi = min(as_ + ash, bs_ + bsh)
        if hi <= lo:
            return None
        starts.append(lo)
        shape.append(hi - lo)
    return tuple(starts), tuple(shape)


@dataclass(frozen=True)
class Transfer:
    """One piece: src_rank's block region -> dst_rank's block region."""

    src_rank: int
    dst_rank: int
    global_starts: Tuple[int, ...]
    shape: Tuple[int, ...]

    @property
    def nbytes_factor(self) -> int:
        return int(np.prod(self.shape))


def plan_redistribution(src: Sequence[Box], dst: Sequence[Box]) -> List[Transfer]:
    """All (src_rank, dst_rank, region) triples with nonempty overlap.

    This is the metadata-only planning step LowFive performs from the HDF5
    dataspace descriptions -- no data is touched.
    """
    out: List[Transfer] = []
    for dr, dbox in enumerate(dst):
        for sr, sbox in enumerate(src):
            ov = intersect(sbox, dbox)
            if ov is not None:
                out.append(Transfer(sr, dr, ov[0], ov[1]))
    return out


def coalesce_transfers(
    transfers: Sequence[Transfer], ignore_src: bool = False
) -> List[Transfer]:
    """Merge transfers that tile contiguously along one axis into single runs.

    By default only transfers with the same (src_rank, dst_rank) merge -- the
    scatter executor reads per-src-rank local blocks, so a run must stay
    inside one source block.  With ``ignore_src=True`` runs merge *across*
    source ranks (merged runs carry ``src_rank=-1``): the global-buffer
    executor reads one stitched array, so a dst block fed by k adjacent
    producer blocks collapses to one slice copy.  Merging is greedy over the
    start-sorted list: two boxes merge when they agree on every axis except
    one, where they abut.
    """
    out: List[Transfer] = []
    for t in sorted(transfers, key=lambda t: (t.dst_rank, t.global_starts, t.src_rank)):
        if out:
            p = out[-1]
            if p.dst_rank == t.dst_rank and (ignore_src or p.src_rank == t.src_rank):
                diff = [
                    a
                    for a in range(len(t.shape))
                    if p.global_starts[a] != t.global_starts[a]
                    or p.shape[a] != t.shape[a]
                ]
                if len(diff) == 1:
                    a = diff[0]
                    if (
                        p.global_starts[a] + p.shape[a] == t.global_starts[a]
                        and all(p.shape[b] == t.shape[b] for b in range(len(t.shape)) if b != a)
                    ):
                        merged = tuple(
                            p.shape[b] + t.shape[b] if b == a else p.shape[b]
                            for b in range(len(t.shape))
                        )
                        rank = p.src_rank if p.src_rank == t.src_rank else -1
                        out[-1] = Transfer(rank, p.dst_rank, p.global_starts, merged)
                        continue
        out.append(t)
    return out


@dataclass(frozen=True)
class PackGeometry:
    """How a single-axis N-D plan flattens onto the 2-D pack kernels.

    The kernels (``pack_blocks`` / ``pack_cols``) DMA row/column tiles of a
    2-D buffer.  An N-D plan whose every coalesced run spans the full extent
    of all axes except one (``axis``) is equivalent to a 2-D gather on a
    reshaped view of the same row-major bytes:

    * ``axis == 0``  -> ``mode="rows"``: view ``(shape[0], prod(shape[1:]))``;
      runs along axis 0 map 1:1 to row runs (``scale == 1``).
    * ``axis  > 0``  -> ``mode="cols"``: view
      ``(prod(shape[:axis]), shape[axis] * inner)`` with
      ``inner = prod(shape[axis+1:])``; a run of ``cnt`` indices starting at
      ``start`` along ``axis`` maps to the contiguous column run
      ``(start * scale, cnt * scale)`` with ``scale == inner``.

    This is the flatten transform; unflattening a gathered 2-D block back to
    the N-D destination block is a plain ``reshape`` (the bytes are already
    in row-major destination order).
    """

    axis: int    # decomposed axis in the N-D frame
    mode: str    # "rows" | "cols" -- which kernel tile layout serves it
    rows: int    # flattened view rows
    cols: int    # flattened view cols
    scale: int   # flattened units per index along ``axis`` (1 in rows mode)

    def covers_slab(self, slab_box: Box, shape: Sequence[int]) -> bool:
        """Can the kernel lowering gather from this slab?  True when the
        slab spans the full extent of every NON-decomposed axis (the shape
        a 1-D decomposition slot always has) -- the single source of truth
        for both the reshard dispatch predicate and the executor's
        validation."""
        starts, sshape = slab_box
        return all(
            s == 0 and n == shape[a]
            for a, (s, n) in enumerate(zip(starts, sshape))
            if a != self.axis)


def _geometry_for_axis(shape: Sequence[int], axis: int) -> PackGeometry:
    shape = tuple(int(s) for s in shape)
    if axis == 0:
        return PackGeometry(axis=0, mode="rows", rows=shape[0],
                            cols=int(np.prod(shape[1:], dtype=np.int64)),
                            scale=1)
    inner = int(np.prod(shape[axis + 1:], dtype=np.int64)) if axis + 1 < len(shape) else 1
    return PackGeometry(axis=axis, mode="cols",
                        rows=int(np.prod(shape[:axis], dtype=np.int64)),
                        cols=shape[axis] * inner, scale=inner)


class CompiledPlan:
    """A redistribution plan compiled once for a (src, dst, shape, dtype) key.

    ``per_dst[r]`` holds dst rank r's per-source slab descriptors (what the
    scatter executor copies out of each producer block); ``per_dst_runs[r]``
    holds the same bytes *coalesced across source ranks* into contiguous runs
    (what the global-buffer executor and the pack-kernel lowering walk -- a
    dst block fed by k adjacent producer blocks is one run, one copy).
    ``aligned`` marks the degenerate exchange where every dst block coincides
    with exactly one src block (boundaries line up), so the transport can
    ship CoW views with zero bytes copied instead of executing any transfer.
    """

    __slots__ = ("src", "dst", "shape", "dtype", "per_dst", "per_dst_runs",
                 "transfers", "identity", "aligned", "nbytes_planned",
                 "_pack_cache", "_pack_lock", "_pack_geom")

    def __init__(self, src: Sequence[Box], dst: Sequence[Box],
                 shape: Sequence[int], dtype: Any = np.float64):
        self.src: Tuple[Box, ...] = tuple(src)
        self.dst: Tuple[Box, ...] = tuple(dst)
        self.shape = tuple(int(s) for s in shape)
        self.dtype = np.dtype(dtype)
        raw = plan_redistribution(self.src, self.dst)
        per_dst: List[Tuple[Transfer, ...]] = []
        per_dst_runs: List[Tuple[Transfer, ...]] = []
        for dr in range(len(self.dst)):
            mine = [t for t in raw if t.dst_rank == dr]
            per_dst.append(tuple(coalesce_transfers(mine)))
            per_dst_runs.append(tuple(coalesce_transfers(mine, ignore_src=True)))
        self.per_dst: Tuple[Tuple[Transfer, ...], ...] = tuple(per_dst)
        self.per_dst_runs: Tuple[Tuple[Transfer, ...], ...] = tuple(per_dst_runs)
        self.transfers: Tuple[Transfer, ...] = tuple(
            t for slabs in per_dst for t in slabs)
        self.identity = self.src == self.dst
        self.aligned = self.identity or all(
            len(slabs) <= 1
            and all(
                (t.global_starts, t.shape) == self.dst[dr]
                and (t.global_starts, t.shape) == self.src[t.src_rank]
                for t in slabs
            )
            for dr, slabs in enumerate(self.per_dst)
        )
        self.nbytes_planned = (
            sum(t.nbytes_factor for t in self.transfers) * self.dtype.itemsize
        )
        self._pack_cache: Dict[Tuple[int, int, str, int], Tuple[np.ndarray, Tuple[Tuple[int, int], ...]]] = {}
        self._pack_lock = make_lock("leaf:pack_cache")
        self._pack_geom = self._compute_pack_geometry()

    # ------------------------------------------------------------- executors
    def dst_bytes(self, ranks: Sequence[int]) -> int:
        """Planned bytes landing on the given dst ranks."""
        return sum(
            t.nbytes_factor for r in ranks for t in self.per_dst[r]
        ) * self.dtype.itemsize

    def execute(
        self,
        src_blocks: Sequence[np.ndarray],
        out: Optional[Sequence[np.ndarray]] = None,
        ranks: Optional[Sequence[int]] = None,
    ) -> List[np.ndarray]:
        """Scatter per-src-rank blocks into per-dst-rank blocks.

        ``src_blocks[r]`` is src rank r's local block (shape ``src[r][1]``).
        Writes go straight into ``out`` (preallocated per-rank destination
        blocks; allocated here if not given) -- the global array is never
        materialized, and each coalesced run is one numpy slice copy.
        ``ranks`` restricts the scatter to those dst ranks (the returned list
        is aligned to it) -- a consumer instance computes only its own blocks.
        """
        wanted = list(range(len(self.dst))) if ranks is None else list(ranks)
        if out is None:
            out = [np.empty(self.dst[r][1], dtype=self.dtype) for r in wanted]
        for i, dr in enumerate(wanted):
            dstarts = self.dst[dr][0]
            for t in self.per_dst[dr]:
                sstarts = self.src[t.src_rank][0]
                s_sl = tuple(
                    slice(g - s, g - s + n)
                    for g, s, n in zip(t.global_starts, sstarts, t.shape)
                )
                d_sl = tuple(
                    slice(g - s, g - s + n)
                    for g, s, n in zip(t.global_starts, dstarts, t.shape)
                )
                out[i][d_sl] = src_blocks[t.src_rank][s_sl]
        return list(out)

    def execute_global(
        self,
        global_array: np.ndarray,
        out: Optional[Sequence[np.ndarray]] = None,
        ranks: Optional[Sequence[int]] = None,
    ) -> List[np.ndarray]:
        """Scatter from the stitched global array (the in-process transport
        holds one buffer for all producer ranks) into per-dst-rank blocks.

        Walks ``per_dst_runs``: transfers coalesced across source ranks, so a
        dst block fed by k adjacent producer blocks is one slice copy.
        ``ranks`` restricts to those dst ranks, as in ``execute``."""
        wanted = list(range(len(self.dst))) if ranks is None else list(ranks)
        if out is None:
            out = [np.empty(self.dst[r][1], dtype=global_array.dtype)
                   for r in wanted]
        for i, dr in enumerate(wanted):
            dstarts = self.dst[dr][0]
            for t in self.per_dst_runs[dr]:
                g_sl = tuple(
                    slice(s, s + n) for s, n in zip(t.global_starts, t.shape)
                )
                d_sl = tuple(
                    slice(g - s, g - s + n)
                    for g, s, n in zip(t.global_starts, dstarts, t.shape)
                )
                out[i][d_sl] = global_array[g_sl]
        return list(out)

    # ----------------------------------------------------- pack-kernel lowering
    def _compute_pack_geometry(self) -> Optional[PackGeometry]:
        """The flatten geometry covering this plan, if any.

        A plan is kernel-lowerable when every coalesced run spans the full
        extent of every axis except ONE -- any rank >= 2, any single
        decomposed axis.  Axis 0 lowers to row tiles, any other axis to
        column tiles of the flattened view (see ``PackGeometry``).  ``None``
        for 1-D plans and genuinely cross-axis N-D tilings (e.g. quadrant
        decompositions) -- those take the numpy scatter executors.
        """
        if len(self.shape) < 2:
            return None
        runs = [t for slabs in self.per_dst_runs for t in slabs]
        for axis in range(len(self.shape)):
            if all(
                all(t.global_starts[b] == 0 and t.shape[b] == self.shape[b]
                    for b in range(len(self.shape)) if b != axis)
                for t in runs
            ):
                return _geometry_for_axis(self.shape, axis)
        return None

    @property
    def pack_geometry(self) -> Optional[PackGeometry]:
        return self._pack_geom

    @property
    def pack_mode(self) -> Optional[str]:
        """``"rows"`` / ``"cols"`` tile layout of the lowered plan, or
        ``None`` when only the numpy executors can serve it."""
        return self._pack_geom.mode if self._pack_geom is not None else None

    @property
    def pack_axis(self) -> Optional[int]:
        """The decomposed axis the kernel lowering gathers along."""
        return self._pack_geom.axis if self._pack_geom is not None else None

    def axis_runs(self, dst_rank: int, axis: int) -> List[Tuple[int, int]]:
        """dst_rank's coalesced (start, count) runs along ``axis``.

        Every run must span the full extent of every OTHER axis -- the
        invariant that lets the flatten transform map it onto contiguous
        row/column runs of the 2-D kernel view.
        """
        runs: List[Tuple[int, int]] = []
        for t in self.per_dst_runs[dst_rank]:
            for b in range(len(self.shape)):
                if b == axis:
                    continue
                if t.global_starts[b] != 0 or t.shape[b] != self.shape[b]:
                    raise ValueError(
                        f"pack lowering along axis {axis} needs runs spanning "
                        f"the full extent of axis {b}, got {t}")
            runs.append((t.global_starts[axis], t.shape[axis]))
        return runs

    def row_runs(self, dst_rank: int) -> List[Tuple[int, int]]:
        """2-D compatibility shim: runs along axis 0 (full-width row slabs)."""
        if len(self.shape) != 2:
            raise ValueError(f"row_runs needs a 2-D plan, got shape {self.shape}")
        return self.axis_runs(dst_rank, 0)

    def col_runs(self, dst_rank: int) -> List[Tuple[int, int]]:
        """2-D compatibility shim: runs along axis 1 (full-height col slabs)."""
        if len(self.shape) != 2:
            raise ValueError(f"col_runs needs a 2-D plan, got shape {self.shape}")
        return self.axis_runs(dst_rank, 1)

    def pack_tiles(
        self, dst_rank: int, tile_rows: int = 8, mode: str = "rows",
        slab_start: int = 0, slab_extent: Optional[int] = None,
    ) -> Tuple[np.ndarray, Tuple[Tuple[int, int], ...]]:
        """Lower dst_rank's runs to pack-kernel tile offsets (cached).

        Returns ``(tile_offsets, segments)``: the int32 source tile index per
        output tile (the kernel's scalar-prefetch operand) and, per run,
        ``(offset_in_packed_output, count)`` to trim the tile padding back to
        the exact rows (``mode="rows"``) or columns (``mode="cols"``).  All
        quantities are in *decomposed-axis units* -- the executor scales by
        ``PackGeometry.scale`` when the plan is a flattened N-D one.

        ``slab_start`` / ``slab_extent`` shift the runs into slab-local
        source coordinates: a consumer holding only its received slab (whose
        origin along the decomposed axis is ``slab_start`` and whose length
        is ``slab_extent``) gathers from a buffer where global index ``g``
        lives at local index ``g - slab_start``; a run falling outside
        ``[slab_start, slab_start + slab_extent)`` on EITHER side raises --
        clamped out-of-bounds tile DMAs would silently corrupt the block.
        """
        geom = self._resolve_geometry(mode)
        key = (dst_rank, tile_rows, mode, slab_start, slab_extent)
        with self._pack_lock:
            hit = self._pack_cache.get(key)
        if hit is not None:
            return hit
        runs = self.axis_runs(dst_rank, geom.axis)
        tiles: List[int] = []
        segs: List[Tuple[int, int]] = []
        for start, cnt in runs:
            start -= slab_start
            if start < 0 or (slab_extent is not None
                             and start + cnt > slab_extent):
                raise ValueError(
                    f"dst rank {dst_rank} needs axis-{geom.axis} run "
                    f"[{start + slab_start}, {start + slab_start + cnt}) but "
                    f"the slab covers [{slab_start}, "
                    f"{slab_start + (slab_extent if slab_extent is not None else 0)}"
                    f"); the slab does not cover this rank")
            t0 = start // tile_rows
            t1 = -(-(start + cnt) // tile_rows)
            segs.append((len(tiles) * tile_rows + (start - t0 * tile_rows), cnt))
            tiles.extend(range(t0, t1))
        result = (np.asarray(tiles, dtype=np.int32), tuple(segs))
        with self._pack_lock:
            self._pack_cache[key] = result
        return result

    def _resolve_geometry(self, mode: str) -> PackGeometry:
        """Geometry for an explicit ``mode`` request.  2-D plans honor a
        forced mode (either axis may be lowerable); N-D plans must match
        their detected geometry -- there is no alternative flattening."""
        if len(self.shape) == 2:
            return _geometry_for_axis(self.shape, 0 if mode == "rows" else 1)
        geom = self._pack_geom
        if geom is None or geom.mode != mode:
            raise ValueError(
                f"plan over shape {self.shape} has no {mode!r} lowering "
                f"(pack_mode={self.pack_mode!r})")
        return geom


def _pad_to_tiles(src, tile: int, axis: int):
    """Pad the (R, C) buffer so ``shape[axis]`` is a tile multiple (one copy,
    reused across every dst rank's gather -- the kernel then never re-pads)."""
    import jax.numpy as jnp

    pad = -src.shape[axis] % tile
    if not pad:
        return src
    widths = [(0, 0), (0, 0)]
    widths[axis] = (0, pad)
    return jnp.pad(src, widths)


def _resolve_pack_geom(plan: CompiledPlan, mode: Optional[str]) -> PackGeometry:
    if mode is None:
        geom = plan.pack_geometry
        if geom is None:
            raise ValueError(
                f"plan is not pack-kernel lowerable (shape {plan.shape}, "
                f"pack_mode={plan.pack_mode!r}); use the numpy scatter executors")
        return geom
    if mode not in ("rows", "cols"):
        raise ValueError(
            f"plan is not pack-kernel lowerable (shape {plan.shape}, "
            f"pack_mode={plan.pack_mode!r}); use the numpy scatter executors")
    return plan._resolve_geometry(mode)


def _flatten_and_pad(plan: CompiledPlan, src, geom: PackGeometry,
                     tile_rows: int, slab_box: Optional[Box]):
    """Flatten the (slab or global) device buffer onto the 2-D kernel frame
    and pad the decomposed axis up to tile granularity (one copy, reused for
    every dst rank's gather).  Returns ``(src2d, slab_start, slab_extent)``
    -- the slab's origin and length along the decomposed axis (the global
    extent when ``slab_box`` is None).

    ``slab_box`` declares that ``src`` holds only the slab
    ``(starts, shape)`` of the global index space; the slab must span the
    full extent of every non-decomposed axis (the shape a 1-D decomposition
    slot always has), and gathers then run in slab-local coordinates.
    """
    expect = tuple(plan.shape) if slab_box is None else tuple(slab_box[1])
    slab_start = 0
    slab_extent = plan.shape[geom.axis]
    if slab_box is not None:
        if not geom.covers_slab(slab_box, plan.shape):
            raise ValueError(
                f"slab {slab_box} does not span the full extent of every "
                f"non-decomposed axis of shape {plan.shape}; the kernel "
                f"lowering gathers along axis {geom.axis} only")
        slab_start = int(slab_box[0][geom.axis])
        slab_extent = int(slab_box[1][geom.axis])
    if len(src.shape) != len(expect) or any(
        s != e for a, (s, e) in enumerate(zip(src.shape, expect))
        if a != geom.axis
    ) or src.shape[geom.axis] < expect[geom.axis]:
        raise ValueError(
            f"pack source has shape {tuple(src.shape)}, expected "
            f"{expect} (axis {geom.axis} may be pre-padded)")
    # flatten: row-major bytes are already in kernel order (see PackGeometry)
    n_axis = int(src.shape[geom.axis])
    if geom.mode == "rows":
        src2d = src.reshape(n_axis, geom.cols)
        return _pad_to_tiles(src2d, tile_rows, 0), slab_start, slab_extent
    src2d = src.reshape(geom.rows, n_axis * geom.scale)
    return (_pad_to_tiles(src2d, tile_rows * geom.scale, 1),
            slab_start, slab_extent)


def _pack_gather(plan: CompiledPlan, dst_rank: int, src2d,
                 tile_rows: int, geom: PackGeometry, slab_start: int,
                 slab_extent: Optional[int] = None):
    """Gather one dst rank's block from the flattened+padded 2-D buffer and
    unflatten it back to the N-D destination block shape."""
    import jax.numpy as jnp

    from repro.kernels import ops

    dshape = plan.dst[dst_rank][1]
    tiles, segs = plan.pack_tiles(dst_rank, tile_rows, mode=geom.mode,
                                  slab_start=slab_start,
                                  slab_extent=slab_extent)
    if tiles.size == 0:
        return jnp.zeros(dshape, dtype=src2d.dtype)
    if geom.mode == "rows":
        packed = ops.pack_blocks(src2d, jnp.asarray(tiles), tile_rows=tile_rows)
        parts = [packed[a : a + c] for a, c in segs]
        out = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
    else:
        k = geom.scale
        packed = ops.pack_cols(src2d, jnp.asarray(tiles),
                               tile_cols=tile_rows * k)
        parts = [packed[:, a * k : (a + c) * k] for a, c in segs]
        out = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    return out.reshape(dshape)


def execute_pack_jax(plan: CompiledPlan, dst_rank: int, src,
                     tile_rows: int = 8, mode: Optional[str] = None,
                     slab_box: Optional[Box] = None):
    """Device-resident reshard: gather dst_rank's block with the Pallas pack
    kernels (``kernels.pack`` scalar-prefetch DMA tiles).

    ``src`` is the device buffer holding the global index space -- or, with
    ``slab_box=(starts, shape)``, only that slab of it (a received payload);
    gathers then run in slab-local source coordinates and every requested
    dst block must lie inside the slab.  Rank>2 buffers are flattened onto
    the 2-D kernel frame per the plan's ``PackGeometry`` and the gathered
    block is reshaped back -- the kernels themselves stay 2-D.

    ``mode`` picks the tile layout -- ``"rows"`` (``pack_blocks``, axis-0
    decompositions) or ``"cols"`` (``pack_cols``, any other axis); ``None``
    takes the plan's detected ``pack_mode``.  ``tile_rows`` is the tile
    extent in decomposed-axis units.  Tile offsets come from the cached plan
    lowering (``plan.pack_tiles``); ragged run boundaries are padded to tile
    granularity and trimmed back here.  Gathering several dst ranks from one
    buffer?  Use ``execute_pack_jax_all`` so the flatten/pad copy happens
    once, not per rank.  Runs in interpret mode on CPU, Mosaic on TPU.
    """
    geom = _resolve_pack_geom(plan, mode)
    src2d, slab_start, slab_extent = _flatten_and_pad(
        plan, src, geom, tile_rows, slab_box)
    return _pack_gather(plan, dst_rank, src2d, tile_rows, geom, slab_start,
                        slab_extent)


def execute_pack_jax_all(plan: CompiledPlan, src, tile_rows: int = 8,
                         mode: Optional[str] = None,
                         slab_box: Optional[Box] = None,
                         ranks: Optional[Sequence[int]] = None):
    """Gather dst-rank blocks (all of them, or just ``ranks``) from ONE
    device buffer -- the global extent, or a received slab (``slab_box``).

    Flattens and pads once for the whole exchange instead of once per
    kernel call, then reuses the 2-D buffer for each rank's tile gather.
    Returns the block list aligned to ``ranks`` (default: every dst rank).
    """
    geom = _resolve_pack_geom(plan, mode)
    src2d, slab_start, slab_extent = _flatten_and_pad(
        plan, src, geom, tile_rows, slab_box)
    wanted = range(len(plan.dst)) if ranks is None else ranks
    return [_pack_gather(plan, r, src2d, tile_rows, geom, slab_start,
                         slab_extent)
            for r in wanted]


class PlanCache:
    """Thread-safe LRU of compiled plans keyed on (src, dst, shape, dtype).

    Planning is O(M*N) index arithmetic per dataset; the key is pure shape
    metadata, so a steady-state workflow hits the cache on every step after
    the first.  ``snapshot()`` exposes hit/miss/eviction counters for the
    redistribution benchmark.
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = int(maxsize)
        self._lock = make_lock("leaf:plan_cache")
        self._plans: "OrderedDict[Tuple, CompiledPlan]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, src: Sequence[Box], dst: Sequence[Box],
            shape: Sequence[int], dtype: Any) -> CompiledPlan:
        key = (tuple(src), tuple(dst), tuple(int(s) for s in shape),
               np.dtype(dtype).str)
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self.hits += 1
                self._plans.move_to_end(key)
                return plan
            self.misses += 1
        # compile outside the lock -- planning may be slow for large M*N
        plan = CompiledPlan(src, dst, shape, dtype)
        with self._lock:
            self._plans[key] = plan
            self._plans.move_to_end(key)
            while len(self._plans) > self.maxsize:
                self._plans.popitem(last=False)
                self.evictions += 1
        return plan

    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            total = self.hits + self.misses
            return {
                "size": len(self._plans),
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": self.hits / total if total else 0.0,
            }

    def reset(self) -> None:
        with self._lock:
            self._plans.clear()
            self.hits = self.misses = self.evictions = 0


_PLAN_CACHE = PlanCache()


def plan_cache() -> PlanCache:
    return _PLAN_CACHE


def reset_plan_cache() -> None:
    _PLAN_CACHE.reset()


@dataclass(frozen=True)
class RedistSpec:
    """A consumer port's declared ownership, carried onto the Channel.

    The consumer task's ensemble instances spatially partition each matched
    dataset along ``axis`` into ``nslots`` slabs (instance ``slot`` owns slab
    ``slot``); within the instance, ``nranks`` logical ranks (``io_procs``
    when subset writers are declared) subdivide the slab.  The frozen
    dataclass doubles as the fan-out payload-cache key.
    """

    axis: int = 0
    nslots: int = 1
    slot: int = 0
    nranks: int = 1

    def dst_boxes(self, shape: Sequence[int]) -> Tuple[List[Box], List[Box]]:
        """(full N-rank dst decomposition, per-instance slot boxes).

        The full decomposition (all instances' ranks, slot-major) keys the
        plan cache so sibling channels of one edge share one compiled plan.
        """
        slot_boxes = even_blocks(shape, self.nslots, axis=self.axis)
        dst: List[Box] = []
        for b_starts, b_shape in slot_boxes:
            for starts, sh in even_blocks(b_shape, self.nranks, axis=self.axis):
                dst.append(
                    (tuple(s + b for s, b in zip(starts, b_starts)), sh))
        return dst, slot_boxes

    def my_ranks(self) -> range:
        return range(self.slot * self.nranks, (self.slot + 1) * self.nranks)


def redistribute_numpy(
    global_array: np.ndarray,
    src: Sequence[Box],
    dst: Sequence[Box],
) -> List[np.ndarray]:
    """Execute a plan: return the N consumer-rank blocks.

    ``global_array`` stands for the union of producer blocks (the runtime
    ships whole File objects; per-rank data would be stitched identically).
    Executed transfer-by-transfer so the byte accounting matches the plan.
    """
    plan = plan_redistribution(src, dst)
    outs: List[np.ndarray] = [
        np.empty(shape, dtype=global_array.dtype) for (_, shape) in dst
    ]
    for t in plan:
        g = tuple(slice(s, s + n) for s, n in zip(t.global_starts, t.shape))
        dstarts = dst[t.dst_rank][0]
        l = tuple(
            slice(gs - ds, gs - ds + n)
            for gs, ds, n in zip(t.global_starts, dstarts, t.shape)
        )
        outs[t.dst_rank][l] = global_array[g]
    return outs


def redistribute_cached(
    global_array: np.ndarray,
    src: Sequence[Box],
    dst: Sequence[Box],
    cache: Optional[PlanCache] = None,
) -> List[np.ndarray]:
    """Drop-in for ``redistribute_numpy`` through the plan cache: the O(M*N)
    intersection is computed once per (src, dst, shape, dtype) key and the
    coalesced scatter executor writes straight into per-rank blocks."""
    cache = cache or plan_cache()
    plan = cache.get(src, dst, global_array.shape, global_array.dtype)
    return plan.execute_global(global_array)


def gather_to_writers(ownership: BlockOwnership, io_procs: int) -> BlockOwnership:
    """Collapse ownership onto the first ``io_procs`` ranks (subset writers).

    With io_procs=1 this reproduces LAMMPS' gather-to-rank-0 idiom: rank 0
    owns the whole global extent and is the only rank participating in the
    data exchange; remaining ranks compute but do no I/O (paper §3.2.2).
    """
    if not ownership.blocks:
        return ownership
    ndim = len(next(iter(ownership.blocks.values()))[0])
    lo = [min(s[a] for s, _ in ownership.blocks.values()) for a in range(ndim)]
    hi = [
        max(s[a] + sh[a] for s, sh in ownership.blocks.values()) for a in range(ndim)
    ]
    global_box = (tuple(lo), tuple(h - l for l, h in zip(lo, hi)))
    blocks = even_blocks(global_box[1], io_procs, axis=0)
    out = BlockOwnership()
    for r, (starts, shape) in enumerate(blocks):
        shifted = tuple(s + l for s, l in zip(starts, lo))
        out.add(r, shifted, shape)
    return out


def reshard_jax(arr, target_sharding):
    """Reshard a jax.Array onto a consumer task's mesh (ICI path on a pod)."""
    import jax

    return jax.device_put(arr, target_sharding)
