"""M->N data redistribution -- the LowFive data-redistribution layer.

A producer running M (logical) ranks owns a dataset as M hyperslab blocks; a
consumer running N ranks wants it as N blocks.  LowFive plans which pieces of
which producer block each consumer rank needs and moves exactly those bytes.
We reproduce that planner (pure index arithmetic, testable to the byte) plus
the executors the transport hot path runs:

* ``CompiledPlan``   -- a plan compiled once into per-dst *coalesced* slab
  descriptors (adjacent transfers merged into contiguous runs) with an
  aligned-boundary detector: when every dst block coincides with exactly one
  src block the exchange degenerates to CoW views (zero bytes copied).
* ``PlanCache``      -- process-wide LRU keyed on (src blocks, dst blocks,
  shape, dtype); steady-state steps re-plan nothing (metadata is per-shape,
  not per-step).  ``Channel`` consults it on every served dataset.
* scatter executor   -- ``CompiledPlan.execute`` writes straight into
  preallocated per-rank destination blocks from per-rank source blocks; no
  global-array materialization, one numpy slice copy per coalesced run.
* JAX pack executor  -- ``execute_pack_jax`` lowers a cached plan's row runs
  to ``kernels.pack.pack_blocks`` scalar-prefetch DMA tiles (interpret mode
  on CPU, Mosaic on TPU) for device-resident reshard.
* ``reshard_jax``    -- resharding a ``jax.Array`` from the producer task's
  mesh layout onto the consumer task's mesh (``device_put`` with a target
  ``NamedSharding``; on a real pod XLA turns this into ICI transfers).

Subset writers (paper §3.2.2): ``gather_to_writers`` collapses an M-block
ownership onto the first k ranks, reproducing the LAMMPS rank-0 gather.
``RedistSpec`` is the per-channel declaration (decomposition axis + rank
counts from the consumer's YAML) the driver wires from the workflow graph.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .datamodel import BlockOwnership, Dataset

__all__ = [
    "even_blocks",
    "intersect",
    "Transfer",
    "plan_redistribution",
    "coalesce_transfers",
    "CompiledPlan",
    "PlanCache",
    "plan_cache",
    "reset_plan_cache",
    "RedistSpec",
    "redistribute_numpy",
    "redistribute_cached",
    "execute_pack_jax",
    "execute_pack_jax_all",
    "gather_to_writers",
    "reshard_jax",
]

Box = Tuple[Tuple[int, ...], Tuple[int, ...]]  # (starts, shape)


def even_blocks(shape: Sequence[int], nranks: int, axis: int = 0) -> List[Box]:
    """Even 1-D decomposition along ``axis`` (LowFive's default layout)."""
    shape = tuple(int(s) for s in shape)
    n = shape[axis]
    base, rem = divmod(n, nranks)
    out: List[Box] = []
    off = 0
    for r in range(nranks):
        cnt = base + (1 if r < rem else 0)
        starts = tuple(off if a == axis else 0 for a in range(len(shape)))
        bshape = tuple(cnt if a == axis else s for a, s in enumerate(shape))
        out.append((starts, bshape))
        off += cnt
    return out


def intersect(a: Box, b: Box) -> Optional[Box]:
    """Intersection of two boxes in global index space, or None."""
    starts, shape = [], []
    for (as_, ash), (bs_, bsh) in zip(zip(*a), zip(*b)):
        lo = max(as_, bs_)
        hi = min(as_ + ash, bs_ + bsh)
        if hi <= lo:
            return None
        starts.append(lo)
        shape.append(hi - lo)
    return tuple(starts), tuple(shape)


@dataclass(frozen=True)
class Transfer:
    """One piece: src_rank's block region -> dst_rank's block region."""

    src_rank: int
    dst_rank: int
    global_starts: Tuple[int, ...]
    shape: Tuple[int, ...]

    @property
    def nbytes_factor(self) -> int:
        return int(np.prod(self.shape))


def plan_redistribution(src: Sequence[Box], dst: Sequence[Box]) -> List[Transfer]:
    """All (src_rank, dst_rank, region) triples with nonempty overlap.

    This is the metadata-only planning step LowFive performs from the HDF5
    dataspace descriptions -- no data is touched.
    """
    out: List[Transfer] = []
    for dr, dbox in enumerate(dst):
        for sr, sbox in enumerate(src):
            ov = intersect(sbox, dbox)
            if ov is not None:
                out.append(Transfer(sr, dr, ov[0], ov[1]))
    return out


def coalesce_transfers(
    transfers: Sequence[Transfer], ignore_src: bool = False
) -> List[Transfer]:
    """Merge transfers that tile contiguously along one axis into single runs.

    By default only transfers with the same (src_rank, dst_rank) merge -- the
    scatter executor reads per-src-rank local blocks, so a run must stay
    inside one source block.  With ``ignore_src=True`` runs merge *across*
    source ranks (merged runs carry ``src_rank=-1``): the global-buffer
    executor reads one stitched array, so a dst block fed by k adjacent
    producer blocks collapses to one slice copy.  Merging is greedy over the
    start-sorted list: two boxes merge when they agree on every axis except
    one, where they abut.
    """
    out: List[Transfer] = []
    for t in sorted(transfers, key=lambda t: (t.dst_rank, t.global_starts, t.src_rank)):
        if out:
            p = out[-1]
            if p.dst_rank == t.dst_rank and (ignore_src or p.src_rank == t.src_rank):
                diff = [
                    a
                    for a in range(len(t.shape))
                    if p.global_starts[a] != t.global_starts[a]
                    or p.shape[a] != t.shape[a]
                ]
                if len(diff) == 1:
                    a = diff[0]
                    if (
                        p.global_starts[a] + p.shape[a] == t.global_starts[a]
                        and all(p.shape[b] == t.shape[b] for b in range(len(t.shape)) if b != a)
                    ):
                        merged = tuple(
                            p.shape[b] + t.shape[b] if b == a else p.shape[b]
                            for b in range(len(t.shape))
                        )
                        rank = p.src_rank if p.src_rank == t.src_rank else -1
                        out[-1] = Transfer(rank, p.dst_rank, p.global_starts, merged)
                        continue
        out.append(t)
    return out


class CompiledPlan:
    """A redistribution plan compiled once for a (src, dst, shape, dtype) key.

    ``per_dst[r]`` holds dst rank r's per-source slab descriptors (what the
    scatter executor copies out of each producer block); ``per_dst_runs[r]``
    holds the same bytes *coalesced across source ranks* into contiguous runs
    (what the global-buffer executor and the pack-kernel lowering walk -- a
    dst block fed by k adjacent producer blocks is one run, one copy).
    ``aligned`` marks the degenerate exchange where every dst block coincides
    with exactly one src block (boundaries line up), so the transport can
    ship CoW views with zero bytes copied instead of executing any transfer.
    """

    __slots__ = ("src", "dst", "shape", "dtype", "per_dst", "per_dst_runs",
                 "transfers", "identity", "aligned", "nbytes_planned",
                 "_pack_cache", "_pack_lock", "_pack_mode")

    def __init__(self, src: Sequence[Box], dst: Sequence[Box],
                 shape: Sequence[int], dtype: Any = np.float64):
        self.src: Tuple[Box, ...] = tuple(src)
        self.dst: Tuple[Box, ...] = tuple(dst)
        self.shape = tuple(int(s) for s in shape)
        self.dtype = np.dtype(dtype)
        raw = plan_redistribution(self.src, self.dst)
        per_dst: List[Tuple[Transfer, ...]] = []
        per_dst_runs: List[Tuple[Transfer, ...]] = []
        for dr in range(len(self.dst)):
            mine = [t for t in raw if t.dst_rank == dr]
            per_dst.append(tuple(coalesce_transfers(mine)))
            per_dst_runs.append(tuple(coalesce_transfers(mine, ignore_src=True)))
        self.per_dst: Tuple[Tuple[Transfer, ...], ...] = tuple(per_dst)
        self.per_dst_runs: Tuple[Tuple[Transfer, ...], ...] = tuple(per_dst_runs)
        self.transfers: Tuple[Transfer, ...] = tuple(
            t for slabs in per_dst for t in slabs)
        self.identity = self.src == self.dst
        self.aligned = self.identity or all(
            len(slabs) <= 1
            and all(
                (t.global_starts, t.shape) == self.dst[dr]
                and (t.global_starts, t.shape) == self.src[t.src_rank]
                for t in slabs
            )
            for dr, slabs in enumerate(self.per_dst)
        )
        self.nbytes_planned = (
            sum(t.nbytes_factor for t in self.transfers) * self.dtype.itemsize
        )
        self._pack_cache: Dict[Tuple[int, int, str], Tuple[np.ndarray, Tuple[Tuple[int, int], ...]]] = {}
        self._pack_lock = threading.Lock()
        self._pack_mode = self._compute_pack_mode()

    # ------------------------------------------------------------- executors
    def dst_bytes(self, ranks: Sequence[int]) -> int:
        """Planned bytes landing on the given dst ranks."""
        return sum(
            t.nbytes_factor for r in ranks for t in self.per_dst[r]
        ) * self.dtype.itemsize

    def execute(
        self,
        src_blocks: Sequence[np.ndarray],
        out: Optional[Sequence[np.ndarray]] = None,
        ranks: Optional[Sequence[int]] = None,
    ) -> List[np.ndarray]:
        """Scatter per-src-rank blocks into per-dst-rank blocks.

        ``src_blocks[r]`` is src rank r's local block (shape ``src[r][1]``).
        Writes go straight into ``out`` (preallocated per-rank destination
        blocks; allocated here if not given) -- the global array is never
        materialized, and each coalesced run is one numpy slice copy.
        ``ranks`` restricts the scatter to those dst ranks (the returned list
        is aligned to it) -- a consumer instance computes only its own blocks.
        """
        wanted = list(range(len(self.dst))) if ranks is None else list(ranks)
        if out is None:
            out = [np.empty(self.dst[r][1], dtype=self.dtype) for r in wanted]
        for i, dr in enumerate(wanted):
            dstarts = self.dst[dr][0]
            for t in self.per_dst[dr]:
                sstarts = self.src[t.src_rank][0]
                s_sl = tuple(
                    slice(g - s, g - s + n)
                    for g, s, n in zip(t.global_starts, sstarts, t.shape)
                )
                d_sl = tuple(
                    slice(g - s, g - s + n)
                    for g, s, n in zip(t.global_starts, dstarts, t.shape)
                )
                out[i][d_sl] = src_blocks[t.src_rank][s_sl]
        return list(out)

    def execute_global(
        self,
        global_array: np.ndarray,
        out: Optional[Sequence[np.ndarray]] = None,
        ranks: Optional[Sequence[int]] = None,
    ) -> List[np.ndarray]:
        """Scatter from the stitched global array (the in-process transport
        holds one buffer for all producer ranks) into per-dst-rank blocks.

        Walks ``per_dst_runs``: transfers coalesced across source ranks, so a
        dst block fed by k adjacent producer blocks is one slice copy.
        ``ranks`` restricts to those dst ranks, as in ``execute``."""
        wanted = list(range(len(self.dst))) if ranks is None else list(ranks)
        if out is None:
            out = [np.empty(self.dst[r][1], dtype=global_array.dtype)
                   for r in wanted]
        for i, dr in enumerate(wanted):
            dstarts = self.dst[dr][0]
            for t in self.per_dst_runs[dr]:
                g_sl = tuple(
                    slice(s, s + n) for s, n in zip(t.global_starts, t.shape)
                )
                d_sl = tuple(
                    slice(g - s, g - s + n)
                    for g, s, n in zip(t.global_starts, dstarts, t.shape)
                )
                out[i][d_sl] = global_array[g_sl]
        return list(out)

    # ----------------------------------------------------- pack-kernel lowering
    def _compute_pack_mode(self) -> Optional[str]:
        """Which pack-kernel layout covers this plan, if any.

        ``"rows"`` when every coalesced run is a full-width row slab (axis-0
        decompositions), ``"cols"`` when every run is a full-height column
        slab (axis-1), ``None`` for plans the kernel cannot DMA (non-2-D or
        mixed-axis tilings -- those take the numpy scatter executors).
        """
        if len(self.shape) != 2:
            return None
        rows, cols = self.shape
        runs = [t for slabs in self.per_dst_runs for t in slabs]
        if all(t.global_starts[1] == 0 and t.shape[1] == cols for t in runs):
            return "rows"
        if all(t.global_starts[0] == 0 and t.shape[0] == rows for t in runs):
            return "cols"
        return None

    @property
    def pack_mode(self) -> Optional[str]:
        return self._pack_mode

    def row_runs(self, dst_rank: int) -> List[Tuple[int, int]]:
        """dst_rank's needed global rows as coalesced (start, count) runs.

        Only valid for full-width row decompositions (2-D, every transfer
        spanning all columns) -- the layout ``kernels.pack.pack_blocks`` DMAs.
        """
        if len(self.shape) != 2:
            raise ValueError(f"row_runs needs a 2-D plan, got shape {self.shape}")
        cols = self.shape[1]
        runs: List[Tuple[int, int]] = []
        for t in self.per_dst_runs[dst_rank]:
            if t.global_starts[1] != 0 or t.shape[1] != cols:
                raise ValueError(
                    f"pack lowering needs full-width row slabs, got {t}")
            runs.append((t.global_starts[0], t.shape[0]))
        return runs

    def col_runs(self, dst_rank: int) -> List[Tuple[int, int]]:
        """dst_rank's needed global columns as coalesced (start, count) runs.

        The column twin of ``row_runs``: only valid for full-height column
        decompositions (2-D, every transfer spanning all rows) -- the layout
        ``kernels.pack.pack_cols`` DMAs for axis-1 reshards.
        """
        if len(self.shape) != 2:
            raise ValueError(f"col_runs needs a 2-D plan, got shape {self.shape}")
        rows = self.shape[0]
        runs: List[Tuple[int, int]] = []
        for t in self.per_dst_runs[dst_rank]:
            if t.global_starts[0] != 0 or t.shape[0] != rows:
                raise ValueError(
                    f"pack col lowering needs full-height column slabs, got {t}")
            runs.append((t.global_starts[1], t.shape[1]))
        return runs

    def pack_tiles(
        self, dst_rank: int, tile_rows: int = 8, mode: str = "rows"
    ) -> Tuple[np.ndarray, Tuple[Tuple[int, int], ...]]:
        """Lower dst_rank's runs to pack-kernel tile offsets (cached).

        Returns ``(tile_offsets, segments)``: the int32 source tile index per
        output tile (the kernel's scalar-prefetch operand) and, per run,
        ``(offset_in_packed_output, count)`` to trim the tile padding back to
        the exact rows (``mode="rows"``) or columns (``mode="cols"``).
        """
        key = (dst_rank, tile_rows, mode)
        with self._pack_lock:
            hit = self._pack_cache.get(key)
        if hit is not None:
            return hit
        runs = self.row_runs(dst_rank) if mode == "rows" else self.col_runs(dst_rank)
        tiles: List[int] = []
        segs: List[Tuple[int, int]] = []
        for start, cnt in runs:
            t0 = start // tile_rows
            t1 = -(-(start + cnt) // tile_rows)
            segs.append((len(tiles) * tile_rows + (start - t0 * tile_rows), cnt))
            tiles.extend(range(t0, t1))
        result = (np.asarray(tiles, dtype=np.int32), tuple(segs))
        with self._pack_lock:
            self._pack_cache[key] = result
        return result


def _pad_to_tiles(src, tile: int, axis: int):
    """Pad the (R, C) buffer so ``shape[axis]`` is a tile multiple (one copy,
    reused across every dst rank's gather -- the kernel then never re-pads)."""
    import jax.numpy as jnp

    pad = -src.shape[axis] % tile
    if not pad:
        return src
    widths = [(0, 0), (0, 0)]
    widths[axis] = (0, pad)
    return jnp.pad(src, widths)


def _resolve_pack_mode(plan: CompiledPlan, mode: Optional[str]) -> str:
    if mode is None:
        mode = plan.pack_mode
    if mode not in ("rows", "cols"):
        raise ValueError(
            f"plan is not pack-kernel lowerable (shape {plan.shape}, "
            f"pack_mode={plan.pack_mode!r}); use the numpy scatter executors")
    return mode


def execute_pack_jax(plan: CompiledPlan, dst_rank: int, src,
                     tile_rows: int = 8, mode: Optional[str] = None):
    """Device-resident reshard: gather dst_rank's slab with the Pallas pack
    kernel (``kernels.pack`` scalar-prefetch DMA tiles).

    ``src`` is the (R, C) device buffer holding the global index space.
    ``mode`` picks the tile layout -- ``"rows"`` (``pack_blocks``, axis-0
    decompositions) or ``"cols"`` (``pack_cols``, axis-1); ``None`` takes the
    plan's detected ``pack_mode``.  ``tile_rows`` is the tile extent along
    the decomposed axis.  The tile offsets come from the cached plan lowering
    (``plan.pack_tiles``); ragged run boundaries are padded to tile
    granularity and trimmed back here.  Gathering several dst ranks from one
    ragged buffer?  Use ``execute_pack_jax_all`` so the pad copy happens
    once, not per rank.  Runs in interpret mode on CPU, Mosaic on TPU.
    """
    import jax.numpy as jnp

    from repro.kernels import ops

    mode = _resolve_pack_mode(plan, mode)
    axis = 0 if mode == "rows" else 1
    tiles, segs = plan.pack_tiles(dst_rank, tile_rows, mode=mode)
    if tiles.size == 0:
        empty = (0, plan.shape[1]) if axis == 0 else (plan.shape[0], 0)
        return jnp.zeros(empty, dtype=src.dtype)
    padded = _pad_to_tiles(src, tile_rows, axis)
    if mode == "rows":
        packed = ops.pack_blocks(padded, jnp.asarray(tiles), tile_rows=tile_rows)
        parts = [packed[a : a + c] for a, c in segs]
    else:
        packed = ops.pack_cols(padded, jnp.asarray(tiles), tile_cols=tile_rows)
        parts = [packed[:, a : a + c] for a, c in segs]
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=axis)


def execute_pack_jax_all(plan: CompiledPlan, src, tile_rows: int = 8,
                         mode: Optional[str] = None):
    """Gather EVERY dst rank's block from one (R, C) device buffer.

    Pads the ragged tail once for the whole exchange instead of once per
    kernel call, then reuses the padded buffer for each rank's tile gather.
    Returns the per-dst-rank list of slab blocks.
    """
    mode = _resolve_pack_mode(plan, mode)
    src = _pad_to_tiles(src, tile_rows, 0 if mode == "rows" else 1)
    return [execute_pack_jax(plan, r, src, tile_rows=tile_rows, mode=mode)
            for r in range(len(plan.dst))]


class PlanCache:
    """Thread-safe LRU of compiled plans keyed on (src, dst, shape, dtype).

    Planning is O(M*N) index arithmetic per dataset; the key is pure shape
    metadata, so a steady-state workflow hits the cache on every step after
    the first.  ``snapshot()`` exposes hit/miss/eviction counters for the
    redistribution benchmark.
    """

    def __init__(self, maxsize: int = 256):
        self.maxsize = int(maxsize)
        self._lock = threading.Lock()
        self._plans: "OrderedDict[Tuple, CompiledPlan]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, src: Sequence[Box], dst: Sequence[Box],
            shape: Sequence[int], dtype: Any) -> CompiledPlan:
        key = (tuple(src), tuple(dst), tuple(int(s) for s in shape),
               np.dtype(dtype).str)
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self.hits += 1
                self._plans.move_to_end(key)
                return plan
            self.misses += 1
        # compile outside the lock -- planning may be slow for large M*N
        plan = CompiledPlan(src, dst, shape, dtype)
        with self._lock:
            self._plans[key] = plan
            self._plans.move_to_end(key)
            while len(self._plans) > self.maxsize:
                self._plans.popitem(last=False)
                self.evictions += 1
        return plan

    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            total = self.hits + self.misses
            return {
                "size": len(self._plans),
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": self.hits / total if total else 0.0,
            }

    def reset(self) -> None:
        with self._lock:
            self._plans.clear()
            self.hits = self.misses = self.evictions = 0


_PLAN_CACHE = PlanCache()


def plan_cache() -> PlanCache:
    return _PLAN_CACHE


def reset_plan_cache() -> None:
    _PLAN_CACHE.reset()


@dataclass(frozen=True)
class RedistSpec:
    """A consumer port's declared ownership, carried onto the Channel.

    The consumer task's ensemble instances spatially partition each matched
    dataset along ``axis`` into ``nslots`` slabs (instance ``slot`` owns slab
    ``slot``); within the instance, ``nranks`` logical ranks (``io_procs``
    when subset writers are declared) subdivide the slab.  The frozen
    dataclass doubles as the fan-out payload-cache key.
    """

    axis: int = 0
    nslots: int = 1
    slot: int = 0
    nranks: int = 1

    def dst_boxes(self, shape: Sequence[int]) -> Tuple[List[Box], List[Box]]:
        """(full N-rank dst decomposition, per-instance slot boxes).

        The full decomposition (all instances' ranks, slot-major) keys the
        plan cache so sibling channels of one edge share one compiled plan.
        """
        slot_boxes = even_blocks(shape, self.nslots, axis=self.axis)
        dst: List[Box] = []
        for b_starts, b_shape in slot_boxes:
            for starts, sh in even_blocks(b_shape, self.nranks, axis=self.axis):
                dst.append(
                    (tuple(s + b for s, b in zip(starts, b_starts)), sh))
        return dst, slot_boxes

    def my_ranks(self) -> range:
        return range(self.slot * self.nranks, (self.slot + 1) * self.nranks)


def redistribute_numpy(
    global_array: np.ndarray,
    src: Sequence[Box],
    dst: Sequence[Box],
) -> List[np.ndarray]:
    """Execute a plan: return the N consumer-rank blocks.

    ``global_array`` stands for the union of producer blocks (the runtime
    ships whole File objects; per-rank data would be stitched identically).
    Executed transfer-by-transfer so the byte accounting matches the plan.
    """
    plan = plan_redistribution(src, dst)
    outs: List[np.ndarray] = [
        np.empty(shape, dtype=global_array.dtype) for (_, shape) in dst
    ]
    for t in plan:
        g = tuple(slice(s, s + n) for s, n in zip(t.global_starts, t.shape))
        dstarts = dst[t.dst_rank][0]
        l = tuple(
            slice(gs - ds, gs - ds + n)
            for gs, ds, n in zip(t.global_starts, dstarts, t.shape)
        )
        outs[t.dst_rank][l] = global_array[g]
    return outs


def redistribute_cached(
    global_array: np.ndarray,
    src: Sequence[Box],
    dst: Sequence[Box],
    cache: Optional[PlanCache] = None,
) -> List[np.ndarray]:
    """Drop-in for ``redistribute_numpy`` through the plan cache: the O(M*N)
    intersection is computed once per (src, dst, shape, dtype) key and the
    coalesced scatter executor writes straight into per-rank blocks."""
    cache = cache or plan_cache()
    plan = cache.get(src, dst, global_array.shape, global_array.dtype)
    return plan.execute_global(global_array)


def gather_to_writers(ownership: BlockOwnership, io_procs: int) -> BlockOwnership:
    """Collapse ownership onto the first ``io_procs`` ranks (subset writers).

    With io_procs=1 this reproduces LAMMPS' gather-to-rank-0 idiom: rank 0
    owns the whole global extent and is the only rank participating in the
    data exchange; remaining ranks compute but do no I/O (paper §3.2.2).
    """
    if not ownership.blocks:
        return ownership
    ndim = len(next(iter(ownership.blocks.values()))[0])
    lo = [min(s[a] for s, _ in ownership.blocks.values()) for a in range(ndim)]
    hi = [
        max(s[a] + sh[a] for s, sh in ownership.blocks.values()) for a in range(ndim)
    ]
    global_box = (tuple(lo), tuple(h - l for l, h in zip(lo, hi)))
    blocks = even_blocks(global_box[1], io_procs, axis=0)
    out = BlockOwnership()
    for r, (starts, shape) in enumerate(blocks):
        shifted = tuple(s + l for s, l in zip(starts, lo))
        out.add(r, shifted, shape)
    return out


def reshard_jax(arr, target_sharding):
    """Reshard a jax.Array onto a consumer task's mesh (ICI path on a pod)."""
    import jax

    return jax.device_put(arr, target_sharding)
