"""M->N data redistribution -- the LowFive data-redistribution layer.

A producer running M (logical) ranks owns a dataset as M hyperslab blocks; a
consumer running N ranks wants it as N blocks.  LowFive plans which pieces of
which producer block each consumer rank needs and moves exactly those bytes.
We reproduce that planner (pure index arithmetic, testable to the byte) plus
two executors:

* numpy executor  -- used by the host-side workflow runtime and the paper's
  synthetic benchmarks;
* JAX executor    -- resharding a ``jax.Array`` from the producer task's mesh
  layout onto the consumer task's mesh (``device_put`` with a target
  ``NamedSharding``; on a real pod XLA turns this into ICI transfers, the
  interconnect path of the paper).

Subset writers (paper §3.2.2): ``gather_to_writers`` collapses an M-block
ownership onto the first k ranks, reproducing the LAMMPS rank-0 gather.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .datamodel import BlockOwnership, Dataset

__all__ = [
    "even_blocks",
    "intersect",
    "Transfer",
    "plan_redistribution",
    "redistribute_numpy",
    "gather_to_writers",
    "reshard_jax",
]

Box = Tuple[Tuple[int, ...], Tuple[int, ...]]  # (starts, shape)


def even_blocks(shape: Sequence[int], nranks: int, axis: int = 0) -> List[Box]:
    """Even 1-D decomposition along ``axis`` (LowFive's default layout)."""
    shape = tuple(int(s) for s in shape)
    n = shape[axis]
    base, rem = divmod(n, nranks)
    out: List[Box] = []
    off = 0
    for r in range(nranks):
        cnt = base + (1 if r < rem else 0)
        starts = tuple(off if a == axis else 0 for a in range(len(shape)))
        bshape = tuple(cnt if a == axis else s for a, s in enumerate(shape))
        out.append((starts, bshape))
        off += cnt
    return out


def intersect(a: Box, b: Box) -> Optional[Box]:
    """Intersection of two boxes in global index space, or None."""
    starts, shape = [], []
    for (as_, ash), (bs_, bsh) in zip(zip(*a), zip(*b)):
        lo = max(as_, bs_)
        hi = min(as_ + ash, bs_ + bsh)
        if hi <= lo:
            return None
        starts.append(lo)
        shape.append(hi - lo)
    return tuple(starts), tuple(shape)


@dataclass(frozen=True)
class Transfer:
    """One piece: src_rank's block region -> dst_rank's block region."""

    src_rank: int
    dst_rank: int
    global_starts: Tuple[int, ...]
    shape: Tuple[int, ...]

    @property
    def nbytes_factor(self) -> int:
        return int(np.prod(self.shape))


def plan_redistribution(src: Sequence[Box], dst: Sequence[Box]) -> List[Transfer]:
    """All (src_rank, dst_rank, region) triples with nonempty overlap.

    This is the metadata-only planning step LowFive performs from the HDF5
    dataspace descriptions -- no data is touched.
    """
    out: List[Transfer] = []
    for dr, dbox in enumerate(dst):
        for sr, sbox in enumerate(src):
            ov = intersect(sbox, dbox)
            if ov is not None:
                out.append(Transfer(sr, dr, ov[0], ov[1]))
    return out


def redistribute_numpy(
    global_array: np.ndarray,
    src: Sequence[Box],
    dst: Sequence[Box],
) -> List[np.ndarray]:
    """Execute a plan: return the N consumer-rank blocks.

    ``global_array`` stands for the union of producer blocks (the runtime
    ships whole File objects; per-rank data would be stitched identically).
    Executed transfer-by-transfer so the byte accounting matches the plan.
    """
    plan = plan_redistribution(src, dst)
    outs: List[np.ndarray] = [
        np.empty(shape, dtype=global_array.dtype) for (_, shape) in dst
    ]
    for t in plan:
        g = tuple(slice(s, s + n) for s, n in zip(t.global_starts, t.shape))
        dstarts = dst[t.dst_rank][0]
        l = tuple(
            slice(gs - ds, gs - ds + n)
            for gs, ds, n in zip(t.global_starts, dstarts, t.shape)
        )
        outs[t.dst_rank][l] = global_array[g]
    return outs


def gather_to_writers(ownership: BlockOwnership, io_procs: int) -> BlockOwnership:
    """Collapse ownership onto the first ``io_procs`` ranks (subset writers).

    With io_procs=1 this reproduces LAMMPS' gather-to-rank-0 idiom: rank 0
    owns the whole global extent and is the only rank participating in the
    data exchange; remaining ranks compute but do no I/O (paper §3.2.2).
    """
    if not ownership.blocks:
        return ownership
    ndim = len(next(iter(ownership.blocks.values()))[0])
    lo = [min(s[a] for s, _ in ownership.blocks.values()) for a in range(ndim)]
    hi = [
        max(s[a] + sh[a] for s, sh in ownership.blocks.values()) for a in range(ndim)
    ]
    global_box = (tuple(lo), tuple(h - l for l, h in zip(lo, hi)))
    blocks = even_blocks(global_box[1], io_procs, axis=0)
    out = BlockOwnership()
    for r, (starts, shape) in enumerate(blocks):
        shifted = tuple(s + l for s, l in zip(starts, lo))
        out.add(r, shifted, shape)
    return out


def reshard_jax(arr, target_sharding):
    """Reshard a jax.Array onto a consumer task's mesh (ICI path on a pod)."""
    import jax

    return jax.device_put(arr, target_sharding)
