"""Restricted-world communicators -- the PMPI-partitioning analogue.

Wilkins runs all tasks as one SPMD job and presents each task with a
*restricted* MPI_COMM_WORLD so task codes can be written as if they were the
only program running (paper §3.5).  In the JAX adaptation the resources being
partitioned are *devices* (and logical ranks for the host-side runtime): the
driver slices the global device list into disjoint per-task groups sized
proportionally to ``nprocs`` and hands every task a ``TaskComm`` that exposes

* ``size``/``rank``       -- the logical process view (nprocs from YAML),
* ``io_procs``            -- the subset-of-writers count (``nwriters`` field),
* ``devices`` / ``mesh()``-- the task's restricted JAX device group.

Task code obtains its communicator with ``comm.world()`` -- which returns the
restricted world inside a workflow and a trivial single-rank world standalone,
so the code is, again, identical in both settings.

``TaskComm.reshard`` is the user-facing face of the M->N redistribution
subsystem (paper §3.4): the driver wires each task's declared ``RedistSpec``s
onto the communicator, so task code reshards a device array / numpy array /
received Dataset into its per-rank blocks with ONE call -- no plan objects,
no executor choice.  Device-resident buffers (any rank, global extent or a
received slab) go through the Pallas pack kernels -- rank>2 plans flatten
their non-decomposed axes onto the 2-D kernels; host buffers and genuinely
cross-axis N-D decompositions take the numpy scatter executors.  Plans come
from the process-wide ``PlanCache``.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["TaskComm", "world", "push_comm", "pop_comm"]

_tls = threading.local()


@dataclass
class TaskComm:
    task: str = "__standalone__"
    instance: int = 0
    rank: int = 0
    size: int = 1
    io_procs: int = 1
    rank_offset: int = 0          # position in the global SPMD rank space
    devices: Optional[List[Any]] = None   # restricted JAX device group
    mesh_axes: Tuple[str, ...] = ("data",)
    extras: dict = field(default_factory=dict)
    # filename_pattern -> RedistSpec, wired by the driver from the task's
    # redistributing ports (consumer inports win over outports it feeds)
    redist_specs: Dict[str, Any] = field(default_factory=dict)
    # per-run SchedulerRuntime (driver-wired): lets task code mark explicit
    # step boundaries for the depth autotuner via ``comm.step()`` -- useful
    # for compute loops that do no file I/O between timesteps
    scheduler: Any = None
    # per-instance RecoveryContext (driver-wired when the run has a
    # supervisor): the checkpoint/restore surface below routes through it
    recovery: Any = None
    # the RunSupervisor itself (driver-wired alongside ``recovery``): the
    # programmatic rescale trigger below routes through it
    supervisor: Any = None
    # per-run SpanRecorder (driver-wired on traced runs): checkpoint /
    # restore / reshard below report themselves as spans when present
    tracer: Any = None

    def is_io_proc(self, rank: Optional[int] = None) -> bool:
        r = self.rank if rank is None else rank
        return r < self.io_procs

    def mesh(self, shape: Optional[Tuple[int, ...]] = None,
             axes: Optional[Tuple[str, ...]] = None):
        """Build a Mesh over this task's restricted device group.

        ``shape`` must fit inside the restricted world: asking for more
        devices than the driver granted this task raises a clear
        ``ValueError`` (instead of an opaque numpy reshape error) -- the fix
        is a bigger ``nprocs`` share in the workflow YAML, not a code change.
        """
        import numpy as np
        import jax

        devs = self.devices
        if devs is None:
            devs = jax.devices()[:1]
        if shape is None:
            shape = (len(devs),)
        shape = tuple(int(s) for s in shape)
        need = int(np.prod(shape)) if shape else 1
        if need > len(devs):
            raise ValueError(
                f"task {self.task!r}: mesh shape {shape} needs {need} "
                f"devices but this task's restricted device group holds "
                f"only {len(devs)}; grow the task's nprocs share (or shrink "
                f"the mesh)")
        if axes is None:
            axes = self.mesh_axes[: len(shape)]
        arr = np.asarray(devs[:need]).reshape(shape)
        return jax.sharding.Mesh(arr, axes)

    def barrier(self) -> None:  # single-process runtime: no-op
        pass

    def step(self) -> None:
        """Mark an explicit step boundary for the runtime scheduler.

        File closes (producers) and intercepted opens (consumers) already
        count as step events; a task whose timestep loop does neither can
        call this so the depth autotuner / telemetry sampler still tick at
        its cadence.  No-op standalone (no workflow scheduler wired)."""
        if self.scheduler is not None:
            self.scheduler.notify_step("comm_step")
        if self.supervisor is not None:
            # an explicit step is proof of life for the stall watchdog too
            self.supervisor.heartbeat(self.task, self.instance)

    # ------------------------------------------------- checkpoint / restore
    @property
    def attempt(self) -> int:
        """Which incarnation of this task instance is running (0 = first
        launch; restarts increment).  0 standalone."""
        return self.recovery.attempt if self.recovery is not None else 0

    @property
    def epoch(self) -> int:
        """The channel epoch this incarnation serves/receives under."""
        return self.recovery.epoch if self.recovery is not None else 0

    def checkpoint(self, state: Any, step: Optional[int] = None,
                   block: bool = True,
                   sharded_axes: Optional[Dict[str, int]] = None
                   ) -> Optional[int]:
        """Snapshot ``state`` (any pytree) for crash recovery.

        Routed through the run's ``AsyncCheckpointer`` (atomic container +
        LATEST pointer under the run's spill dir) and then *acks* this
        instance's channels: everything served/delivered so far is durable,
        so a restart replays only what came after this call.  Returns the
        checkpoint step, or ``None`` standalone (no recovery wired) -- task
        code is identical in and out of a workflow.

        ``sharded_axes`` maps top-level keys of a flat dict ``state`` to the
        axis along which that leaf is this instance's shard of a global
        array.  Required for tasks under an elastic ``rescale:`` policy: a
        rescale re-cuts those leaves across the new instance count and
        asserts every other leaf is replicated.

        ``block=True`` (default) makes the save durable before acking; see
        DESIGN.md for the cadence/overhead trade."""
        if self.recovery is None:
            return None
        if self.tracer is None:
            return self.recovery.checkpoint(state, step=step, block=block,
                                            sharded_axes=sharded_axes)
        t0 = time.monotonic()
        out = self.recovery.checkpoint(state, step=step, block=block,
                                       sharded_axes=sharded_axes)
        self.tracer.record("checkpoint", "ckpt.save", self.task,
                           self.instance, t0, time.monotonic(), step=out,
                           blocking=block)
        return out

    def rescale(self, task: Optional[str] = None, *,
                nslots: Optional[int] = None,
                nprocs: Optional[int] = None,
                reason: str = "") -> Any:
        """Programmatic elastic-rescale trigger (``RunSupervisor.rescale``).

        Requests that ``task`` (default: this task) be brought down and
        relaunched at a different instance count (``nslots``) and/or logical
        rank count (``nprocs``), replaying undelivered steps into the
        re-partitioned consumers.  Returns the ``RescaleOp`` handle (its
        ``done`` event fires when the surgery completes), or ``None``
        standalone."""
        if self.supervisor is None:
            return None
        return self.supervisor.rescale(task or self.task, nslots=nslots,
                                       nprocs=nprocs, reason=reason)

    def restore(self, like: Any) -> Optional[Tuple[int, Any]]:
        """(step, state) from this instance's newest checkpoint, or ``None``
        on a fresh start (including standalone).  Call it first thing in the
        task function; a restarted incarnation resumes instead of redoing
        work.  ``like`` supplies the pytree structure/shapes (shape-checked
        on load)."""
        if self.recovery is None:
            return None
        if self.tracer is None:
            return self.recovery.restore(like)
        t0 = time.monotonic()
        out = self.recovery.restore(like)
        self.tracer.record("checkpoint", "ckpt.restore", self.task,
                           self.instance, t0, time.monotonic(),
                           step=out[0] if out is not None else None,
                           fresh=out is None)
        return out

    # ------------------------------------------------------------- reshard
    def resolve_redist_spec(self, spec: Any = None, port: Optional[str] = None):
        """The ``RedistSpec`` governing this task's reshards.

        Explicit ``spec`` wins; else ``port`` names the filename pattern of a
        wired redistributing port; else the task must have exactly one
        distinct spec wired by the driver."""
        if spec is not None:
            return spec
        if port is not None:
            try:
                return self.redist_specs[port]
            except KeyError:
                raise ValueError(
                    f"task {self.task!r} has no RedistSpec for port {port!r}; "
                    f"wired ports: {sorted(self.redist_specs)}") from None
        distinct = set(self.redist_specs.values())
        if len(distinct) == 1:
            return next(iter(distinct))
        if not distinct:
            raise ValueError(
                f"task {self.task!r} has no RedistSpec wired; declare "
                f"`redistribute:` on a port in the workflow YAML or pass spec=")
        raise ValueError(
            f"task {self.task!r} has {len(distinct)} distinct RedistSpecs "
            f"(ports {sorted(self.redist_specs)}); pass port= or spec=")

    def reshard(self, data, spec: Any = None, *, port: Optional[str] = None,
                src: Optional[Sequence[Any]] = None, ranks: Any = "mine",
                tile_rows: int = 8, prefer: str = "auto") -> List[Any]:
        """Reshard an array (or received Dataset) into per-rank blocks.

        The one-call face of the M->N subsystem: resolves the task's
        ``RedistSpec`` (see ``resolve_redist_spec``), pulls the
        ``CompiledPlan`` through the process-wide ``PlanCache``, and picks
        the executor -- the Pallas pack kernels for device-resident 2-D
        arrays whose plan lowers to row/column tiles, the numpy scatter
        executors otherwise.  Task code never touches plan objects.

        Parameters
        ----------
        data:   a ``jax.Array`` / ``np.ndarray`` holding the GLOBAL index
                space, or a ``datamodel.Dataset`` -- either a producer-side
                dataset (its ``ownership`` becomes the src decomposition) or
                a consumer-side slab received over a redistributing channel
                (recognised by its ``redist_*`` attrs; scatter reads straight
                from the slab, no global buffer is ever stitched).
        spec/port: see ``resolve_redist_spec``.
        src:    explicit src decomposition (list of (starts, shape) boxes)
                for raw arrays; default one global block.
        ranks:  ``"mine"`` (this instance's logical ranks -- the default),
                ``"all"`` (every dst rank of the full decomposition), or an
                explicit iterable of dst rank ids.
        tile_rows: pack-kernel tile extent along the decomposed axis.
        prefer: ``"auto"`` | ``"pack"`` (raise if the kernel path cannot
                serve) | ``"numpy"``.

        Returns the per-rank block list aligned to ``ranks`` (jax arrays on
        the pack path, numpy arrays on the scatter path).

        Executor dispatch: the Pallas pack kernels serve any device-resident
        buffer (a ``jax.Array``, or a Dataset whose backing buffer lives on
        device) whose plan is decomposed along a single axis -- any rank
        (rank>2 plans flatten onto the 2-D kernels, see
        ``redistribute.PackGeometry``), over the global extent OR a received
        slab (gathers then run in slab-local source coordinates).  Only
        host-resident data and genuinely cross-axis N-D decompositions take
        the numpy scatter executors.
        """
        import numpy as np

        from .datamodel import Dataset
        from .redistribute import execute_pack_jax_all, intersect, plan_cache

        if prefer not in ("auto", "pack", "numpy"):
            raise ValueError(f"prefer must be auto|pack|numpy, got {prefer!r}")
        rspec = self.resolve_redist_spec(spec, port)

        slab_box = None
        if isinstance(data, Dataset):
            arr = data.read_direct()
            if "redist_box_starts" in data.attrs:
                # a received slab: its attrs carry the global frame
                gshape = tuple(int(s) for s in data.attrs["redist_global_shape"])
                slab_box = (tuple(int(s) for s in data.attrs["redist_box_starts"]),
                            tuple(arr.shape))
                src_boxes = [slab_box]
            elif data.ownership is not None and data.ownership.blocks:
                gshape = tuple(arr.shape)
                src_boxes = [data.ownership.blocks[r]
                             for r in sorted(data.ownership.blocks)]
            else:
                gshape = tuple(arr.shape)
                src_boxes = [((0,) * arr.ndim, gshape)]
        else:
            arr = data
            gshape = tuple(int(s) for s in arr.shape)
            src_boxes = ([(tuple(s), tuple(sh)) for s, sh in src]
                         if src is not None else [((0,) * len(gshape), gshape)])

        dst, _ = rspec.dst_boxes(gshape)
        if ranks == "mine":
            if rspec.slot < 0:
                raise ValueError(
                    f"task {self.task!r} is a PRODUCER for this "
                    f"redistributing port -- it has no 'mine' in the "
                    f"consumer decomposition; pass ranks=\"all\", explicit "
                    f"rank ids, or an explicit spec")
            wanted = list(rspec.my_ranks())
        elif ranks == "all":
            wanted = list(range(len(dst)))
        else:
            wanted = [int(r) for r in ranks]
        bad = [r for r in wanted if not 0 <= r < len(dst)]
        if bad:
            raise ValueError(f"dst ranks {bad} out of range for the "
                             f"{len(dst)}-block decomposition of {rspec}")
        pc = plan_cache()
        hits0 = pc.hits  # plan-cache verdict for the reshard span (traced
        cache = None     # runs only; racy across threads, advisory only)
        plan = pc.get(src_boxes, dst, gshape, arr.dtype)
        if self.tracer is not None:
            cache = "hit" if pc.hits > hits0 else "miss"

        if slab_box is not None:
            # an instance reshards what it was shipped: every wanted dst box
            # must sit inside the received slab (kernel and numpy path alike)
            for r in wanted:
                if intersect(dst[r], slab_box) != dst[r]:
                    raise ValueError(
                        f"dst rank {r} block {dst[r]} is not covered by the "
                        f"received slab {slab_box}; reshard the slab only "
                        f"onto ranks {list(rspec.my_ranks())}")

        # Probe the READ BUFFER, not the wrapper: a Dataset backed by a
        # device array reshards on the kernel path exactly like a raw
        # jax.Array (checking `data` here used to silently drop every
        # device-resident Dataset onto the numpy executors).
        is_jax = False
        if prefer != "numpy":
            try:
                import jax
                is_jax = isinstance(arr, jax.Array)
            except ImportError:  # numpy-only deployment
                pass
        geom = plan.pack_geometry
        slab_pack_ok = slab_box is None or (
            geom is not None and geom.covers_slab(slab_box, gshape))
        expect_shape = plan.shape if slab_box is None else tuple(slab_box[1])
        can_pack = (is_jax and geom is not None and slab_pack_ok
                    and tuple(arr.shape) == expect_shape)
        if prefer == "pack" and not can_pack:
            raise ValueError(
                "pack-kernel path unavailable: needs a device-resident "
                "buffer (jax.Array or device-backed Dataset) over the "
                "global extent or a received slab, and a single-axis "
                f"lowerable plan (got type={type(data).__name__}, "
                f"buffer={type(arr).__name__}, shape={tuple(arr.shape)}, "
                f"pack_mode={plan.pack_mode!r}, slab={slab_box!r})")
        from .datamodel import transport_stats
        transport_stats().record_reshard(pack=can_pack)
        tr = self.tracer
        t0 = time.monotonic()
        if can_pack:
            out = execute_pack_jax_all(plan, arr, tile_rows=tile_rows,
                                       slab_box=slab_box, ranks=wanted)
        else:
            np_arr = np.asarray(arr)
            if slab_box is not None:
                # scatter straight out of the slab (src_boxes == [slab_box])
                out = plan.execute([np_arr], ranks=wanted)
            else:
                out = plan.execute_global(np_arr, ranks=wanted)
        if tr is not None:
            tr.record("reshard",
                      "reshard.pack" if can_pack else "reshard.numpy",
                      self.task, self.instance, t0, time.monotonic(),
                      bytes=int(arr.nbytes), cache=cache,
                      ranks=len(wanted))
        return out


def world() -> TaskComm:
    """The task's restricted world (or a standalone single-rank world)."""
    stack = getattr(_tls, "comm_stack", None)
    if stack and stack[-1] is not None:
        return stack[-1]
    return TaskComm()


def push_comm(c: Optional[TaskComm]) -> None:
    if not hasattr(_tls, "comm_stack"):
        _tls.comm_stack = []
    _tls.comm_stack.append(c)


def pop_comm() -> None:
    _tls.comm_stack.pop()
