"""Restricted-world communicators -- the PMPI-partitioning analogue.

Wilkins runs all tasks as one SPMD job and presents each task with a
*restricted* MPI_COMM_WORLD so task codes can be written as if they were the
only program running (paper §3.5).  In the JAX adaptation the resources being
partitioned are *devices* (and logical ranks for the host-side runtime): the
driver slices the global device list into disjoint per-task groups sized
proportionally to ``nprocs`` and hands every task a ``TaskComm`` that exposes

* ``size``/``rank``       -- the logical process view (nprocs from YAML),
* ``io_procs``            -- the subset-of-writers count (``nwriters`` field),
* ``devices`` / ``mesh()``-- the task's restricted JAX device group.

Task code obtains its communicator with ``comm.world()`` -- which returns the
restricted world inside a workflow and a trivial single-rank world standalone,
so the code is, again, identical in both settings.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, List, Optional, Sequence, Tuple

__all__ = ["TaskComm", "world", "push_comm", "pop_comm"]

_tls = threading.local()


@dataclass
class TaskComm:
    task: str = "__standalone__"
    instance: int = 0
    rank: int = 0
    size: int = 1
    io_procs: int = 1
    rank_offset: int = 0          # position in the global SPMD rank space
    devices: Optional[List[Any]] = None   # restricted JAX device group
    mesh_axes: Tuple[str, ...] = ("data",)
    extras: dict = field(default_factory=dict)

    def is_io_proc(self, rank: Optional[int] = None) -> bool:
        r = self.rank if rank is None else rank
        return r < self.io_procs

    def mesh(self, shape: Optional[Tuple[int, ...]] = None,
             axes: Optional[Tuple[str, ...]] = None):
        """Build a Mesh over this task's restricted device group."""
        import numpy as np
        import jax

        devs = self.devices
        if devs is None:
            devs = jax.devices()[:1]
        if shape is None:
            shape = (len(devs),)
        if axes is None:
            axes = self.mesh_axes[: len(shape)]
        arr = np.asarray(devs[: int(np.prod(shape))]).reshape(shape)
        return jax.sharding.Mesh(arr, axes)

    def barrier(self) -> None:  # single-process runtime: no-op
        pass


def world() -> TaskComm:
    """The task's restricted world (or a standalone single-rank world)."""
    stack = getattr(_tls, "comm_stack", None)
    if stack and stack[-1] is not None:
        return stack[-1]
    return TaskComm()


def push_comm(c: Optional[TaskComm]) -> None:
    if not hasattr(_tls, "comm_stack"):
        _tls.comm_stack = []
    _tls.comm_stack.append(c)


def pop_comm() -> None:
    _tls.comm_stack.pop()
