"""Producer->consumer channels with Wilkins' three flow-control strategies.

A Channel couples one producer task *instance* to one consumer task *instance*
for one matched (filename pattern, dataset patterns) port pair.  Channels are
created by the driver from the data-centric YAML matching (``graph.py``) --
users never construct them.

Flow control (paper §3.6), selected by ``io_freq``:

* ``all``    (io_freq in {0,1}) -- rendezvous: the producer blocks at file
  close until the consumer has taken the previous item (queue of depth 1).
* ``some``   (io_freq = N > 1) -- the producer serves only every Nth file
  close; skipped closes drop the data immediately and the producer continues.
* ``latest`` (io_freq = -1)    -- the producer serves only if the consumer is
  currently waiting for data; otherwise it skips this timestep.  Older data
  are never queued, so the consumer always sees the freshest snapshot.

The channel also implements the producer-query protocol of §3.5.1: when the
producer finishes it marks the channel done; a consumer ``get()`` after that
returns ``None`` ("all done"), which is how stateful consumers exit their loop
and how the driver decides to stop relaunching stateless consumers.

Every state transition is recorded as a timestamped event so benchmarks can
reconstruct the paper's Fig. 5 Gantt charts.
"""

from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from .datamodel import File, match_file, match_path

__all__ = ["FlowControl", "Channel", "ChannelStats"]


class FlowControl:
    ALL = "all"
    SOME = "some"
    LATEST = "latest"

    @staticmethod
    def from_io_freq(io_freq: int) -> Tuple[str, int]:
        """Decode the paper's io_freq field: 0/1 -> all, N>1 -> some(N), -1 -> latest."""
        if io_freq in (0, 1):
            return FlowControl.ALL, 1
        if io_freq > 1:
            return FlowControl.SOME, int(io_freq)
        if io_freq == -1:
            return FlowControl.LATEST, 1
        raise ValueError(f"invalid io_freq {io_freq}")


@dataclass
class ChannelStats:
    served: int = 0
    dropped: int = 0
    bytes_moved: int = 0
    producer_wait_s: float = 0.0
    consumer_wait_s: float = 0.0
    events: List[Tuple[float, str, str]] = field(default_factory=list)  # (t, who, what)


class Channel:
    """One producer-instance -> consumer-instance coupling for one file port."""

    def __init__(
        self,
        name: str,
        producer: Tuple[str, int],
        consumer: Tuple[str, int],
        filename_pattern: str,
        dset_patterns: Sequence[str],
        mode: str = "memory",  # "memory" (in-situ) | "file" (spill through disk)
        io_freq: int = 1,
        spill_dir: Optional[str] = None,
        record_events: bool = False,
    ):
        self.name = name
        self.producer = producer
        self.consumer = consumer
        self.filename_pattern = filename_pattern
        self.dset_patterns = list(dset_patterns)
        assert mode in ("memory", "file"), mode
        self.mode = mode
        self.strategy, self.freq = FlowControl.from_io_freq(io_freq)
        self.spill_dir = spill_dir or os.path.join("/tmp", "wilkins_spill")
        self.record_events = record_events

        self._lock = threading.Condition()
        self._item: Optional[Any] = None  # depth-1 slot (rendezvous semantics)
        self._done = False
        self._consumer_waiting = 0
        self._close_count = 0
        self.stats = ChannelStats()

    # ------------------------------------------------------------------ util
    def _event(self, who: str, what: str) -> None:
        if self.record_events:
            self.stats.events.append((time.monotonic(), who, what))

    def matches_file(self, filename: str) -> bool:
        return match_file(self.filename_pattern, filename) or match_file(
            filename, self.filename_pattern
        )

    def filter_file(self, f: File) -> File:
        """Data-centric selection: ship only the datasets this port asked for."""
        out = File(f.filename)
        out.attrs.update(f.attrs)
        n = 0
        for ds in f.visit_datasets():
            if any(match_path(p, ds.path) for p in self.dset_patterns):
                nd = out.create_dataset(ds.path, data=ds.read_direct())
                nd.attrs.update(ds.attrs)
                nd.ownership = ds.ownership
                n += 1
        return out

    # ------------------------------------------------------------- producer
    def offer(self, f: File) -> bool:
        """Producer-side serve with flow control. Returns True if served.

        Called from the VOL layer at (after-)file-close time, mirroring
        LowFive's serve-on-close. The flow-control decision happens *before*
        any data is copied or queued, so a skipped timestep costs nothing --
        that is the entire point of the paper's §3.6.
        """
        with self._lock:
            self._close_count += 1
            if self.strategy == FlowControl.SOME and (self._close_count % self.freq) != 0:
                self.stats.dropped += 1
                self._event("producer", "skip_some")
                return False
            if self.strategy == FlowControl.LATEST and self._consumer_waiting == 0:
                # No incoming request from the consumer: skip this timestep
                # and proceed to generating the next one (paper §3.6).
                self.stats.dropped += 1
                self._event("producer", "skip_latest")
                return False

        payload = self._prepare(f)
        t0 = time.monotonic()
        with self._lock:
            self._event("producer", "wait_begin")
            while self._item is not None and not self._done:
                self._lock.wait()
            self.stats.producer_wait_s += time.monotonic() - t0
            self._event("producer", "wait_end")
            if self._done:
                return False
            self._item = payload
            self.stats.served += 1
            self.stats.bytes_moved += f.total_bytes()
            self._event("producer", "serve")
            self._lock.notify_all()
        return True

    def _prepare(self, f: File) -> Any:
        sub = self.filter_file(f)
        if self.mode == "file":
            # Spill through "disk" -- the paper's ``file: 1`` transport path.
            path = sub.save(self.spill_dir)
            return ("file", path)
        return ("memory", sub)

    def finish(self) -> None:
        """Producer signals all-done (query protocol: empty filename list)."""
        with self._lock:
            self._done = True
            self._event("producer", "done")
            self._lock.notify_all()

    # ------------------------------------------------------------- consumer
    def get(self, timeout: Optional[float] = None) -> Optional[File]:
        """Consumer-side blocking receive; None means producer is all-done."""
        t0 = time.monotonic()
        with self._lock:
            self._consumer_waiting += 1
            self._lock.notify_all()  # wake a producer doing `latest` rendezvous
            self._event("consumer", "wait_begin")
            try:
                while self._item is None and not self._done:
                    if not self._lock.wait(timeout=timeout):
                        return None
                self.stats.consumer_wait_s += time.monotonic() - t0
                self._event("consumer", "wait_end")
                if self._item is None:
                    return None  # all done
                kind, payload = self._item
                self._item = None
                self._lock.notify_all()
            finally:
                self._consumer_waiting -= 1
        self._event("consumer", "recv")
        if kind == "file":
            return File.load(payload)
        return payload

    def peek_pending(self) -> bool:
        with self._lock:
            return self._item is not None

    def is_done(self) -> bool:
        with self._lock:
            return self._done and self._item is None

    def __repr__(self) -> str:
        return (
            f"<Channel {self.name} {self.producer}->{self.consumer} "
            f"{self.filename_pattern} mode={self.mode} fc={self.strategy}/{self.freq}>"
        )
