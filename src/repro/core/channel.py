"""Producer->consumer channels with Wilkins' three flow-control strategies.

A Channel couples one producer task *instance* to one consumer task *instance*
for one matched (filename pattern, dataset patterns) port pair.  Channels are
created by the driver from the data-centric YAML matching (``graph.py``) --
users never construct them.

Flow control (paper §3.6), selected by ``io_freq``:

* ``all``    (io_freq in {0,1}) -- rendezvous: the producer blocks at file
  close until a queue slot frees up (bounded ring queue of ``queue_depth``
  items, default 1 = the paper's depth-1 rendezvous; depth >= 2 pipelines the
  producer ahead of the consumer).
* ``some``   (io_freq = N > 1) -- the producer serves only every Nth file
  close; skipped closes drop the data immediately and the producer continues.
* ``latest`` (io_freq = -1)    -- the producer serves only if the consumer is
  currently waiting for data; otherwise it skips this timestep.  Older data
  are never queued, so the consumer always sees the freshest snapshot.

Transport fast path: ``filter_file`` ships copy-on-write dataset *views*
(``Dataset.view``), so a fan-out of N channels serves ONE filtered payload --
the per-dataset ``_Share`` refcount tracks the sharing and the first consumer
write materializes a private copy.  Pass ``zero_copy=False`` to get the old
materialize-per-channel behaviour (the benchmark's legacy baseline).

The channel also implements the producer-query protocol of §3.5.1: when the
producer finishes it marks the channel done; a consumer ``get()`` after that
returns ``None`` ("all done"), which is how stateful consumers exit their loop
and how the driver decides to stop relaunching stateless consumers.  A
``get(timeout=...)`` that elapses raises ``ChannelTimeout`` -- timeouts are
*not* conflated with producer-done.

Every state transition is recorded as a timestamped event so benchmarks can
reconstruct the paper's Fig. 5 Gantt charts.
"""

from __future__ import annotations

import os
import re
import threading
import time
from collections import deque
from concurrent.futures import CancelledError, Future
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..analysis.lockcheck import (check_blocking, hb_consume, hb_publish,
                                  make_condition, make_lock, sched_point)
from ..obs.recorder import flow_id
from .datamodel import (BlockOwnership, File, compile_file_pattern,
                        compile_path_pattern, transport_stats)
from .redistribute import RedistSpec, plan_cache
from .scheduler import FifoPolicy, QueuePolicy, ResizableSemaphore

__all__ = [
    "FlowControl",
    "Channel",
    "ChannelStats",
    "ChannelTimeout",
    "ChannelError",
    "ChannelMux",
    "NO_DATA",
    "PrefetchPool",
    "configure_prefetch_pool",
    "shutdown_prefetch_pool",
    "DEFAULT_PREFETCH_DEPTH",
]


class ChannelTimeout(Exception):
    """``Channel.get(timeout=...)`` elapsed with no data and no producer-done."""


class ChannelError(Exception):
    """The peer producer failed permanently (poison pill).

    Raised by ``get``/``try_get`` the moment the driver poisons the channel
    -- a consumer blocked on a dead producer learns *which* task died and
    why (the producer's exception is chained as ``__cause__``) instead of
    waiting out its timeout for an opaque ``ChannelTimeout``.  Carries
    ``task`` and ``instance`` of the dead producer.
    """

    def __init__(self, msg: str, task: str = "?", instance: int = -1):
        super().__init__(msg)
        self.task = task
        self.instance = instance


class _NoData:
    """Sentinel: channel queue is empty but the producer is still live."""

    _instance: Optional["_NoData"] = None

    def __new__(cls) -> "_NoData":
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:
        return "NO_DATA"


NO_DATA = _NoData()


# --- nested-wait accounting guard (satellite: counter consistency) ----------
# The VOL mux loop accounts its whole multiplexed wait into the channel that
# finally delivers; a ``get()`` on one of those same channels issued INSIDE
# that scope (e.g. from an ``after_file_open`` callback) must not add its own
# wait to ``consumer_wait_s`` again.  The scope is per-thread and nestable.
_MUX_WAIT_SCOPE = threading.local()


def enter_mux_wait_scope(channels: Sequence["Channel"]) -> frozenset:
    """Mark ``channels`` as wait-accounted by the caller; returns the token
    to pass to :func:`exit_mux_wait_scope` (the previous scope)."""
    prev = getattr(_MUX_WAIT_SCOPE, "ids", frozenset())
    _MUX_WAIT_SCOPE.ids = prev | frozenset(id(c) for c in channels)
    return prev


def exit_mux_wait_scope(token: frozenset) -> None:
    """Restore the previous scope (idempotent: tokens nest)."""
    _MUX_WAIT_SCOPE.ids = token


def _in_mux_wait_scope(ch: "Channel") -> bool:
    return id(ch) in getattr(_MUX_WAIT_SCOPE, "ids", frozenset())


class FlowControl:
    ALL = "all"
    SOME = "some"
    LATEST = "latest"

    @staticmethod
    def from_io_freq(io_freq: int) -> Tuple[str, int]:
        """Decode the paper's io_freq field: 0/1 -> all, N>1 -> some(N), -1 -> latest."""
        if io_freq in (0, 1):
            return FlowControl.ALL, 1
        if io_freq > 1:
            return FlowControl.SOME, int(io_freq)
        if io_freq == -1:
            return FlowControl.LATEST, 1
        raise ValueError(
            f"invalid io_freq {io_freq}: use 0/1 (all), N>1 (some: every "
            f"Nth step), or -1 (latest)")


#: default ring size for per-channel event timelines (satellite: bounded so
#: ``record_events=True`` cannot grow memory without limit on long runs)
EVENTS_MAXLEN = 4096

#: default per-edge prefetch depth when a redistributing port does not set
#: ``prefetch: N`` in YAML (max in-flight payload preps on that edge)
DEFAULT_PREFETCH_DEPTH = 2


class PrefetchPool:
    """Shared executor for asynchronous payload preparation (slab prefetch).

    Channels with a RedistSpec enqueue a *future* of the filtered payload, so
    slab construction / eager copies / spill writes overlap with both the
    producer's rendezvous wait and the consumer's compute on the previous
    step.  Unlike ``concurrent.futures.ThreadPoolExecutor`` (whose non-daemon
    workers are joined at interpreter exit -- a payload prep stuck in I/O
    then hangs process shutdown, and a pool nobody shuts down leaks its
    workers across runs), this pool:

    * runs DAEMON workers, so a wedged prep can never hang interpreter exit;
    * supports ``shutdown()``: queued-but-unstarted preps are *cancelled*
      (their futures resolve to CancelledError, which still fires their
      done-callbacks, so per-edge depth slots are released -- the slot-leak
      regression) and workers drain and stop;
    * arbitrates pending preps through a pluggable ``QueuePolicy``
      (``scheduler.FifoPolicy`` -- the default, bit-for-bit the old single
      deque -- or ``scheduler.FairPolicy``, deficit-weighted round-robin by
      per-edge YAML ``weight:``);
    * is created per ``Wilkins.run`` (sized to the run's total prefetch
      depth, policy from the YAML ``scheduler:`` block) and shut down on
      both the success and error paths -- standalone ``Channel`` use falls
      back to a lazy module-level default.
    """

    def __init__(self, max_workers: int = 2,
                 thread_name_prefix: str = "wilkins-prefetch",
                 policy: Optional[QueuePolicy] = None):
        self._cv = make_condition("pool:prefetch")
        self._policy: QueuePolicy = policy if policy is not None else FifoPolicy()
        self._shutdown = False
        # Error accounting (never drop a prep exception on the floor): every
        # prep a worker starts is tracked in ``_inflight`` until it settles;
        # a prep that settles with an exception is remembered in ``_errored``
        # so ``drain_errors`` can report any error the consumer never
        # observed via ``fut.result()`` -- the shutdown-race audit.
        self._inflight: set = set()
        self._errored: List[Future] = []
        self._threads = [
            threading.Thread(target=self._worker,
                             name=f"{thread_name_prefix}-{i}", daemon=True)
            for i in range(max(1, int(max_workers)))
        ]
        for t in self._threads:
            t.start()

    def submit(self, fn: Callable, *args, edge: Optional[str] = None,
               weight: int = 1) -> Future:
        """Enqueue a prep; ``edge``/``weight`` feed the queue policy (the
        FIFO policy ignores them, so plain ``submit(fn)`` is unchanged)."""
        fut: Future = Future()
        fut._wilkins_edge = edge  # type: ignore[attr-defined]
        with self._cv:
            if self._shutdown:
                raise RuntimeError("prefetch pool is shut down")
            self._policy.push((fut, fn, args), edge=edge, weight=weight)
            self._cv.notify()
        return fut

    def _worker(self) -> None:
        while True:
            with self._cv:
                while not self._policy.pending() and not self._shutdown:
                    self._cv.wait()
                if not self._policy.pending():
                    return  # shutdown and drained
                item = self._policy.pop()
                if item is not None:
                    # claimed under the SAME cv hold as the pop: drain_errors
                    # can never observe "not pending, not in flight" for a
                    # prep a worker is about to run
                    self._inflight.add(item[0])
            if item is None:  # policy raced empty (defensive)
                continue
            fut, fn, args = item
            try:
                if fut.set_running_or_notify_cancel():
                    try:
                        fut.set_result(fn(*args))
                    except BaseException as e:  # surfaced via fut.result()
                        fut.set_exception(e)
            finally:
                with self._cv:
                    self._inflight.discard(fut)
                    if (fut.done() and not fut.cancelled()
                            and fut.exception() is not None):
                        self._errored.append(fut)
                    self._cv.notify_all()

    def drain_errors(self, timeout: Optional[float] = 5.0) -> List[Tuple[Optional[str], BaseException]]:
        """Wait (bounded) for in-flight preps to settle, then return every
        prep exception no consumer observed, as ``(edge, exception)`` pairs.

        This closes the shutdown race: ``shutdown(cancel_pending=True)``
        cancels *queued* preps, but a prep already running on a worker can
        still error after teardown -- with nobody left to call
        ``fut.result()``, the exception used to vanish.  The driver calls
        this after every run and attaches the result to the
        ``WorkflowReport``.  Errors the consumer did re-raise (delivery
        marks the future observed) are not double-reported."""
        deadline = None if timeout is None else time.monotonic() + timeout
        out: List[Tuple[Optional[str], BaseException]] = []
        with self._cv:
            while self._inflight or self._policy.pending():
                remaining = None if deadline is None else deadline - time.monotonic()
                if remaining is not None and remaining <= 0:
                    break
                self._cv.wait(timeout=remaining)
            for fut in self._errored:
                if not getattr(fut, "_wilkins_observed", False):
                    fut._wilkins_observed = True  # type: ignore[attr-defined]
                    out.append((getattr(fut, "_wilkins_edge", None),
                                fut.exception()))
        return out

    def shutdown(self, cancel_pending: bool = True) -> None:
        """Stop accepting work; cancel queued preps; wake and drain workers.

        Running preps are left to finish on their (daemon) worker -- there is
        no way to interrupt them, but they can no longer block exit.
        ``Future.cancel`` fires done-callbacks, so every cancelled prep still
        releases its edge's depth slot (no leak, no over-release)."""
        with self._cv:
            self._shutdown = True
            pending = self._policy.drain() if cancel_pending else []
            self._cv.notify_all()
        for fut, _, _ in pending:
            fut.cancel()

    def alive_workers(self) -> int:
        return sum(t.is_alive() for t in self._threads)


_PREFETCH_POOL: Optional[PrefetchPool] = None
_PREFETCH_POOL_LOCK = make_lock("leaf:prefetch_pool_global")


def _prefetch_pool() -> PrefetchPool:
    global _PREFETCH_POOL
    if _PREFETCH_POOL is None:
        with _PREFETCH_POOL_LOCK:
            if _PREFETCH_POOL is None:
                _PREFETCH_POOL = PrefetchPool(max_workers=2)
    return _PREFETCH_POOL


def configure_prefetch_pool(max_workers: int) -> PrefetchPool:
    """Install a fresh module-default pool (standalone use / tests); any
    previous default is shut down, its queued preps cancelled.  Workflow
    runs do NOT go through the global: ``Wilkins.run`` builds its own pool
    and injects it per channel, so concurrent runs in one process cannot
    cancel each other's in-flight preps."""
    global _PREFETCH_POOL
    with _PREFETCH_POOL_LOCK:
        old, _PREFETCH_POOL = _PREFETCH_POOL, PrefetchPool(max_workers)
        pool = _PREFETCH_POOL
    if old is not None:
        old.shutdown()
    return pool


def shutdown_prefetch_pool() -> None:
    """Shut down the module-default pool (cancelling queued preps) and reset
    the global, so the next standalone use starts from a clean pool."""
    global _PREFETCH_POOL
    with _PREFETCH_POOL_LOCK:
        pool, _PREFETCH_POOL = _PREFETCH_POOL, None
    if pool is not None:
        pool.shutdown()


@dataclass
class ChannelStats:
    served: int = 0
    dropped: int = 0
    bytes_moved: int = 0
    producer_wait_s: float = 0.0
    consumer_wait_s: float = 0.0
    # Per-EDGE prefetch accounting (the process-wide TransportStats keeps the
    # aggregate): the depth autotuner and the telemetry timeline both need to
    # attribute hits/misses/blocked seconds to the edge that earned them.
    prefetch_hits: int = 0
    prefetch_misses: int = 0
    prefetch_cancelled: int = 0
    prefetch_prepared_s: float = 0.0
    prefetch_blocked_s: float = 0.0
    inflight_preps: int = 0  # gauge: preps submitted but not yet done
    # Recovery accounting: serves a restarted producer regenerated that the
    # consumer already held (skipped), payloads requeued for replay after a
    # consumer restart, and preps re-run synchronously after an async prep
    # error (mid-prefetch crash recovery).
    deduped: int = 0
    replayed: int = 0
    prep_retries: int = 0
    # (t, who, what) ring: oldest events roll off past the maxlen, counted
    # in ``events_dropped`` so Gantt consumers know the timeline is truncated
    events: Deque[Tuple[float, str, str]] = field(
        default_factory=lambda: deque(maxlen=EVENTS_MAXLEN))
    events_dropped: int = 0


class ChannelMux:
    """Condition-variable multiplexer: wait for ANY registered channel to
    serve or finish, without polling.

    A channel bumps the mux version (``notify``) on every state change; the
    waiter snapshots the version (``token``) *before* scanning channels, so a
    serve that lands between the scan and the wait is never missed.
    """

    def __init__(self) -> None:
        self._cond = make_condition("leaf:mux")
        self._version = 0

    def notify(self) -> None:
        with self._cond:
            self._version += 1
            self._cond.notify_all()

    def token(self) -> int:
        with self._cond:
            return self._version

    def wait(self, token: int, timeout: Optional[float] = None) -> int:
        """Block until the version moves past ``token`` (or timeout); the
        caller rescans its channels either way, so spurious wakeups are safe."""
        with self._cond:
            if self._version == token:
                self._cond.wait(timeout)  # wilkins: ignore[WLK302] -- caller
                # rescans its channels on every return, so a spurious wakeup
                # or missed-notify race costs one extra scan, never a hang
            return self._version


class Channel:
    """One producer-instance -> consumer-instance coupling for one file port."""

    def __init__(
        self,
        name: str,
        producer: Tuple[str, int],
        consumer: Tuple[str, int],
        filename_pattern: str,
        dset_patterns: Sequence[str],
        mode: str = "memory",  # "memory" (in-situ) | "file" (spill through disk)
        io_freq: int = 1,
        spill_dir: Optional[str] = None,
        record_events: bool = False,
        queue_depth: int = 1,
        zero_copy: bool = True,
        redistribute: Optional[RedistSpec] = None,
        prefetch: Optional[Union[bool, int]] = None,
        events_maxlen: int = EVENTS_MAXLEN,
        weight: int = 1,
        autotune: Optional[Tuple[int, int]] = None,
    ):
        self.name = name
        self.producer = producer
        self.consumer = consumer
        self.filename_pattern = filename_pattern
        self.dset_patterns = list(dset_patterns)
        assert mode in ("memory", "file"), mode
        self.mode = mode
        self.strategy, self.freq = FlowControl.from_io_freq(io_freq)
        self.spill_dir = spill_dir or os.path.join("/tmp", "wilkins_spill")
        self.record_events = record_events
        if queue_depth < 1:
            raise ValueError(f"queue_depth must be >= 1, got {queue_depth}")
        self.queue_depth = int(queue_depth)
        self.zero_copy = bool(zero_copy)
        self.redistribute = redistribute
        # Async payload preparation: ``prefetch`` is the PER-EDGE depth --
        # the max number of in-flight preps on this channel (0 = synchronous
        # serve).  On by default (DEFAULT_PREFETCH_DEPTH) exactly when the
        # channel carries a RedistSpec (slab construction is the serve-side
        # work worth hiding); the YAML inport knob ``prefetch: N`` overrides
        # (0 = off, N >= 1 = depth).  Depth is enforced by a per-channel
        # semaphore over the shared sized pool, so one hot edge cannot
        # monopolize every prefetch worker.
        if prefetch is None:
            depth = DEFAULT_PREFETCH_DEPTH if redistribute is not None else 0
        elif isinstance(prefetch, bool):
            depth = DEFAULT_PREFETCH_DEPTH if prefetch else 0
        else:
            depth = int(prefetch)
        if depth < 0:
            raise ValueError(f"prefetch depth must be >= 0, got {depth}")
        # Scheduling knobs (see scheduler.py): ``weight`` feeds the fair
        # (DWRR) queue policy; ``autotune=(min, max)`` bounds the depth
        # autotuner and implies prefetch -- the initial depth is clamped
        # into the bounds, so an autotuned edge always starts async.
        if weight < 1:
            raise ValueError(f"scheduler weight must be >= 1, got {weight}")
        self.weight = int(weight)
        if autotune is not None:
            amin, amax = int(autotune[0]), int(autotune[1])
            if amin < 1:
                raise ValueError(
                    f"autotune min depth must be >= 1, got {amin} "
                    f"(use prefetch: 0 to disable prefetch instead)")
            if amax < amin:
                raise ValueError(
                    f"autotune bounds must satisfy min <= max, got "
                    f"[{amin}, {amax}]")
            autotune = (amin, amax)
            depth = min(max(depth, amin), amax)
        self.autotune = autotune
        self.prefetch = depth
        self._prefetch_sem = ResizableSemaphore(depth) if depth else None
        # run-scoped pool injected by the driver (None = module default)
        self._prefetch_pool: Optional[PrefetchPool] = None

        # precompiled matchers (LRU-cached globally, pinned here for the hot path)
        self._file_matcher = compile_file_pattern(filename_pattern)
        self._dset_matchers = [compile_path_pattern(p) for p in self.dset_patterns]
        # filename -> bool memo: the reverse compile in matches_file otherwise
        # runs on every serve/open for every non-matching filename
        self._match_cache: Dict[str, bool] = {}

        self._lock = make_condition(f"channel.cv:{filename_pattern}")
        # bounded ring (queue_depth) of (kind, payload, seq, epoch, src):
        # positions 0/1 are the pre-recovery item layout; ``seq`` is the
        # producer's serve ordinal (dedup watermark), ``epoch`` the
        # incarnation that queued it, ``src`` the source File kept for
        # synchronous prep retry (recovery runs only, else None)
        self._queue: Deque[Tuple[str, Any, int, int, Any]] = deque()
        self._done = False
        # --- recovery protocol state (see recovery.py) -------------------
        # producer side: serve seqs are strictly monotonic; ack_producer
        # snapshots them at a checkpoint so quarantine_producer can rewind.
        self._serve_seq = 0
        self._acked_seq = 0
        self._acked_close_count = 0
        # consumer side: delivered watermark + ack snapshot + the
        # delivered-but-unacked payloads quarantine_consumer will replay.
        self._delivered_seq = 0
        self._acked_delivered_seq = 0
        self._replay: List[Tuple[str, Any, int, int, Any]] = []
        self._replay_enabled = False
        self._epoch = 0
        self._poison: Optional[Tuple[str, int, BaseException]] = None
        self._abandoned = False
        self._prep_retry = False
        # --- elastic rescale state (see recovery.RescaleOp) --------------
        # consumer interrupt: raised out of get/try_get to pull the consumer
        # thread out of its callable so the task can be resized
        self._interrupt: Optional[BaseException] = None
        # producer grace: a retiring channel lets blocked offers complete
        # immediately (ring may transiently exceed depth) so the feeding
        # producer drains out of the rendezvous before the channel swap
        self._grace = False
        # retention ring: when the consumer's policy is a rescale, acked
        # payloads move here (instead of being discarded) so the surgery can
        # re-cut every step after the consistent cut, even for sibling
        # instances that checkpointed ahead of it
        self._retention = False
        self._retained: Deque[Tuple[str, Any, int, int, Any]] = deque()
        self._supervisor: Optional[Any] = None  # RunSupervisor (fault hook)
        self._tracer: Optional[Any] = None      # obs.SpanRecorder (run-scoped)
        # Waiter accounting for the `latest` rendezvous decision: one entry
        # per *distinct consumer thread* currently blocked on this channel,
        # with a nesting depth so a thread registered by the VOL mux
        # (``set_consumer_waiting``) that then blocks in ``get`` still counts
        # once, not twice (double counting skewed the fan-in decision).
        self._waiters: Dict[int, int] = {}
        self._close_count = 0
        self._spill_seq = 0
        self._listeners: List[ChannelMux] = []
        self.stats = ChannelStats(events=deque(maxlen=int(events_maxlen)))

    # ------------------------------------------------------------------ util
    def _event_locked(self, who: str, what: str) -> None:
        if self.record_events:
            ev = self.stats.events
            if ev.maxlen is not None and len(ev) == ev.maxlen:
                self.stats.events_dropped += 1
            ev.append((time.monotonic(), who, what))

    def set_prefetch_pool(self, pool: Optional["PrefetchPool"]) -> None:
        """Attach the run-scoped prefetch pool (driver-owned); ``None``
        detaches and falls back to the lazy module default."""
        self._prefetch_pool = pool

    def set_tracer(self, tracer: Optional[Any]) -> None:
        """Attach the run's ``SpanRecorder`` (None = untraced: every hook
        site below is a single attribute load + None test)."""
        self._tracer = tracer

    def stats_snapshot(self) -> Dict[str, Any]:
        """Point-in-time scalar counters, read under the owning lock --
        the error-report path must never see a half-updated struct (same
        discipline astlint WLK30x enforces on the happy-path mutations)."""
        with self._lock:
            s = self.stats
            return {
                "served": s.served, "dropped": s.dropped,
                "bytes_moved": s.bytes_moved,
                "producer_wait_s": s.producer_wait_s,
                "consumer_wait_s": s.consumer_wait_s,
                "prefetch_hits": s.prefetch_hits,
                "prefetch_misses": s.prefetch_misses,
                "prefetch_cancelled": s.prefetch_cancelled,
                "prefetch_prepared_s": s.prefetch_prepared_s,
                "prefetch_blocked_s": s.prefetch_blocked_s,
                "inflight_preps": s.inflight_preps,
                "deduped": s.deduped, "replayed": s.replayed,
                "prep_retries": s.prep_retries,
                "events_dropped": s.events_dropped,
            }

    # ----------------------------------------------------------- recovery
    def set_supervisor(self, sup: Optional[Any]) -> None:
        """Attach the run's ``RunSupervisor`` (fault-injection hook for the
        async prep path); ``None`` detaches on teardown."""
        self._supervisor = sup

    def set_replay(self, enabled: bool) -> None:
        """Track delivered-but-unacked payloads for consumer-restart replay.

        Only enabled when the consumer's policy is a managed restart -- the
        buffer grows until the consumer checkpoints (cadence guidance in
        DESIGN.md), so always-on would leak on checkpoint-free runs."""
        with self._lock:
            self._replay_enabled = bool(enabled)
            if not enabled:
                self._replay.clear()

    def set_prep_retry(self, enabled: bool) -> None:
        """Recover async prep errors by re-running the (idempotent) prep
        synchronously at delivery instead of failing the consumer."""
        self._prep_retry = bool(enabled)

    def ack_producer(self) -> None:
        """Producer checkpointed: serves so far are durable.  A later
        ``quarantine_producer`` keeps them queued and rewinds the serve/flow
        counters to exactly this point."""
        with self._lock:
            self._acked_seq = self._serve_seq
            self._acked_close_count = self._close_count

    def ack_consumer(self) -> None:
        """Consumer checkpointed: deliveries so far are consumed.  The
        replay buffer empties (into the retention ring when a rescale may
        need to re-cut consumed steps); a later ``quarantine_consumer``
        replays only payloads delivered after this point."""
        with self._lock:
            self._acked_delivered_seq = self._delivered_seq
            if self._retention and self._replay:
                self._retained.extend(self._replay)
            self._replay.clear()

    def set_retention(self, enabled: bool, cap: int = 512) -> None:
        """Keep acked payloads in a bounded ring for rescale re-cutting.

        Only enabled when the consumer's ``on_failure`` policy is a rescale:
        a sibling instance may checkpoint (and ack) steps *past* the
        consistent cut, and the surgery must still re-partition those steps
        for the new instances.  The ring is CoW views, so retention holds
        references, not copies."""
        with self._lock:
            self._retention = bool(enabled)
            self._retained = deque(maxlen=int(cap)) if enabled else deque()

    @property
    def delivered_seq(self) -> int:
        """Consumer-side delivery watermark (checkpoint sidecar feed)."""
        with self._lock:
            return self._delivered_seq

    def _discard_item_locked(self, item: Tuple[str, Any, int, int, Any]) -> None:
        """Drop one queued item (caller holds the lock): cancel an unfinished
        prep (marking it observed so ``drain_errors`` does not report a
        deliberately-quarantined crash), unlink a spill file."""
        kind, payload = item[0], item[1]
        self.stats.dropped += 1
        if kind == "future":
            payload._wilkins_observed = True
            if not payload.cancel():
                self.stats.prefetch_cancelled += 1
                transport_stats().record_prefetch_cancelled()
        elif kind == "file":
            try:
                os.unlink(payload)
            except OSError:
                pass

    def quarantine_producer(self, epoch: int) -> None:
        """The producer incarnation died: drop its un-acked queued payloads
        (the restart regenerates them from the checkpoint; in-flight prefetch
        futures are cancelled, spills unlinked), keep acked-but-undelivered
        ones, and rewind the serve/flow-control counters to the last ack so
        the replayed closes line up.  Waiters are woken to re-rendezvous
        against the new epoch."""
        sched_point("Channel.quarantine_producer", key=("chan", id(self)))
        with self._lock:
            kept: Deque[Tuple[str, Any, int, int, Any]] = deque()
            for item in self._queue:
                if item[2] > self._acked_seq:
                    self._discard_item_locked(item)
                else:
                    kept.append(item)
            self._queue = kept
            self._serve_seq = self._acked_seq
            self._close_count = self._acked_close_count
            self._epoch = max(self._epoch, epoch)
            self._event_locked("producer", f"quarantine:epoch={epoch}")
            if self._tracer is not None:
                self._tracer.instant("recovery", "channel.quarantine_producer",
                                     self.producer[0], self.producer[1],
                                     edge=self.name, epoch=epoch)
            self._lock.notify_all()
        self._notify_listeners()

    def quarantine_consumer(self, epoch: int) -> None:
        """The consumer incarnation died: requeue every delivered-but-unacked
        payload at the head (oldest first) and rewind the dedup watermark to
        the last ack, so the restarted consumer replays exactly the steps it
        had not checkpointed.  A producer blocked in ``offer`` keeps waiting
        for ring space and re-rendezvouses with the new incarnation."""
        sched_point("Channel.quarantine_consumer", key=("chan", id(self)))
        with self._lock:
            if self._replay:
                for item in reversed(self._replay):
                    self._queue.appendleft(item)
                self.stats.replayed += len(self._replay)
                self._replay = []
            self._delivered_seq = self._acked_delivered_seq
            self._epoch = max(self._epoch, epoch)
            self._event_locked("consumer", f"quarantine:epoch={epoch}")
            if self._tracer is not None:
                self._tracer.instant("recovery", "channel.quarantine_consumer",
                                     self.consumer[0], self.consumer[1],
                                     edge=self.name, epoch=epoch)
            self._lock.notify_all()
        self._notify_listeners()

    def poison(self, task: str, instance: int, error: BaseException) -> None:
        """Producer failed permanently: wake blocked consumers with a
        ``ChannelError`` naming the dead task (chained to its exception)
        instead of letting them time out.  Already-queued payloads still
        deliver first -- they were produced before the failure."""
        with self._lock:
            self._poison = (task, instance, error)
            self._event_locked("producer", "poison")
            if self._tracer is not None:
                self._tracer.instant("recovery", "channel.poison", task,
                                     instance, edge=self.name,
                                     error=type(error).__name__)
            self._lock.notify_all()
        self._notify_listeners()

    def abandon_consumer(self) -> None:
        """Consumer gone for good (dropped / failed permanently): queued
        payloads are discarded and every future ``offer`` becomes a counted
        drop, so the producer runs on unimpeded instead of parking in the
        rendezvous wait until the join deadline."""
        with self._lock:
            self._abandoned = True
            for item in self._queue:
                self._discard_item_locked(item)
            self._queue.clear()
            self._event_locked("consumer", "abandoned")
            self._lock.notify_all()
        self._notify_listeners()

    # ------------------------------------------------------- elastic rescale
    def interrupt_consumer(self, exc: BaseException) -> None:
        """Pull the consumer out of this channel: the next (or currently
        blocked) ``get``/``try_get`` raises ``exc`` instead of delivering.
        Used by the rescale protocol to stop sibling instances at a step
        boundary; not an error path -- queued data stays queued and is
        re-cut for the new partition."""
        sched_point("Channel.interrupt_consumer", key=("chan", id(self)))
        with self._lock:
            self._interrupt = exc
            self._event_locked("consumer", "interrupt")
            if self._tracer is not None:
                self._tracer.instant("rescale", "channel.interrupt",
                                     self.consumer[0], self.consumer[1],
                                     edge=self.name)
            self._lock.notify_all()
        self._notify_listeners()

    def rescale_release_producer(self) -> None:
        """Retire-side grace: complete any blocked ``offer`` immediately
        (the ring may transiently exceed ``queue_depth``) so the feeding
        producer drains out of its rendezvous before the channel swap."""
        sched_point("Channel.rescale_release_producer", key=("chan", id(self)))
        with self._lock:
            self._grace = True
            self._event_locked("producer", "rescale_grace")
            self._lock.notify_all()
        self._notify_listeners()

    def rescale_snapshot(self) -> Dict[str, Any]:
        """Counters + every step the surgery may need to re-cut: the
        retention ring (acked), the replay buffer (delivered, unacked) and
        the queue (undelivered).  Items may still be payload *futures*; the
        caller resolves them outside this lock."""
        sched_point("Channel.rescale_snapshot", key=("chan", id(self)))
        with self._lock:
            return {
                "serve_seq": self._serve_seq,
                "acked_seq": self._acked_seq,
                "close_count": self._close_count,
                "acked_close_count": self._acked_close_count,
                "delivered_seq": self._delivered_seq,
                "acked_delivered_seq": self._acked_delivered_seq,
                "done": self._done,
                "items": list(self._retained) + list(self._replay)
                         + list(self._queue),
            }

    def rescale_adopt(self, *, serve_seq: int, acked_seq: int,
                      close_count: int, acked_close_count: int, done: bool,
                      epoch: int, delivered_floor: int) -> None:
        """Initialize a freshly built channel as the continuation of a
        retired edge at a new partition: producer-side counters carry over
        verbatim (the producer's serve ordinals and flow-control phase must
        not restart), the consumer-side watermark rewinds to the consistent
        cut so the preloaded replay delivers, and the epoch is bumped past
        every retired incarnation."""
        sched_point("Channel.rescale_adopt", key=("chan", id(self)))
        with self._lock:
            self._serve_seq = serve_seq
            self._acked_seq = acked_seq
            self._close_count = close_count
            self._acked_close_count = acked_close_count
            self._delivered_seq = delivered_floor
            self._acked_delivered_seq = delivered_floor
            self._done = bool(done)
            self._epoch = max(self._epoch, epoch)
            self._event_locked("producer", f"rescale_adopt:epoch={epoch}")

    def rescale_preload(self, payload: File, seq: int) -> None:
        """Queue one re-partitioned replay payload on an adopted channel
        (bypasses flow control: the seq was already assigned -- and any
        some/latest skipping already applied -- on the retired edge)."""
        sched_point("Channel.rescale_preload", key=("chan", id(self)))
        with self._lock:
            self._queue.append(("memory", payload, seq, self._epoch, None))
            self.stats.replayed += 1
            self.stats.served += 1
            self._event_locked("producer", "rescale_replay")
            self._lock.notify_all()
        self._notify_listeners()

    @property
    def epoch(self) -> int:
        with self._lock:
            return self._epoch

    def set_depth(self, depth: int) -> None:
        """Retune the per-edge prefetch depth at runtime (autotuner hook).

        The new depth is applied under the channel lock, then the in-flight
        semaphore is resized: growing wakes producers blocked in ``offer``;
        shrinking lets the excess in-flight preps drain without interrupting
        any of them.  Only valid on a channel built with prefetch enabled
        (``self._prefetch_sem`` exists); depth must stay >= 1 so a producer
        already committed to the async path can never block forever on a
        zero-limit semaphore.
        """
        depth = int(depth)
        if depth < 1:
            raise ValueError(f"runtime prefetch depth must be >= 1, got {depth}")
        if self._prefetch_sem is None:
            raise ValueError(
                f"channel {self.name} was built without prefetch; "
                f"set prefetch >= 1 (or autotune:) in the workflow YAML")
        with self._lock:
            self.prefetch = depth
            self._prefetch_sem.resize(depth)

    @property
    def max_prefetch_depth(self) -> int:
        """Upper bound on this edge's depth: the autotune max if autotuned,
        else the static depth (used to size the run's prefetch pool)."""
        return self.autotune[1] if self.autotune is not None else self.prefetch

    def _on_prep_done(self, fut: Future) -> None:
        """Done-callback for every submitted prep: completion, error, and
        shutdown-cancel alike release the edge's depth slot and close the
        in-flight gauge; a cancelled prep (pool shutdown, or a `latest`
        edge dropping a stale step) also counts as ``prefetch_cancelled``."""
        self._prefetch_sem.release()
        cancelled = fut.cancelled()
        with self._lock:
            self.stats.inflight_preps -= 1
            if cancelled:
                self.stats.prefetch_cancelled += 1
            inflight = self.stats.inflight_preps
        tr = self._tracer
        if tr is not None:
            tr.counter(f"inflight:{self.name}", inflight)
        if cancelled:
            transport_stats().record_prefetch_cancelled()

    def add_listener(self, mux: ChannelMux) -> None:
        with self._lock:
            self._listeners.append(mux)

    def remove_listener(self, mux: ChannelMux) -> None:
        with self._lock:
            try:
                self._listeners.remove(mux)
            except ValueError:
                pass

    def _notify_listeners(self) -> None:
        with self._lock:
            listeners = list(self._listeners)
        for mux in listeners:
            mux.notify()

    def matches_file(self, filename: str) -> bool:
        # bidirectional: either side's pattern may be the more general one.
        # Memoized per channel: every serve/open probes every channel, and the
        # reverse compile would otherwise run each time for non-matches.
        hit = self._match_cache.get(filename)
        if hit is None:
            hit = self._file_matcher.matches(filename) or compile_file_pattern(
                filename
            ).matches(self.filename_pattern)
            if len(self._match_cache) < 4096:  # bound pathological filename churn
                self._match_cache[filename] = hit
        return hit

    def filter_file(self, f: File) -> File:
        """Data-centric selection: ship only the datasets this port asked for.

        Zero-copy mode grafts CoW views; a port with declared M->N ownership
        (``redistribute``) consults the plan cache and ships only this
        consumer instance's owned slab of each dataset.  Legacy mode
        materializes a private copy per dataset (the seed's per-channel
        deep-copy behaviour).
        """
        out = File(f.filename)
        out.attrs.update(f.attrs)
        for ds in f.visit_datasets():
            if any(m.matches(ds.path) for m in self._dset_matchers):
                if self.redistribute is not None:
                    # the slab contract holds in legacy mode too (the copy is
                    # eager there instead of CoW-deferred)
                    self._attach_redistributed(out, ds)
                elif self.zero_copy:
                    out.attach_view(ds)
                else:
                    buf = np.array(ds.read_direct())  # eager materialization
                    transport_stats().record_copy(buf.nbytes)
                    nd = out.create_dataset(ds.path, data=buf, copy=False)
                    nd.attrs.update(ds.attrs)
                    nd.ownership = ds.ownership
        return out

    def _attach_redistributed(self, out: File, ds) -> None:
        """Attach only this consumer instance's owned blocks of ``ds``.

        The M->N plan (src = the dataset's producer BlockOwnership, dst = the
        port-declared consumer decomposition) comes from the process-wide
        ``PlanCache`` -- the O(M*N) intersection runs once per shape/ownership
        key, not per step.  Two fast paths:

        * aligned decompositions (every dst block == one src block) ship a
          whole-dataset CoW view -- zero bytes *copied*, no rearrangement;
          the payload bytes (what a wire would carry rank-to-rank) still
          count as shipped;
        * otherwise the instance's union box ships as a CoW ``slab_view``
          (still zero copies in-process; the slab's nbytes is what would
          cross the wire) with per-rank dst blocks as its ownership map.

        Legacy (``zero_copy=False``) channels honor the same contract with
        eager copies: the consumer still receives only its owned slab, with
        the same attrs and ownership map.
        """
        spec = self.redistribute
        shape = ds.shape
        if not shape or spec.axis >= len(shape):
            out.attach_view(ds)  # scalars / axis mismatch: no decomposition
            return
        if ds.ownership is not None and ds.ownership.blocks:
            src = [ds.ownership.blocks[r] for r in sorted(ds.ownership.blocks)]
        else:
            src = [((0,) * len(shape), shape)]  # unowned: one global block
        dst, slot_boxes = spec.dst_boxes(shape)
        plan = plan_cache().get(src, dst, shape, ds.dtype)

        my_ranks = spec.my_ranks()
        planned = plan.dst_bytes(my_ranks)
        own = BlockOwnership()
        for local, r in enumerate(my_ranks):
            own.add(local, dst[r][0], dst[r][1])

        stats = transport_stats()
        if plan.aligned and spec.nslots == 1:
            if self.zero_copy:
                v = out.attach_view(ds)
            else:
                buf = np.array(ds.read_direct())
                stats.record_copy(buf.nbytes)
                v = out.create_dataset(ds.path, data=buf, copy=False)
                v.attrs.update(ds.attrs)
            v.ownership = own
            stats.record_redistribution(planned, ds.nbytes, ds.nbytes,
                                        aligned=True)
            return
        box_starts, box_shape = slot_boxes[spec.slot]
        if self.zero_copy:
            v = out.attach_slab(ds, box_starts, box_shape)
        else:
            slc = tuple(slice(s, s + n) for s, n in zip(box_starts, box_shape))
            buf = np.array(ds.read_direct()[slc])
            stats.record_copy(buf.nbytes)
            v = out.create_dataset(ds.path, data=buf, copy=False)
            v.attrs.update(ds.attrs)
        v.ownership = own
        v.attrs["redist_global_shape"] = list(shape)
        v.attrs["redist_box_starts"] = list(box_starts)
        stats.record_redistribution(planned, v.nbytes, ds.nbytes, aligned=False)

    # ------------------------------------------------------------- producer
    def offer(self, f: File, _payload_cache: Optional[Dict[Any, File]] = None) -> bool:
        """Producer-side serve with flow control. Returns True if served.

        Called from the VOL layer at (after-)file-close time, mirroring
        LowFive's serve-on-close. The flow-control decision happens *before*
        any data is filtered, copied, or queued, so a skipped timestep costs
        nothing -- that is the entire point of the paper's §3.6.

        ``_payload_cache`` (passed by ``VOL.serve_all``) shares ONE filtered
        payload across every fan-out channel with the same dataset selection:
        each channel ships a structural ``File.view()`` over the same buffers.

        Prefetching channels (``self.prefetch`` > 0, default for
        redistributing ports) enqueue a *future* of the payload instead:
        ``_prepare`` runs on the shared prefetch pool, overlapping slab
        construction with this producer's rendezvous wait and with the
        consumer's compute on the step it is still holding.  At most
        ``self.prefetch`` preps are in flight per edge (per-channel
        semaphore); a producer outrunning its own preps blocks here.
        Payload bytes are then accounted at delivery time (``_deliver``),
        when the future's size is known.
        """
        with self._lock:
            if self._abandoned:
                # consumer dropped/dead: the serve is a counted no-op
                self.stats.dropped += 1
                self._event_locked("producer", "skip_abandoned")
                return False
            self._close_count += 1
            step = self._close_count - 1
            if self.strategy == FlowControl.SOME and (self._close_count % self.freq) != 0:
                self.stats.dropped += 1
                self._event_locked("producer", "skip_some")
                return False
            if self.strategy == FlowControl.LATEST and not self._waiters:
                # No incoming request from the consumer: skip this timestep
                # and proceed to generating the next one (paper §3.6).
                self.stats.dropped += 1
                self._event_locked("producer", "skip_latest")
                return False
            # every SERVED close gets a monotonic seq; a restarted producer
            # rewound to its last ack regenerates the same seqs, so serves
            # the consumer already delivered are recognized here and skipped
            # (exactly-once delivery across producer restarts)
            self._serve_seq += 1
            seq = self._serve_seq
            if seq <= self._delivered_seq:
                self.stats.deduped += 1
                self._event_locked("producer", "dedup_replay")
                return True
            epoch = self._epoch
            # depth is read under the lock: the autotuner retunes it at
            # runtime via set_depth, also under this lock
            depth = self.prefetch

        # THE unlocked window of the serve protocol: between the flow-control
        # decision above and the enqueue below, a quarantine/rescale/abandon
        # can land -- the explorer preempts here
        sched_point("Channel.offer:prepare", key=("chan", id(self)))
        # keep the source File only when prep retry may need it (recovery
        # runs): retry re-filters from the producer's CoW tree at delivery
        src = f if (depth and self._prep_retry) else None
        if depth:
            # per-edge depth: block until one of this channel's in-flight
            # preps completes (backpressure), never starving other edges
            # of pool workers
            self._prefetch_sem.acquire()
            try:
                pool = self._prefetch_pool or _prefetch_pool()
                fut = pool.submit(self._prepare_timed, f, _payload_cache,
                                  step, edge=self.name, weight=self.weight)
            except BaseException:
                self._prefetch_sem.release()
                raise
            with self._lock:
                self.stats.inflight_preps += 1
                inflight = self.stats.inflight_preps
            if self._tracer is not None:
                self._tracer.counter(f"inflight:{self.name}", inflight)
            # release the slot + close the gauge on completion, error, or
            # cancel alike (shutdown AND the `latest` stale-prep drop)
            fut.add_done_callback(self._on_prep_done)
            item: Tuple[str, Any, int, int, Any] = ("future", fut, seq, epoch, src)
            payload_bytes = None
        else:
            payload, payload_bytes = self._prepare(f, _payload_cache)
            item = (payload[0], payload[1], seq, epoch, None)
        t0 = time.monotonic()
        with self._lock:
            if self.strategy == FlowControl.LATEST and depth:
                # a newer step supersedes any queued payload future whose
                # prep has not finished: cancel it rather than prepare
                # bytes nobody will read (`latest` semantics)
                self._drop_stale_preps_locked()
            self._event_locked("producer", "wait_begin")
            while (len(self._queue) >= self.queue_depth and not self._done
                   and not self._abandoned and not self._grace):
                if self._supervisor is not None:
                    # a producer parked in the rendezvous is starved, not
                    # stalled: keep its heartbeat alive for the watchdog
                    self._supervisor.heartbeat(*self.producer)
                    self._lock.wait(
                        timeout=self._supervisor.wait_quantum(self.producer[0]))
                else:
                    self._lock.wait()
            now = time.monotonic()
            self.stats.producer_wait_s += now - t0
            self._event_locked("producer", "wait_end")
            tr = self._tracer
            if self._abandoned:
                if tr is not None:
                    tr.record("channel", "channel.offer", self.producer[0],
                              self.producer[1], t0, now, step=step,
                              edge=self.name, aborted=True)
                self._discard_item_locked(item)
                return False
            if self._done:
                if tr is not None:
                    tr.record("channel", "channel.offer", self.producer[0],
                              self.producer[1], t0, now, step=step,
                              edge=self.name, aborted=True)
                return False
            self._queue.append(item)
            # HB edge half 1 (offer -> get): the consumer that pops seq
            # joins this clock in _take_locked
            hb_publish(("chan", id(self), seq))
            self.stats.served += 1
            if payload_bytes is not None:
                self.stats.bytes_moved += payload_bytes
            self._event_locked("producer", "serve")
            if tr is not None:
                tr.record("channel", "channel.offer", self.producer[0],
                          self.producer[1], t0, now, step=step,
                          flow=("s", flow_id(self.name, seq)), edge=self.name)
                tr.counter(f"qdepth:{self.name}", len(self._queue), t=now)
            self._lock.notify_all()
        self._notify_listeners()
        return True

    def _drop_stale_preps_locked(self) -> int:
        """Drop queued-but-unfinished payload futures on a `latest` edge
        (caller holds ``self._lock``; a newer step is about to be queued).

        A prep that has not started is cancelled -- its done-callback
        releases the depth slot and counts ``prefetch_cancelled``.  A prep
        already running cannot be stopped, but it leaves the queue here so
        its bytes are never delivered; it is counted as cancelled directly
        (its done-callback will see a *completed* future and only close the
        gauge).  Finished futures stay queued: their bytes exist, and they
        are still the freshest data until the new step lands.
        """
        kept: Deque[Tuple[str, Any, int, int, Any]] = deque()
        dropped = 0
        for item in self._queue:
            kind, payload = item[0], item[1]
            if kind == "future" and not payload.done():
                dropped += 1
                self.stats.dropped += 1
                self._event_locked("producer", "drop_stale_prep")
                if not payload.cancel():
                    self.stats.prefetch_cancelled += 1
                    transport_stats().record_prefetch_cancelled()
            else:
                kept.append(item)
        self._queue = kept
        if dropped:
            self._lock.notify_all()  # a freed ring slot unblocks rendezvous
        return dropped

    def _prepare_timed(
        self, f: File, cache: Optional[Dict[Any, File]] = None, step: int = 0
    ) -> Tuple[Tuple[str, Any], int]:
        """``_prepare`` on the prefetch executor, timed for the overlap
        accounting (prepared vs consumer-blocked seconds).

        Fault-injection point ``prefetch`` fires here (on the pool worker,
        keyed to the *producer* task): an injected crash lands in the
        future's exception and surfaces at delivery -- exactly the surface a
        real prep I/O error would use.  The synchronous retry path goes
        through ``_prepare`` directly and so never re-fires the fault."""
        sup = self._supervisor
        if sup is not None:
            sup.fire(self.producer[0], self.producer[1], "prefetch", step)
        t0 = time.monotonic()
        item, payload_bytes = self._prepare(f, cache)
        dt = time.monotonic() - t0
        transport_stats().record_prefetch_prepare(dt)
        with self._lock:
            self.stats.prefetch_prepared_s += dt
        tr = self._tracer
        if tr is not None:
            # pool workers get their own pseudo-process track: overlapping
            # preps must not stack onto a task instance's timeline
            tr.record("prefetch", "prefetch.prep", "pool",
                      threading.get_ident() & 0xF, t0, t0 + dt, step=step,
                      edge=self.name, bytes=payload_bytes)
        return item, payload_bytes

    def _prepare(
        self, f: File, cache: Optional[Dict[Any, File]] = None
    ) -> Tuple[Tuple[str, Any], int]:
        """Build this channel's payload; returns (queue item, payload bytes).

        The fan-out payload cache key includes the redistribution spec: two
        consumer instances of an M->N port own *different* slabs, so only
        channels with the same selection AND the same owned blocks may share
        one filtered payload.

        Prefetching channels may run this concurrently on the executor; the
        cache get/set are GIL-atomic and a lost race merely duplicates the
        (cheap, CoW) filter work for one step, never corrupts a payload.
        """
        if self.zero_copy:
            key = (tuple(self.dset_patterns), self.redistribute)
            base = cache.get(key) if cache is not None else None
            if base is None:
                base = self.filter_file(f)
                if cache is not None:
                    cache[key] = base
            sub = base.view()  # per-channel tree, shared buffers
        else:
            sub = self.filter_file(f)
        payload_bytes = sub.total_bytes()
        if self.mode == "file":
            # Spill through "disk" -- the paper's ``file: 1`` transport path.
            # One container per served step so queued (queue_depth > 1) and
            # concurrently-read spills never clobber each other.
            with self._lock:
                seq = self._spill_seq
                self._spill_seq += 1
            base_name = f"{os.path.basename(f.filename)}.{_sanitize(self.name)}.{seq:06d}"
            path = sub.save(self.spill_dir, basename=base_name)
            return ("file", path), payload_bytes
        return ("memory", sub), payload_bytes

    def finish(self) -> None:
        """Producer signals all-done (query protocol: empty filename list)."""
        with self._lock:
            self._done = True
            self._event_locked("producer", "done")
            self._lock.notify_all()
        self._notify_listeners()

    # ------------------------------------------------------------- consumer
    def _waiter_enter_locked(self) -> None:
        """Register the current thread as a blocked consumer (lock held).

        Keyed by thread ident with a nesting depth: the VOL mux registering
        via ``set_consumer_waiting`` and the same thread then blocking in
        ``get`` collapse to ONE waiter, so the `latest` rendezvous fan-in
        decision sees distinct blocked consumers, not registration counts.
        """
        me = threading.get_ident()
        first = me not in self._waiters
        self._waiters[me] = self._waiters.get(me, 0) + 1
        if first:
            self._event_locked("consumer", "wait_begin")
            self._lock.notify_all()  # wake a producer doing `latest` rendezvous

    def _waiter_exit_locked(self) -> None:
        """Drop one nesting level; the thread stops counting at depth 0."""
        me = threading.get_ident()
        depth = self._waiters.get(me, 0) - 1
        if depth > 0:
            self._waiters[me] = depth
        else:
            self._waiters.pop(me, None)
            self._event_locked("consumer", "wait_end")

    def waiting_consumers(self) -> int:
        """Distinct consumer threads currently counted as blocked here."""
        with self._lock:
            return len(self._waiters)

    def _take_locked(self) -> Tuple[str, Any, int, int, Any]:
        """Pop under self._lock (caller holds it) and wake the producer.

        The dedup watermark advances HERE, at pop time, not at the end of
        ``_deliver``: delivery runs outside the lock (future result, file
        load), and a producer quarantine+replay landing in that window
        would re-serve a step the consumer has already taken -- the
        replayed serve passes the offer-side ``seq <= _delivered_seq``
        check against the stale watermark and the step delivers twice
        (found by the schedule explorer on the crash_replay scenario).
        ``quarantine_consumer`` still rewinds the watermark to the last
        consumer ack, so consumer-restart replay is unaffected."""
        item = self._queue.popleft()
        if item[2] > self._delivered_seq:
            self._delivered_seq = item[2]
        hb_consume(("chan", id(self), item[2]))  # HB edge half 2 (offer -> get)
        self._lock.notify_all()
        return item

    def _deliver(self, item: Tuple[str, Any, int, int, Any]) -> File:
        kind, payload, seq, epoch, src = item
        if kind == "future":
            fut: "Future[Tuple[Tuple[str, Any], int]]" = payload
            hit = fut.done()
            t0 = time.monotonic()
            try:
                inner, payload_bytes = fut.result()  # re-raises prepare errors
                fail = None
            except BaseException as e:
                fut._wilkins_observed = True  # consumer saw it: not "dropped"
                fail = e
            if fail is not None:
                if (self._prep_retry and src is not None
                        and not isinstance(fail, CancelledError)):
                    # Recovery path: the prep is pure (filter + CoW views of
                    # the producer's File), so re-run it synchronously here.
                    # Injected faults live in _prepare_timed, never here.
                    inner, payload_bytes = self._prepare(src)
                    with self._lock:
                        self.stats.prep_retries += 1
                        self._event_locked("consumer", "prep_retry")
                else:
                    # A payload that failed to prepare must not leave the
                    # producer parked forever in the rendezvous wait (the
                    # sync path failed fast inside offer; the async path
                    # surfaces the error here, in the consumer that asked
                    # for the data, so mark the channel done to unblock and
                    # stop the producer).
                    with self._lock:
                        self._done = True
                        self._event_locked("consumer", "prepare_error")
                        self._lock.notify_all()
                    self._notify_listeners()
                    raise fail
            blocked = 0.0 if hit else time.monotonic() - t0
            transport_stats().record_prefetch(hit, blocked_s=blocked)
            with self._lock:
                self.stats.bytes_moved += payload_bytes
                if hit:
                    self.stats.prefetch_hits += 1
                else:
                    self.stats.prefetch_misses += 1
                    self.stats.prefetch_blocked_s += blocked
            tr = self._tracer
            if tr is not None:
                # zero-length on a hit: still carries the cache verdict and
                # the payload bytes for the per-edge rollup
                tr.record("prefetch", "prefetch.wait", self.consumer[0],
                          self.consumer[1], t0, t0 + blocked, edge=self.name,
                          cache="hit" if hit else "miss",
                          bytes=payload_bytes)
            kind, payload = inner
        if kind == "file":
            f = File.load(payload, mmap=True)
            try:
                os.unlink(payload)  # np.memmap keeps the mapping alive (POSIX)
            except OSError:
                pass
        else:
            f = payload
        with self._lock:
            self._event_locked("consumer", "recv")
            if seq > self._delivered_seq:
                self._delivered_seq = seq
            if self._replay_enabled:
                # a structural CoW view: consumer writes materialize private
                # copies in the consumer's tree, the replay copy stays intact
                self._replay.append(("memory", f.view(), seq, epoch, None))
        return f

    def get(self, timeout: Optional[float] = None) -> Optional[File]:
        """Consumer-side blocking receive.

        Returns the next ``File``; ``None`` means the producer is all-done
        (query protocol).  If ``timeout`` elapses first, raises
        ``ChannelTimeout`` -- distinct from producer-done, and the elapsed
        wait still lands in ``consumer_wait_s``.  If the producer FAILED
        (the driver poisoned the channel), raises ``ChannelError`` naming
        the dead task immediately -- a blocked consumer is woken, it does
        not wait out its timeout.  Data queued before the failure still
        delivers first.
        """
        check_blocking("Channel.get")
        sched_point("Channel.get", key=("chan", id(self)))
        t0 = time.monotonic()
        deadline = None if timeout is None else t0 + timeout
        with self._lock:
            if self._interrupt is not None:
                raise self._interrupt
            self._waiter_enter_locked()
            try:
                while (not self._queue and not self._done
                       and self._poison is None and self._interrupt is None):
                    remaining = None if deadline is None else deadline - time.monotonic()
                    if remaining is not None and remaining <= 0:
                        if not _in_mux_wait_scope(self):
                            self.stats.consumer_wait_s += time.monotonic() - t0
                        self._event_locked("consumer", "timeout")
                        if self._tracer is not None:
                            self._tracer.record(
                                "channel", "channel.get", self.consumer[0],
                                self.consumer[1], t0, time.monotonic(),
                                edge=self.name, aborted=True, why="timeout")
                        raise ChannelTimeout(
                            f"{self.name}: no data within {timeout}s")
                    if self._supervisor is not None:
                        # a consumer parked on an empty channel is starved,
                        # not stalled: keep its heartbeat alive
                        self._supervisor.heartbeat(*self.consumer)
                        q = self._supervisor.wait_quantum(self.consumer[0])
                        remaining = q if remaining is None else min(
                            remaining, q)
                    self._lock.wait(timeout=remaining)
                now = time.monotonic()
                if not _in_mux_wait_scope(self):
                    self.stats.consumer_wait_s += now - t0
                tr = self._tracer
                if self._interrupt is not None:
                    if tr is not None:
                        tr.record("channel", "channel.get", self.consumer[0],
                                  self.consumer[1], t0, now, edge=self.name,
                                  aborted=True, why="interrupt")
                    raise self._interrupt
                if self._queue:
                    item = self._take_locked()
                    if tr is not None:
                        tr.record("channel", "channel.get", self.consumer[0],
                                  self.consumer[1], t0, now,
                                  flow=("f", flow_id(self.name, item[2])),
                                  edge=self.name)
                        tr.counter(f"qdepth:{self.name}",
                                   len(self._queue), t=now)
                elif self._poison is not None:
                    if tr is not None:
                        tr.record("channel", "channel.get", self.consumer[0],
                                  self.consumer[1], t0, now, edge=self.name,
                                  aborted=True, why="poison")
                    raise self._poison_error_locked()
                else:
                    return None  # all done
            finally:
                self._waiter_exit_locked()
        return self._deliver(item)

    def _poison_error_locked(self) -> ChannelError:
        """Build the poison-pill exception (caller holds the lock, and
        RAISES the result -- chained to the producer's own error)."""
        task, inst, cause = self._poison
        self._event_locked("consumer", "poisoned")
        err = ChannelError(
            f"{self.name}: producer task {task!r} (instance {inst}) failed "
            f"permanently: {type(cause).__name__}: {cause}",
            task=task, instance=inst)
        err.__cause__ = cause
        return err

    def try_get(self) -> Any:
        """Non-blocking receive: a ``File``, ``None`` (producer all-done), or
        ``NO_DATA`` (queue empty, producer still live).  Raises
        ``ChannelError`` if the producer failed permanently (poison pill --
        also how ``ChannelMux`` scan loops learn of a dead producer)."""
        with self._lock:
            if self._interrupt is not None:
                raise self._interrupt
            if self._queue:
                item = self._take_locked()
            elif self._poison is not None:
                raise self._poison_error_locked()
            elif self._done:
                return None
            else:
                return NO_DATA
        return self._deliver(item)

    def set_consumer_waiting(self, waiting: bool) -> None:
        """Mark the consumer as blocked on this channel (used by the VOL
        multiplexer so the `latest` strategy sees fan-in waiters).

        Idempotent per thread: a consumer the mux already registered that
        then blocks in ``get`` on the same channel counts once."""
        with self._lock:
            if waiting:
                self._waiter_enter_locked()
            else:
                self._waiter_exit_locked()

    def peek_pending(self) -> bool:
        with self._lock:
            return bool(self._queue)

    def is_done(self) -> bool:
        # a poisoned channel with nothing left to deliver is terminal too:
        # the driver's relaunch loop must stop relaunching its consumer
        with self._lock:
            return (self._done or self._poison is not None) and not self._queue

    def __repr__(self) -> str:
        return (
            f"<Channel {self.name} {self.producer}->{self.consumer} "
            f"{self.filename_pattern} mode={self.mode} fc={self.strategy}/{self.freq} "
            f"depth={self.queue_depth}>"
        )


def _sanitize(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]+", "_", name)
