"""Elastic rescale surgery: live M->N resize of a supervised consumer task.

This module is the driver-side executor behind ``RunSupervisor.lead(op)``:
by the time :func:`execute_rescale` runs, every live old instance of the
task has retired out of its callable (``RescaleInterrupt`` arrival protocol
in ``recovery.py``) and the caller is the single surgery leader.  The
surgery then performs, in order:

1. **Grace + quiesce** -- blocked producer ``offer``s on the retiring
   channels complete immediately (``rescale_release_producer``), then every
   feeding producer's ``serve_lock`` is taken so no serve can straddle the
   swap.  The lock order (grace first) matters: a producer parked inside
   ``offer`` *holds* its serve lock, so the grace release is what makes the
   lock acquirable.
2. **Snapshot** -- producer-side counters plus every step the new partition
   may need (retention ring + replay buffer + undelivered queue) are read
   from each retiring channel; sibling channels of one edge must agree on
   the producer counters (they are fan-out copies of the same serves).
   Payload futures are resolved here, outside any channel lock.
3. **Consistent cut** -- ``C = min`` over the old instances' newest durable
   checkpoint steps.  Each instance's step-``C`` container is re-cut:
   leaves declared in ``sharded.json`` are re-split M->N through
   ``reshard_blocks`` (the startup reshard machinery turned recovery
   feature); every other leaf must be a bitwise replica and is copied
   through.  The per-step ``seqs_*.json`` sidecar gives the delivered-seq
   floor: everything after it is replay.
4. **Rebuild** -- N fresh channels per inbound edge (new ``RedistSpec``
   partition, epoch bumped past every retired incarnation) adopt the
   producer counters verbatim and are preloaded with the replay steps,
   re-partitioned by reconstructing each step's *global* file from the M
   sibling slabs and running it through the new channel's own serve-path
   payload builder -- so a replayed delivery is byte-identical to a live
   one at the new size.
5. **Swap + seal** -- producer VOL outgoing lists, driver channel/VOL/
   recovery-context tables, the graph's ``task_count``/``nprocs``, the
   scheduler's channel list and the supervisor's are all repointed; then
   ``finish_rescale`` bumps the task generation (fencing zombies) and the
   driver spawns fresh threads for all N new instances.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..analysis.lockcheck import check_blocking, sched_point
from .channel import Channel
from .datamodel import Dataset, File, Group
from .recovery import (RecoveryContext, RescaleError, RescaleOp, edge_key,
                       reshard_blocks)
from .redistribute import RedistSpec
from .vol import VOL

__all__ = ["execute_rescale"]

# flatten-with-path key of a flat dict state: ``{"acc": ...}`` -> ``"['acc']"``
_FLAT_KEY_RE = re.compile(r"^\['(.+)'\]$")

_REDIST_ATTRS = ("redist_global_shape", "redist_box_starts")


# ---------------------------------------------------------------------------
# payload resolution + global-file reconstruction
# ---------------------------------------------------------------------------
def _resolve_items(ch: Channel, items: List[Tuple[str, Any, int, int, Any]]
                   ) -> Dict[int, File]:
    """Materialize a snapshot's items into {seq: File}.

    Future payloads resolve here -- *outside* any channel lock -- falling
    back to a synchronous re-prepare of the source file when the async prep
    errored or was cancelled (same idempotence contract as prep-retry)."""
    out: Dict[int, File] = {}
    for kind, payload, seq, _epoch, src in items:
        if kind == "future":
            try:
                check_blocking("future.result")
                (kind, payload), _nbytes = payload.result()
            except BaseException:
                if src is None:
                    raise
                (kind, payload), _nbytes = ch._prepare(src)
        if kind == "file":
            payload = File.load(payload)
        out[seq] = payload
    return out


def _copy_group_attrs(src: Group, dst: File) -> None:
    for name, child in src.children.items():
        if isinstance(child, Dataset):
            continue
        g = dst.require_group(child.path)
        g.attrs.update(child.attrs)
        _copy_group_attrs(child, dst)


def _reconstruct_global(siblings: List[File]) -> File:
    """Rebuild one served step's global file from the M per-instance slabs.

    Datasets shipped whole (fan-out, aligned fast path, scalars) graft as
    CoW views of sibling 0's copy.  Redistributed slabs carry their global
    shape and box origin as attrs; the global array is stitched from every
    sibling's slab (the old decomposition tiles it exactly) and the redist
    bookkeeping attrs are dropped -- the result is what the producer closed,
    ready for any new partition's payload builder."""
    base = siblings[0]
    out = File(base.filename)
    out.attrs.update(base.attrs)
    _copy_group_attrs(base, out)
    for ds in base.visit_datasets():
        if "redist_global_shape" not in ds.attrs:
            out.attach_view(ds)
            continue
        gshape = tuple(int(x) for x in ds.attrs["redist_global_shape"])
        buf = np.zeros(gshape, dtype=ds.dtype)
        for sib in siblings:
            sds = sib.get(ds.path)
            if sds is None or 0 in sds.shape:
                continue
            starts = tuple(int(x) for x in sds.attrs["redist_box_starts"])
            slc = tuple(slice(s, s + n) for s, n in zip(starts, sds.shape))
            buf[slc] = sds.read_direct()
        v = out.create_dataset(ds.path, data=buf, copy=False)
        for k, val in ds.attrs.items():
            if k not in _REDIST_ATTRS:
                v.attrs[k] = val
    return out


# ---------------------------------------------------------------------------
# checkpoint re-cut
# ---------------------------------------------------------------------------
def _write_json(directory: str, name: str, payload: Dict[str, Any]) -> None:
    tmp = os.path.join(directory, name + ".tmp")
    with open(tmp, "w") as f:
        json.dump(payload, f)
    os.replace(tmp, os.path.join(directory, name))


def _recut_checkpoints(driver: Any, op: RescaleOp, gen_next: int
                       ) -> Tuple[Optional[int], Dict[str, int], List[str]]:
    """Pick the consistent cut C, re-split the step-C shards M->N, and save
    them into fresh per-generation directories.  C is the NEWEST step every
    old instance still holds (shard + seq sidecar) with agreeing consumed-seq
    floors: checkpoint GC (``keep=``) trims each instance's window
    independently, so when a stalled instance fell behind its live siblings
    the windows may no longer overlap -- then, or when some instance never
    checkpointed at all, C is None: fresh start with a full replay from the
    producers' retention rings.  Returns ``(C, per-edge delivered floors at
    C, new dirs)``."""
    from ..train.checkpoint import load_pytree_flat, save_pytree

    task, M, N = op.task, op.old_nslots, op.new_nslots
    new_dirs = [os.path.join(driver._ck_root, f"{task}_{j}__g{gen_next}")
                for j in range(N)]
    for d in new_dirs:
        os.makedirs(d, exist_ok=True)

    old_rcs = [driver._recovery_ctx[(task, i)] for i in range(M)]
    latests = [rc.latest_step() for rc in old_rcs]
    if any(l is None for l in latests):
        return None, {}, new_dirs

    def _held_steps(rc: Any) -> set:
        steps = set()
        for fn in os.listdir(rc.directory):
            m = re.match(r"^step_(\d{8})\.ckpt$", fn)
            if m is not None and os.path.exists(os.path.join(
                    rc.directory, f"seqs_{int(m.group(1)):08d}.json")):
                steps.add(int(m.group(1)))
        return steps

    common = set.intersection(*(_held_steps(rc) for rc in old_rcs))
    candidates = sorted((s for s in common if s <= min(latests)),
                        reverse=True)

    C: Optional[int] = None
    flats: List[Dict[str, np.ndarray]] = []
    floors: Optional[Dict[str, int]] = None
    for cand in candidates:
        flats, floors, ok = [], None, True
        for rc in old_rcs:
            flats.append(load_pytree_flat(
                os.path.join(rc.directory, f"step_{cand:08d}.ckpt")))
            with open(os.path.join(rc.directory,
                                   f"seqs_{cand:08d}.json")) as f:
                fl = {k: int(v)
                      for k, v in json.load(f).get("seqs", {}).items()}
            if floors is None:
                floors = fl
            elif floors != fl:
                # the per-step loops drifted at this step; an older common
                # step may still carry an agreeing replay floor
                ok = False
                break
        if ok:
            C = cand
            break
    if C is None:
        return None, {}, new_dirs

    sharded: Dict[str, int] = {}
    spath = os.path.join(old_rcs[0].directory, "sharded.json")
    if os.path.exists(spath):
        with open(spath) as f:
            sharded = {k: int(v) for k, v in json.load(f).items()}

    keys0 = set(flats[0])
    for rc, fl in zip(old_rcs[1:], flats[1:]):
        if set(fl) != keys0:
            raise RescaleError(
                f"task {task!r}: checkpoint leaf keys differ across "
                f"instances ({sorted(keys0)} vs {sorted(fl)})")
    user_keys: Dict[str, str] = {}
    for fk in sorted(keys0):
        m = _FLAT_KEY_RE.match(fk)
        if m is None:
            raise RescaleError(
                f"task {task!r}: rescale requires a flat dict checkpoint "
                f"state (top-level string keys only), got leaf {fk!r}")
        user_keys[m.group(1)] = fk

    new_states: List[Dict[str, np.ndarray]] = [{} for _ in range(N)]
    for uk, fk in user_keys.items():
        if uk in sharded:
            cut = reshard_blocks([fl[fk] for fl in flats], N,
                                 axis=sharded[uk])
            for j in range(N):
                new_states[j][uk] = np.ascontiguousarray(cut[j])
        else:
            ref = np.asarray(flats[0][fk])
            for rc, fl in zip(old_rcs[1:], flats[1:]):
                if not np.array_equal(np.asarray(fl[fk]), ref):
                    raise RescaleError(
                        f"task {task!r}: non-sharded checkpoint leaf {uk!r} "
                        f"differs between instances 0 and {rc.instance} -- "
                        f"declare it in sharded_axes or keep it a replica")
            for j in range(N):
                new_states[j][uk] = ref

    for j, d in enumerate(new_dirs):
        save_pytree(new_states[j], os.path.join(d, f"step_{C:08d}.ckpt"))
        _write_json(d, f"seqs_{C:08d}.json",
                    {"step": C, "seqs": dict(floors or {})})
        if sharded:
            _write_json(d, "sharded.json", dict(sharded))
        # LATEST last: a crash mid-recut leaves no readable checkpoint, and
        # the new incarnation starts fresh instead of reading a torn cut
        tmp = os.path.join(d, "LATEST.tmp")
        with open(tmp, "w") as f:
            f.write(str(C))
        os.replace(tmp, os.path.join(d, "LATEST"))
    return C, dict(floors or {}), new_dirs


# ---------------------------------------------------------------------------
# the surgery
# ---------------------------------------------------------------------------
def execute_rescale(driver: Any, op: RescaleOp) -> None:
    """Perform the M->N resize of ``op.task`` on a quiesced driver.

    Caller contract (enforced by ``RunSupervisor``): every live old
    instance has arrived (retired out of its callable); exactly one thread
    -- the leader -- calls this."""
    sup = driver._run_supervisor
    if sup is None:
        raise RescaleError(f"task {op.task!r}: no run in progress")
    try:
        _execute(driver, sup, op)
    except BaseException as e:
        sup.fail_rescale(op, e)
        raise


def _execute(driver: Any, sup: Any, op: RescaleOp) -> None:
    import time as _time

    task, M, N = op.task, op.old_nslots, op.new_nslots
    t = driver.graph.tasks[task]
    gen_next = sup.generation(task) + 1
    tr = sup.tracer  # surgery stages report as rescale spans when traced

    def _stage(name: str, t0: float) -> float:
        now = _time.monotonic()
        if tr is not None:
            tr.record("rescale", f"rescale.{name}", task, -1, t0, now,
                      old=M, new=N)
        return now

    t_stage = _time.monotonic()

    old_chs = [ch for ch in driver.channels if ch.consumer[0] == task]
    old_by_edge: Dict[str, List[Channel]] = {}
    for ch in old_chs:
        old_by_edge.setdefault(edge_key(ch.name), []).append(ch)
    for chs in old_by_edge.values():
        chs.sort(key=lambda c: c.consumer[1])
        if len(chs) != M or [c.consumer[1] for c in chs] != list(range(M)):
            raise RescaleError(
                f"task {task!r}: edge {edge_key(chs[0].name)!r} does not "
                f"have one channel per old instance (found "
                f"{[c.consumer[1] for c in chs]}, expected 0..{M - 1})")

    # 1. grace: complete any blocked producer offer on the retiring edges,
    # THEN take the producers' serve locks -- a producer parked in offer
    # holds its serve lock, so this order is what makes them acquirable.
    for ch in old_chs:
        ch.rescale_release_producer()
    # the grace-to-lock window: producers drain out of their rendezvous
    # while the leader has not yet taken the serve locks
    sched_point("rescale.grace_to_lock", key=("rescale", task))
    producers = sorted({ch.producer for ch in old_chs})
    held: List[Any] = []
    try:
        for p in producers:
            lk = driver.vols[p].serve_lock
            lk.acquire()
            held.append(lk)
        t_stage = _stage("grace", t_stage)

        # 2. snapshot counters + every re-cuttable step; siblings of one
        # edge are fan-out copies of the same serves, so their producer
        # counters must agree or the retiring edges are not re-cuttable.
        snaps: Dict[str, List[Dict[str, Any]]] = {}
        for key, chs in old_by_edge.items():
            per = [ch.rescale_snapshot() for ch in chs]
            ref = per[0]
            for s in per[1:]:
                for fld in ("serve_seq", "close_count", "done"):
                    if s[fld] != ref[fld]:
                        raise RescaleError(
                            f"task {task!r}: sibling channels of edge "
                            f"{key!r} disagree on producer counter {fld} "
                            f"({ref[fld]} vs {s[fld]})")
            snaps[key] = per
        payloads: Dict[str, List[Dict[int, File]]] = {
            key: [_resolve_items(ch, s["items"])
                  for ch, s in zip(old_by_edge[key], snaps[key])]
            for key in old_by_edge
        }
        t_stage = _stage("snapshot", t_stage)

        # 3. consistent cut + checkpoint re-cut (M shards -> N shards)
        cut_step, floors, new_dirs = _recut_checkpoints(driver, op, gen_next)
        t_stage = _stage("recut", t_stage)

        # 4. rebuild: N fresh channels per inbound edge, counters adopted
        # verbatim, replay steps re-partitioned through each new channel's
        # own payload builder (byte-identical to a live serve at size N)
        new_np = op.new_nprocs
        new_io = t.nwriters if t.nwriters is not None else new_np
        new_chs: List[Channel] = []
        new_by_inst: List[List[Channel]] = [[] for _ in range(N)]
        for edge in driver.graph.producers_of(task):
            key = f"{edge.producer}->{task}:{edge.filename_pattern}"
            if key not in old_by_edge:
                raise RescaleError(
                    f"task {task!r}: no retiring channels for inbound edge "
                    f"{key!r}")
            pi = old_by_edge[key][0].producer[1]
            snap0 = snaps[key][0]
            floor = int(floors.get(key, 0))
            serve_seq = int(snap0["serve_seq"])
            sib_maps = payloads[key]
            replayed: Dict[int, File] = {}
            for seq in range(floor + 1, serve_seq + 1):
                sibs = []
                for m in sib_maps:
                    if seq not in m:
                        raise RescaleError(
                            f"task {task!r}: edge {key!r} lost served step "
                            f"seq={seq} from the retention window before "
                            f"the rescale -- checkpoint more often or raise "
                            f"the retention cap")
                    sibs.append(m[seq])
                replayed[seq] = _reconstruct_global(sibs)
            for j in range(N):
                redist = None
                if edge.redistribute:
                    redist = RedistSpec(axis=edge.redist_axis, nslots=N,
                                        slot=j, nranks=new_io)
                ch = Channel(
                    name=f"{edge.producer}[{pi}]->{task}[{j}]:"
                         f"{edge.filename_pattern}",
                    producer=(edge.producer, pi),
                    consumer=(task, j),
                    filename_pattern=edge.filename_pattern,
                    dset_patterns=edge.dset_patterns,
                    mode=edge.mode,
                    io_freq=edge.io_freq,
                    spill_dir=driver.spill_dir,
                    record_events=driver.record_events,
                    queue_depth=edge.queue_depth,
                    zero_copy=driver.zero_copy,
                    redistribute=redist,
                    prefetch=edge.prefetch,
                    weight=edge.weight,
                    autotune=edge.autotune,
                )
                ch.rescale_adopt(
                    serve_seq=serve_seq,
                    acked_seq=int(snap0["acked_seq"]),
                    close_count=int(snap0["close_count"]),
                    acked_close_count=int(snap0["acked_close_count"]),
                    done=bool(snap0["done"]),
                    epoch=sup.epoch(task, j) + 1,
                    delivered_floor=floor,
                )
                ch.set_supervisor(sup)
                ch.set_tracer(tr)
                ch.set_prep_retry(True)
                ch.set_replay(True)
                ch.set_retention(True)
                if driver._run_pool is not None:
                    ch.set_prefetch_pool(driver._run_pool)
                for seq in range(floor + 1, serve_seq + 1):
                    (kind, sub), _nb = ch._prepare(replayed[seq])
                    assert kind == "memory", kind
                    ch.rescale_preload(sub, seq)
                new_chs.append(ch)
                new_by_inst[j].append(ch)
        t_stage = _stage("rebuild", t_stage)

        # 5. swap, everywhere, while the producers are still locked out
        dead = {id(c) for c in old_chs}
        sched_wired = driver.vols[(task, 0)].scheduler \
            if (task, 0) in driver.vols else None
        for p in producers:
            pvol = driver.vols[p]
            pvol.outgoing = [c for c in pvol.outgoing
                             if id(c) not in dead] + \
                            [c for c in new_chs if c.producer == p]
            prc = driver._recovery_ctx.get(p)
            if prc is not None:
                prc.outgoing = [c for c in prc.outgoing
                                if id(c) not in dead] + \
                               [c for c in new_chs if c.producer == p]
        for i in range(M):
            rc_old = driver._recovery_ctx.get((task, i))
            if rc_old is not None:
                rc_old.superseded = True
        for j in range(N, M):
            driver._recovery_ctx.pop((task, j), None)
            driver.vols.pop((task, j), None)
        for j in range(N):
            vol = VOL(task, instance=j, nprocs=new_np, io_procs=new_io)
            vol.incoming.extend(new_by_inst[j])
            for ch in new_by_inst[j]:
                if ch.mode == "memory":
                    vol.set_memory(ch.filename_pattern)
                else:
                    vol.set_file(ch.filename_pattern)
            vol.scheduler = sched_wired
            vol.supervisor = sup
            vol.tracer = tr
            driver.vols[(task, j)] = vol
            driver._recovery_ctx[(task, j)] = RecoveryContext(
                task, j, new_dirs[j], incoming=new_by_inst[j], outgoing=[])
        t.task_count = N
        t.nprocs = new_np
        # rebind (don't mutate): concurrent readers iterate a consistent list
        updated = [c for c in driver.channels if id(c) not in dead] + new_chs
        driver.channels = updated
        if driver._run_report is not None:
            driver._run_report.channels = updated
        sched = driver._sched_runtime
        if sched is not None:
            sched.channels = [c for c in sched.channels
                              if id(c) not in dead] + new_chs
        sup.replace_channels(old_chs, new_chs)
        t_stage = _stage("swap", t_stage)
    finally:
        for lk in held:
            lk.release()

    # 6. seal: bump the generation (fencing every pre-rescale incarnation),
    # record the event, and hand the new instances to fresh threads
    ev = sup.finish_rescale(op, cut_step if cut_step is not None else -1)
    if driver._run_report is not None:
        driver._run_report.rescales.append(ev.as_dict())
    sched = driver._sched_runtime
    if sched is not None:
        sched.notify_rescale(task, M, N, op.old_nprocs, new_np, op.trigger,
                             ev.cut_step, ev.latency_s, op.reason)
    gen = sup.generation(task)
    for j in range(N):
        driver._spawn_extra(task, j, gen)
