"""VOL interception layer -- the LowFive analogue.

LowFive is an HDF5 Virtual Object Layer plugin: user task code performs
ordinary HDF5 I/O, and the plugin redirects it over memory/MPI or files, and
exposes callback hooks at I/O execution points.  Here the same boundary is
implemented over ``repro.core.datamodel``: the user task code calls the
``repro.core.h5`` API (identical standalone and in-workflow); when a workflow
is active, an ambient ``VOL`` object intercepts opens/closes/reads/writes.

The VOL object carries (mirroring the LowFive API used in the paper's
Listing 5):

* per-pattern memory/file properties (``set_memory`` / ``set_file``),
* outgoing and incoming channels (set by the driver, matched data-centrically),
* callback registry: ``set_before_file_open``, ``set_after_file_open``,
  ``set_before_file_close``, ``set_after_file_close``,
  ``set_after_dataset_write``, ``set_before_dataset_open``,
* ``serve_all()``, ``clear_files()``, ``broadcast_files()``,
  ``file_close_counter`` -- the exact surface used by the Nyx custom-action
  script in the paper,
* flow control is enforced by the channels the files are served into.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..analysis.lockcheck import make_lock, sched_point
from ..obs.recorder import flow_id
from .channel import (NO_DATA, Channel, ChannelMux, enter_mux_wait_scope,
                      exit_mux_wait_scope)
from .datamodel import BlockOwnership, File, compile_file_pattern

__all__ = ["VOL", "current_vol", "push_vol", "pop_vol"]

_tls = threading.local()


def current_vol() -> Optional["VOL"]:
    return getattr(_tls, "vol_stack", [None])[-1]


def push_vol(vol: Optional["VOL"]) -> None:
    if not hasattr(_tls, "vol_stack"):
        _tls.vol_stack = [None]
    _tls.vol_stack.append(vol)


def pop_vol() -> None:
    _tls.vol_stack.pop()


class VOL:
    """Per task-instance interception object (one LowFive plugin instance)."""

    def __init__(self, task: str, instance: int = 0, rank: int = 0, nprocs: int = 1,
                 io_procs: Optional[int] = None):
        self.task = task
        self.instance = instance
        self.rank = rank
        self.nprocs = nprocs
        self.io_procs = io_procs if io_procs is not None else nprocs

        self.outgoing: List[Channel] = []
        self.incoming: List[Channel] = []

        # (filename_pattern -> mode) properties; "memory" wins by default
        self._props: Dict[str, str] = {}

        # declared producer ownership per outport pattern (driver sets these
        # from YAML ``outports: [{ownership: {axis: A}}]``): every dataset
        # written to a matching file gets an even per-rank BlockOwnership
        # stamped at close, replacing create_dataset(ownership=...) calls
        self._ownership: List[Tuple[Any, int, int]] = []  # (matcher, axis, nranks)

        # callback registry (LowFive execution points)
        self._cb: Dict[str, Optional[Callable[[Any], None]]] = {
            "before_file_open": None,
            "after_file_open": None,
            "before_file_close": None,
            "after_file_close": None,
            "after_dataset_write": None,
            "before_dataset_open": None,
        }

        # per-run scheduler runtime (driver-attached): producer file closes
        # and consumer intercepted opens are the step events that drive the
        # depth-autotuner / telemetry tick (see scheduler.SchedulerRuntime)
        self.scheduler = None
        # per-run supervisor (driver-attached): fault-injection points fire
        # through it, and served files are stamped with the incarnation's
        # epoch (``wilkins_epoch`` attr) at close
        self.supervisor = None
        # per-run span recorder (driver-attached; None = untraced run)
        self.tracer = None

        self.file_close_counter = 0
        self.file_open_counter = 0
        self.dataset_write_counter = 0
        self._unserved: List[File] = []
        self._broadcast_log: List[str] = []
        self._open_files: Dict[str, File] = {}
        self.log: List[Tuple[float, str]] = []
        # Serialize serving against the rescale channel swap: a resize of a
        # downstream task replaces entries of ``self.outgoing`` under this
        # lock, so a serve never straddles old and new channel sets.
        self.serve_lock = make_lock(f"vol.serve:{task}[{instance}]")

    # ------------------------------------------------------------ properties
    def set_memory(self, filename_pattern: str, dset_pattern: str = "*") -> None:
        self._props[filename_pattern] = "memory"

    def set_file(self, filename_pattern: str, dset_pattern: str = "*") -> None:
        self._props[filename_pattern] = "file"

    def set_ownership(self, filename_pattern: str, axis: int, nranks: int) -> None:
        """Declare that this task's ``nranks`` logical ranks own an even
        ``axis`` decomposition of every dataset written to matching files."""
        self._ownership.append((compile_file_pattern(filename_pattern),
                                int(axis), int(nranks)))

    def _stamp_ownership(self, f: File) -> None:
        """Apply declared producer ownership to a file at close time.

        Datasets that already carry an ownership map (task code called
        ``create_dataset(ownership=...)``) are left alone; scalars have no
        decomposition axis and are skipped; an axis beyond a dataset's rank
        is a workflow-description error and raises clearly."""
        from .redistribute import even_blocks

        for matcher, axis, nranks in self._ownership:
            if not (matcher.matches(f.filename)
                    or compile_file_pattern(f.filename).matches(matcher.pattern)):
                continue
            for ds in f.visit_datasets():
                if ds.ownership is not None and ds.ownership.blocks:
                    continue
                if not ds.shape:
                    continue  # scalar: nothing to decompose
                if axis >= len(ds.shape):
                    raise ValueError(
                        f"task {self.task!r}: declared ownership axis {axis} "
                        f"out of range for dataset {ds.path} with shape "
                        f"{ds.shape} in {f.filename!r}")
                own = BlockOwnership()
                for r, (s, sh) in enumerate(
                        even_blocks(ds.shape, nranks, axis=axis)):
                    own.add(r, s, sh)
                ds.ownership = own

    # ------------------------------------------------------------- callbacks
    def set_before_file_open(self, cb: Callable[[Any], None]) -> None:
        self._cb["before_file_open"] = cb

    def set_after_file_open(self, cb: Callable[[Any], None]) -> None:
        self._cb["after_file_open"] = cb

    def set_before_file_close(self, cb: Callable[[Any], None]) -> None:
        self._cb["before_file_close"] = cb

    def set_after_file_close(self, cb: Callable[[Any], None]) -> None:
        self._cb["after_file_close"] = cb

    def set_after_dataset_write(self, cb: Callable[[Any], None]) -> None:
        self._cb["after_dataset_write"] = cb

    def set_before_dataset_open(self, cb: Callable[[Any], None]) -> None:
        self._cb["before_dataset_open"] = cb

    def _fire(self, point: str, arg: Any) -> bool:
        """Fire a callback; returns True if a user callback handled the point."""
        cb = self._cb[point]
        if cb is not None:
            cb(arg)
            return True
        return False

    # --------------------------------------------------------- LowFive verbs
    def serve_all(self, memory: bool = True, file: bool = True) -> int:
        """Serve every unserved file to all matching outgoing channels.

        Flow control happens inside ``Channel.offer`` -- a skip there is not an
        error, it is the strategy working as intended.

        A per-file payload cache is shared across the fan-out: every channel
        with the same dataset selection AND the same declared M->N ownership
        (``Channel.redistribute``) ships a CoW view over ONE filtered payload
        instead of materializing its own copy (zero-copy fast path).  Sibling
        consumer instances of a redistributing port own different slabs, so
        they intentionally miss each other's cache entries.
        """
        n = 0
        sched_point("VOL.serve_all", key=("vol", id(self)))
        with self.serve_lock:
            for f in list(self._unserved):
                payload_cache: Dict[Any, File] = {}
                for ch in self.outgoing:
                    if not ch.matches_file(f.filename):
                        continue
                    if ch.mode == "memory" and not memory:
                        continue
                    if ch.mode == "file" and not file:
                        continue
                    if ch.offer(f, _payload_cache=payload_cache):
                        n += 1
        return n

    def clear_files(self) -> None:
        self._unserved.clear()

    def broadcast_files(self) -> None:
        """Rank-0 metadata broadcast (Nyx idiom). In the single-driver
        execution model this records the structural copy; per-rank views all
        share the driver's tree, so the broadcast is a metadata no-op but the
        event is logged for the custom-action tests."""
        self._broadcast_log.append(
            f"bcast@close={self.file_close_counter} files={[f.filename for f in self._unserved]}"
        )

    # ------------------------------------------------- h5-facing entry points
    def on_file_create(self, f: File) -> None:
        self._open_files[f.filename] = f

    def on_file_close(self, f: File) -> None:
        t0 = time.monotonic()
        sup = self.supervisor  # local: the driver may detach it concurrently
        if sup is not None:
            # every step boundary is a health signal for the stall watchdog
            sup.heartbeat(self.task, self.instance)
            # fault point "close": the producer crashes AT the step boundary,
            # before this step's data is served -- the canonical lost-step
            # (step is 0-based: the close about to complete)
            sup.fire(self.task, self.instance, "close", self.file_close_counter)
            # stamp the incarnation's epoch so consumers (and the recovery
            # tests) can tell which incarnation produced a payload
            f.attrs["wilkins_epoch"] = sup.epoch(self.task, self.instance)
        self._stamp_ownership(f)
        self._fire("before_file_close", f)
        self.file_close_counter += 1
        self._unserved.append(f)
        self._open_files.pop(f.filename, None)
        self.log.append((time.monotonic(), f"close:{f.filename}"))
        if not self._fire("after_file_close", f):
            # Default behaviour: serve at close, then drop our reference --
            # exactly LowFive's serve-on-close convention.
            self.serve_all(True, True)
            self.clear_files()
        tr = self.tracer  # local: the driver may detach it concurrently
        if tr is not None:
            # lifecycle span, not a wait: the rendezvous-blocked portion is
            # claimed by the nested channel.offer spans, the rest is serve
            # work (filter/slab/spill) on the producer's own clock
            tr.record("vol", "vol.close", self.task, self.instance, t0,
                      time.monotonic(), step=self.file_close_counter - 1,
                      filename=f.filename)
        sched = self.scheduler  # local: the driver may detach it concurrently
        if sched is not None:
            sched.notify_step("file_close")

    def on_file_open(self, filename: str) -> Optional[File]:
        """Consumer-side open: pull the next version from a matching channel.

        A consumer port may aggregate several producer instances (fan-in).
        All matching channels are multiplexed over one condition variable
        (``ChannelMux``): the consumer scans non-blockingly, then sleeps until
        ANY channel serves or finishes -- no polling loop.  The version-token
        handshake (token taken *before* the scan) makes a serve that lands
        between scan and wait impossible to miss.
        """
        sup = self.supervisor  # local: the driver may detach it concurrently
        if sup is not None:
            sup.heartbeat(self.task, self.instance)
            # fault point "open": the consumer crashes before asking for
            # data (nothing delivered yet -- restart re-opens cleanly)
            sup.fire(self.task, self.instance, "open", self.file_open_counter)
        self._fire("before_file_open", filename)
        chans = [c for c in self.incoming if c.matches_file(filename)]
        if not chans:
            return None  # not intercepted -> caller falls back to standalone
        mux = ChannelMux()
        for c in chans:
            c.add_listener(mux)
            # advertise the blocked consumer so `latest` producers serve us
            c.set_consumer_waiting(True)
        t0 = time.monotonic()
        # nested-wait guard: this loop accounts the whole multiplexed wait
        # itself, so a get() issued on one of these channels from inside the
        # scope must not add the same wall time to consumer_wait_s again
        scope = enter_mux_wait_scope(chans)
        try:
            while True:
                token = mux.token()
                any_live = False
                # the wait ends when data is FOUND; delivery work after the
                # take (future result on a prefetch miss, spill load) is
                # accounted by prefetch_blocked_s, never re-counted as wait
                t_scan = time.monotonic()
                for c in chans:
                    r = c.try_get()
                    if r is NO_DATA:
                        any_live = True
                    elif r is not None:
                        # under the channel lock: every other writer of
                        # consumer_wait_s holds it, and += on a float is
                        # read-modify-write -- a concurrent get() on a
                        # sibling consumer could otherwise lose the update
                        with c._lock:
                            c.stats.consumer_wait_s += t_scan - t0
                        # wait accounted: callbacks below may block anew
                        exit_mux_wait_scope(scope)
                        step = self.file_open_counter
                        self.file_open_counter += 1
                        tr = self.tracer  # local: driver may detach it
                        if tr is not None:
                            tr.record("vol", "vol.open.wait", self.task,
                                      self.instance, t0, t_scan, step=step,
                                      flow=("f", flow_id(c.name,
                                                         c.delivered_seq)),
                                      edge=c.name)
                        if sup is not None:
                            # fault point "recv": the payload WAS delivered
                            # (the channel's watermark moved, the replay
                            # buffer recorded it) but the task never saw it
                            # -- the window only the replay protocol covers
                            sup.fire(self.task, self.instance, "recv", step)
                        self._fire("after_file_open", r)
                        sched = self.scheduler  # local: driver may detach it
                        if sched is not None:
                            sched.notify_step("file_open")
                        return r
                if not any_live:
                    return None  # all producers report all-done (query protocol)
                if sup is not None:
                    # bounded sleep + heartbeat: a consumer parked in the
                    # fan-in mux is starved, not stalled (watchdog hysteresis)
                    sup.heartbeat(self.task, self.instance)
                    mux.wait(token, timeout=sup.wait_quantum(self.task))
                else:
                    mux.wait(token)
        finally:
            exit_mux_wait_scope(scope)  # idempotent on the delivery path
            for c in chans:
                c.set_consumer_waiting(False)
                c.remove_listener(mux)

    def on_dataset_write(self, ds) -> None:
        self.dataset_write_counter += 1
        self._fire("after_dataset_write", ds)

    def on_dataset_open(self, path: str) -> None:
        self._fire("before_dataset_open", path)

    # ------------------------------------------------------------- restart
    def reset_for_restart(self) -> None:
        """Fresh-incarnation reset: drop the dead incarnation's unserved
        files and open handles, restart the step counters.  Channel-side
        state (serve seqs, flow-control counters) is rewound separately by
        ``Channel.quarantine_producer`` -- the two never disagree because
        the supervisor calls both under the restart barrier."""
        self._unserved.clear()
        self._open_files.clear()
        self.file_close_counter = 0
        self.file_open_counter = 0
        self.dataset_write_counter = 0

    def update_ownership_nranks(self, old_nranks: int, new_nranks: int) -> None:
        """nprocs rescale: re-point declared producer decompositions at the
        new logical rank count (entries pinned to other counts -- an explicit
        YAML ``nranks:`` -- are left alone)."""
        self._ownership = [
            (m, axis, new_nranks if n == old_nranks else n)
            for (m, axis, n) in self._ownership]

    # ------------------------------------------------------------- shutdown
    def finalize(self) -> None:
        """Task function returned: serve any leftover files, mark all-done."""
        if self._unserved:
            self.serve_all(True, True)
            self.clear_files()
        with self.serve_lock:
            for ch in self.outgoing:
                ch.finish()

    def __repr__(self) -> str:
        return (f"<VOL task={self.task}[{self.instance}] out={len(self.outgoing)} "
                f"in={len(self.incoming)} closes={self.file_close_counter}>")
