"""repro.core -- the Wilkins in situ workflow system (the paper's contribution).

Layers (paper Fig. 1):
  workflow driver   -> driver.Wilkins            (Wilkins-master)
  workflow graph    -> graph.WorkflowGraph       (data-centric YAML matching)
  execution         -> comm.TaskComm             (restricted worlds)
  data transport    -> channel.Channel           (flow control all/some/latest)
                       redistribute              (M->N planning + executors)
  data model / VOL  -> datamodel, vol, h5        (HDF5 data model + interception)
  fault tolerance   -> recovery.RunSupervisor    (policies, epochs, fault plan)
"""

from . import datamodel, h5, redistribute, scheduler
from .channel import (Channel, ChannelError, ChannelMux, ChannelStats,
                      ChannelTimeout, FlowControl, NO_DATA, PrefetchPool)
from .recovery import (FailurePolicy, FaultPlan, FaultSpec, InjectedFault,
                       RecoveryContext, RescaleError, RescaleEvent,
                       RescaleInterrupt, RescaleOp, RunSupervisor, StallEvent,
                       SupersededError, TaskState, edge_key, reshard_blocks)
from .scheduler import (DepthAutotuner, FairPolicy, FifoPolicy,
                        ResizableSemaphore, SchedulerConfig, SchedulerRuntime,
                        TelemetryTimeline)
from .comm import TaskComm, world
from .datamodel import BlockOwnership, Dataset, File, Group
from .driver import TaskFailure, Wilkins, WorkflowReport
from .graph import DsetSpec, Edge, Port, TaskSpec, WorkflowGraph
from .redistribute import (CompiledPlan, PlanCache, RedistSpec, plan_cache,
                           reset_plan_cache)
from .vol import VOL, current_vol

__all__ = [
    "datamodel",
    "h5",
    "redistribute",
    "scheduler",
    "PrefetchPool",
    "DepthAutotuner",
    "FairPolicy",
    "FifoPolicy",
    "ResizableSemaphore",
    "SchedulerConfig",
    "SchedulerRuntime",
    "TelemetryTimeline",
    "Channel",
    "ChannelError",
    "ChannelMux",
    "ChannelStats",
    "ChannelTimeout",
    "FlowControl",
    "NO_DATA",
    "FailurePolicy",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "RecoveryContext",
    "RescaleError",
    "RescaleEvent",
    "RescaleInterrupt",
    "RescaleOp",
    "RunSupervisor",
    "StallEvent",
    "SupersededError",
    "TaskState",
    "edge_key",
    "reshard_blocks",
    "TaskComm",
    "world",
    "BlockOwnership",
    "Dataset",
    "File",
    "Group",
    "TaskFailure",
    "Wilkins",
    "WorkflowReport",
    "DsetSpec",
    "Edge",
    "Port",
    "TaskSpec",
    "WorkflowGraph",
    "CompiledPlan",
    "PlanCache",
    "RedistSpec",
    "plan_cache",
    "reset_plan_cache",
    "VOL",
    "current_vol",
]
