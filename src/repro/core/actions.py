"""User-defined custom actions (paper §3.5.2, Listings 3 & 5).

Users extend the otherwise declarative workflow with imperative callbacks by
supplying an external Python script; the YAML names it per task:

    actions: ["actions", "nyx"]     # script `actions.py`, function `nyx`

The function receives ``(vol, rank)`` -- the task instance's VOL object and
its rank -- and registers callbacks on the VOL's execution points
(``set_after_file_close`` etc.).  The Wilkins-master code itself is never
modified: this is the paper's middle ground between declarative and
imperative interfaces.
"""

from __future__ import annotations

import importlib
import importlib.util
import os
import sys
from typing import Callable, Optional, Tuple

__all__ = ["load_action"]


def load_action(spec: Tuple[str, str], search_dirs=()) -> Callable:
    """Resolve (script_or_module, function) to a callable.

    ``script_or_module`` may be a path to a ``.py`` file (with or without the
    extension, searched in ``search_dirs`` then the CWD) or an importable
    module name.
    """
    modname, funcname = spec
    candidates = []
    for d in list(search_dirs) + [os.getcwd()]:
        candidates.append(os.path.join(d, modname + ".py"))
        candidates.append(os.path.join(d, modname))
    for path in candidates:
        if os.path.isfile(path):
            spec_ = importlib.util.spec_from_file_location(
                f"wilkins_actions_{os.path.basename(modname)}", path
            )
            mod = importlib.util.module_from_spec(spec_)
            spec_.loader.exec_module(mod)
            return getattr(mod, funcname)
    # fall back to a normal import
    mod = importlib.import_module(modname)
    return getattr(mod, funcname)
