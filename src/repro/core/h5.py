"""User-facing HDF5-style API -- identical standalone and inside a workflow.

This is the paper's ease-of-adoption contract: task codes perform ordinary
(HDF5-style) I/O through this module and run *unmodified* both as standalone
programs and inside a Wilkins workflow.  Standalone, ``File(..., "w")`` writes
a real container file to disk at close and ``File(..., "r")`` reads one back.
In a workflow, the ambient VOL object (installed by the driver, analogous to
enabling the LowFive plugin through environment variables) intercepts the same
calls and routes the data through memory channels with flow control.

    from repro.core import h5

    def producer():                      # user task code -- no workflow API
        for t in range(10):
            with h5.File("outfile.h5", "w") as f:
                f.create_dataset("/group1/grid", data=grid)
                f.create_dataset("/group1/particles", data=parts)

    def consumer():
        while True:
            f = h5.File("outfile.h5", "r")
            if f is None:                # producer says all-done
                break
            grid = f["/group1/grid"][:]
"""

from __future__ import annotations

import os
from typing import Any, Optional, Tuple

import numpy as np

from . import datamodel
from .vol import current_vol

__all__ = ["File", "set_standalone_dir"]

_standalone_dir = os.environ.get("WILKINS_STANDALONE_DIR", ".")


def set_standalone_dir(path: str) -> None:
    global _standalone_dir
    _standalone_dir = path


class _H5File:
    """Proxy over ``datamodel.File`` firing VOL execution points."""

    def __init__(self, inner: datamodel.File, mode: str, vol=None):
        self._inner = inner
        self._mode = mode
        self._vol = vol
        self._closed = False

    # -- writes ---------------------------------------------------------
    def create_dataset(self, path: str, shape=None, dtype=None, data=None,
                       ownership: Optional[datamodel.BlockOwnership] = None):
        ds = self._inner.create_dataset(path, shape=shape, dtype=dtype, data=data)
        if ownership is not None:
            ds.ownership = ownership
        if self._vol is not None:
            self._vol.on_dataset_write(ds)
        return ds

    def require_group(self, path: str):
        return self._inner.require_group(path)

    # -- reads ----------------------------------------------------------
    def __getitem__(self, path: str):
        if self._vol is not None:
            self._vol.on_dataset_open(path)
        return self._inner[path]

    def __contains__(self, path: str) -> bool:
        return path in self._inner

    def get(self, path: str):
        return self._inner.get(path)

    def visit_datasets(self):
        return self._inner.visit_datasets()

    @property
    def attrs(self):
        return self._inner.attrs

    @property
    def filename(self) -> str:
        return self._inner.filename

    def total_bytes(self) -> int:
        return self._inner.total_bytes()

    @property
    def inner(self) -> datamodel.File:
        return self._inner

    # -- lifecycle --------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        if self._mode == "w":
            if self._vol is not None:
                self._vol.on_file_close(self._inner)
            else:
                self._inner.save(_standalone_dir)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def File(filename: str, mode: str = "r") -> Optional[_H5File]:
    """Open a file for writing ("w") or reading ("r").

    Reading inside a workflow blocks until the next version of the file
    arrives over a matched channel and returns ``None`` when all matched
    producers are done (the paper's query protocol).
    """
    vol = current_vol()
    if mode == "w":
        inner = datamodel.File(filename)
        if vol is not None:
            vol.on_file_create(inner)
        return _H5File(inner, "w", vol)
    if mode == "r":
        if vol is not None and vol.incoming:
            inner = vol.on_file_open(filename)
            if inner is None:
                # Either all-done, or this filename is not intercepted.
                if any(c.matches_file(filename) for c in vol.incoming):
                    return None
            else:
                return _H5File(inner, "r", vol)
        # standalone fallback: load from disk
        path = os.path.join(_standalone_dir, os.path.basename(filename))
        if not os.path.exists(path):
            raise FileNotFoundError(path)
        return _H5File(datamodel.File.load(path), "r", vol)
    raise ValueError(f"mode must be 'r' or 'w', got {mode!r}")
