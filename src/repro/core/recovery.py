"""Fault-tolerant execution: task supervision, checkpointed restart, and a
deterministic fault-injection harness.

Wilkins promises resilient coupling of tasks with disparate data rates, but a
single crash used to kill the whole run -- the error chaining reported the
failure cleanly, yet nothing recovered.  This module turns the transport's
existing machinery (epochal channels, the reshard ``PlanCache``,
``train/checkpoint.py``'s ``AsyncCheckpointer``) into a recovery feature:

* **FailurePolicy** -- the per-task YAML ``on_failure:`` declaration:

  - ``fail``    (default): today's behaviour -- the error is chained onto the
    run's primary exception and the partial ``WorkflowReport`` rides on it.
    Additionally the dead task's outgoing channels are *poisoned* so a
    consumer blocked in ``Channel.get()`` raises a ``ChannelError`` naming
    the dead task immediately instead of waiting out its timeout.
  - ``restart: {max_retries, backoff_s, jitter}``: the supervisor quarantines
    the failed instance's channels under a new epoch, restores task state
    through ``TaskComm.checkpoint()/restore()``, and relaunches the callable.
    Jitter is *deterministic* (hashed from task/instance/attempt), so
    recovery paths are testable without flaky sleeps.
  - ``drop``: optional analysis tasks degrade to no-ops -- outgoing channels
    finish (consumers see producer-done), incoming channels are abandoned
    (producers' offers turn into counted drops instead of blocking).

* **FaultPlan / FaultSpec / InjectedFault** -- deterministic fault injection
  at named points (``start``, ``close``, ``open``, ``recv``, ``prefetch``)
  keyed by (task, instance, step, attempt).  Threaded through
  ``Wilkins.run(faults=...)``; every recovery path is reachable from a test
  without sleeping for "long enough".

* **RunSupervisor** -- the per-run object the driver owns: task lifecycle
  states (RUNNING -> FAILED -> RESTARTING -> DONE / DROPPED), per-instance
  epoch + attempt counters, fault firing, and the channel surgery for
  quarantine / poison / drop.

* **RecoveryContext** -- the per-instance face of ``TaskComm.checkpoint()``
  and ``TaskComm.restore()``: saves through ``AsyncCheckpointer`` (atomic
  directories, LATEST pointer) and *acks* the instance's channels -- a
  producer's serves up to the checkpoint are durable (quarantine keeps
  them), a consumer's deliveries up to the checkpoint are consumed
  (quarantine replays only what came after).

* **reshard_blocks** -- restores taken at one rank count replay onto another
  through a cached ``PlanCache`` reshard plan (the live M->N rescale face of
  the redistribution subsystem).

Nothing here imports driver/graph/channel -- channels and vols are duck-typed
(``quarantine_producer``, ``poison``, ``producer``/``consumer`` tuples), so
``graph.py`` and ``channel.py`` can both import this module without cycles.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import threading

from ..analysis.lockcheck import check_blocking, make_lock, sched_point
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

__all__ = [
    "FailurePolicy",
    "FaultPlan",
    "FaultSpec",
    "InjectedFault",
    "FAULT_KINDS",
    "FAULT_POINTS",
    "TaskState",
    "RestartEvent",
    "RescaleEvent",
    "StallEvent",
    "RescaleInterrupt",
    "RescaleError",
    "SupersededError",
    "RescaleOp",
    "RecoveryContext",
    "RunSupervisor",
    "edge_key",
    "reshard_blocks",
]


# ---------------------------------------------------------------------------
# failure policy (YAML `on_failure:` per task)
# ---------------------------------------------------------------------------
POLICY_KINDS = ("fail", "restart", "drop", "rescale")


@dataclass(frozen=True)
class FailurePolicy:
    """Per-task failure handling, parsed from the YAML ``on_failure:`` block.

    ``managed`` distinguishes a YAML-declared restart (full recovery protocol:
    epoch quarantine, checkpoint restore, replay) from the legacy
    ``Wilkins(max_restarts=N)`` budget, which restarts the callable *without*
    channel surgery -- bit-for-bit the pre-recovery behaviour.
    """

    kind: str = "fail"
    max_retries: int = 0
    backoff_s: float = 0.0
    jitter: float = 0.0
    managed: bool = True
    # rescale-only knobs: the instance count / logical rank count the task
    # restarts at.  None = keep the current value.
    nslots: Optional[int] = None
    nprocs: Optional[int] = None

    def backoff(self, task: str, instance: int, attempt: int) -> float:
        """Exponential backoff with DETERMINISTIC jitter.

        The jitter term is hashed from (task, instance, attempt), not drawn
        from a RNG: two runs of the same workflow with the same fault plan
        recover on the same schedule, which is what makes the recovery suite
        assertable without sleeps-and-hope."""
        if self.backoff_s <= 0 and self.jitter <= 0:
            return 0.0
        base = self.backoff_s * (2 ** attempt)
        if self.jitter > 0:
            h = hashlib.sha256(
                f"{task}:{instance}:{attempt}".encode()).digest()
            u = int.from_bytes(h[:8], "little") / 2 ** 64  # [0, 1)
            base += self.jitter * u
        return base

    @classmethod
    def from_yaml(cls, doc: Any, task: str = "?") -> "FailurePolicy":
        """Parse ``on_failure:`` with the task named in every error.

        Accepted spellings::

            on_failure: fail                 # default (today's behaviour)
            on_failure: drop                 # optional task: degrade to no-op
            on_failure: restart              # restart with defaults
            on_failure:
              restart: {max_retries: 3, backoff_s: 0.1, jitter: 0.05}
        """
        if doc is None:
            return cls()
        if isinstance(doc, str):
            if doc == "restart":
                return cls(kind="restart", max_retries=1)
            if doc in ("fail", "drop"):
                return cls(kind=doc)
            raise ValueError(
                f"task {task!r}: on_failure {doc!r} is invalid; use one of "
                f"{POLICY_KINDS} (or a restart: mapping)")
        if isinstance(doc, dict):
            if "rescale" in doc and "drop" in doc:
                raise ValueError(
                    f"task {task!r}: on_failure cannot combine rescale: with "
                    f"drop: -- a dropped task has no instances left to "
                    f"restart at a new size; pick one")
            unknown = set(doc) - {"restart", "rescale"}
            if unknown:
                raise ValueError(
                    f"task {task!r}: unknown on_failure keys "
                    f"{sorted(unknown)} (expected a restart: or rescale: "
                    f"mapping, or the strings fail/drop/restart)")
            if "restart" in doc and "rescale" in doc:
                raise ValueError(
                    f"task {task!r}: on_failure cannot combine restart: and "
                    f"rescale:; a rescale IS a supervised restart (use "
                    f"rescale with the current size for a same-size restart)")
            if "rescale" in doc:
                return cls._parse_rescale(doc["rescale"], task)
            r = doc.get("restart")
            if r is None:
                raise ValueError(
                    f"task {task!r}: on_failure mapping must carry a "
                    f"restart: block")
            if not isinstance(r, dict):
                raise ValueError(
                    f"task {task!r}: on_failure restart must be a mapping "
                    f"{{max_retries, backoff_s, jitter}}, got {r!r}")
            bad = set(r) - {"max_retries", "backoff_s", "jitter"}
            if bad:
                raise ValueError(
                    f"task {task!r}: unknown on_failure restart keys "
                    f"{sorted(bad)} (expected max_retries, backoff_s, jitter)")
            retries = int(r.get("max_retries", 1))
            if retries < 1:
                raise ValueError(
                    f"task {task!r}: on_failure restart max_retries must be "
                    f">= 1, got {retries} (use on_failure: fail for no "
                    f"restarts)")
            backoff = float(r.get("backoff_s", 0.0))
            if backoff < 0:
                raise ValueError(
                    f"task {task!r}: on_failure restart backoff_s must be "
                    f">= 0, got {backoff}")
            jitter = float(r.get("jitter", 0.0))
            if jitter < 0:
                raise ValueError(
                    f"task {task!r}: on_failure restart jitter must be >= 0, "
                    f"got {jitter}")
            return cls(kind="restart", max_retries=retries,
                       backoff_s=backoff, jitter=jitter)
        raise ValueError(
            f"task {task!r}: on_failure must be fail/drop/restart or a "
            f"restart: mapping, got {doc!r}")

    @classmethod
    def _parse_rescale(cls, r: Any, task: str) -> "FailurePolicy":
        """Parse ``on_failure: {rescale: {nslots, nprocs, ...}}``."""
        if not isinstance(r, dict):
            raise ValueError(
                f"task {task!r}: on_failure rescale must be a mapping "
                f"{{nslots, nprocs, max_retries, backoff_s, jitter}}, "
                f"got {r!r}")
        bad = set(r) - {"nslots", "nprocs", "max_retries", "backoff_s",
                        "jitter"}
        if bad:
            raise ValueError(
                f"task {task!r}: unknown on_failure rescale keys "
                f"{sorted(bad)} (expected nslots, nprocs, max_retries, "
                f"backoff_s, jitter)")
        nslots = r.get("nslots")
        nprocs = r.get("nprocs")
        if nslots is None and nprocs is None:
            raise ValueError(
                f"task {task!r}: on_failure rescale needs nslots and/or "
                f"nprocs (the size the task restarts at)")
        if nslots is not None:
            nslots = int(nslots)
            if nslots < 1:
                raise ValueError(
                    f"task {task!r}: on_failure rescale nslots must be >= 1, "
                    f"got {nslots} (use on_failure: drop to remove the task)")
        if nprocs is not None:
            nprocs = int(nprocs)
            if nprocs < 1:
                raise ValueError(
                    f"task {task!r}: on_failure rescale nprocs must be >= 1, "
                    f"got {nprocs}")
        retries = int(r.get("max_retries", 1))
        if retries < 1:
            raise ValueError(
                f"task {task!r}: on_failure rescale max_retries must be >= 1, "
                f"got {retries}")
        backoff = float(r.get("backoff_s", 0.0))
        if backoff < 0:
            raise ValueError(
                f"task {task!r}: on_failure rescale backoff_s must be >= 0, "
                f"got {backoff}")
        jitter = float(r.get("jitter", 0.0))
        if jitter < 0:
            raise ValueError(
                f"task {task!r}: on_failure rescale jitter must be >= 0, "
                f"got {jitter}")
        return cls(kind="rescale", max_retries=retries, backoff_s=backoff,
                   jitter=jitter, nslots=nslots, nprocs=nprocs)


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------
FAULT_KINDS = ("crash", "stall", "slow_io")
#: named injection points: ``start`` fires at task-callable launch (step =
#: attempt), ``close`` at producer file close *before* the serve, ``open`` at
#: consumer intercepted open *before* any delivery, ``recv`` after a payload
#: was delivered but before task code sees it (the replay-protocol window),
#: ``prefetch`` inside the async payload prep on the pool worker.
FAULT_POINTS = ("start", "close", "open", "recv", "prefetch")


class InjectedFault(RuntimeError):
    """A crash raised by the fault-injection harness (never by real code)."""

    def __init__(self, task: str, instance: int, point: str, step: int,
                 attempt: int):
        super().__init__(
            f"injected crash: {task}[{instance}] at {point} step={step} "
            f"attempt={attempt}")
        self.task = task
        self.instance = instance
        self.point = point
        self.step = step
        self.attempt = attempt


@dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault: fires when (task, instance, point, step,
    attempt) all match.  ``instance``/``step``/``attempt`` of ``None`` match
    anything; ``times`` bounds total firings (default once).  ``seconds`` is
    the stall / slow-io duration."""

    task: str
    kind: str = "crash"
    point: str = "close"
    instance: Optional[int] = None
    step: Optional[int] = None
    attempt: Optional[int] = 0
    times: Optional[int] = 1
    seconds: float = 0.05

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"fault kind {self.kind!r} is invalid; use one of {FAULT_KINDS}")
        if self.point not in FAULT_POINTS:
            raise ValueError(
                f"fault point {self.point!r} is invalid; use one of "
                f"{FAULT_POINTS}")
        if self.seconds < 0:
            raise ValueError(f"fault seconds must be >= 0, got {self.seconds}")
        if self.times is not None and self.times < 1:
            raise ValueError(f"fault times must be >= 1, got {self.times}")

    def matches(self, task: str, instance: int, point: str, step: int,
                attempt: int) -> bool:
        return (self.task == task and self.point == point
                and (self.instance is None or self.instance == instance)
                and (self.step is None or self.step == step)
                and (self.attempt is None or self.attempt == attempt))


class FaultPlan:
    """An ordered set of ``FaultSpec``s with per-spec firing budgets.

    ``fire`` is called from the VOL hooks / prefetch preps with the current
    (task, instance, point, step, attempt) coordinates; a matching ``crash``
    spec raises ``InjectedFault``, ``stall``/``slow_io`` sleep for
    ``seconds``.  Counting is thread-safe (preps fire from pool workers).
    """

    def __init__(self, specs: Sequence[Union[FaultSpec, Dict[str, Any]]] = ()):
        self.specs: List[FaultSpec] = [
            s if isinstance(s, FaultSpec) else FaultSpec(**s) for s in specs]
        self._fired: Dict[int, int] = {}
        self._lock = make_lock("leaf:faults")
        self.log: List[Tuple[str, str, int, str, int, int]] = []

    @classmethod
    def coerce(cls, faults: Any) -> Optional["FaultPlan"]:
        if faults is None:
            return None
        if isinstance(faults, FaultPlan):
            return faults
        if isinstance(faults, (FaultSpec, dict)):
            return cls([faults])
        return cls(list(faults))

    def fire(self, task: str, instance: int, point: str, step: int,
             attempt: int) -> None:
        for i, spec in enumerate(self.specs):
            if not spec.matches(task, instance, point, step, attempt):
                continue
            with self._lock:
                n = self._fired.get(i, 0)
                if spec.times is not None and n >= spec.times:
                    continue
                self._fired[i] = n + 1
                self.log.append((spec.kind, task, instance, point, step,
                                 attempt))
            if spec.kind == "crash":
                raise InjectedFault(task, instance, point, step, attempt)
            check_blocking("sleep")
            time.sleep(spec.seconds)  # stall / slow_io

    def fired(self) -> int:
        with self._lock:
            return sum(self._fired.values())


# ---------------------------------------------------------------------------
# lifecycle
# ---------------------------------------------------------------------------
class TaskState:
    PENDING = "PENDING"
    RUNNING = "RUNNING"
    FAILED = "FAILED"
    RESTARTING = "RESTARTING"
    DONE = "DONE"
    DROPPED = "DROPPED"


@dataclass
class RestartEvent:
    t: float
    task: str
    instance: int
    attempt: int          # the attempt that FAILED (restart launches attempt+1)
    epoch: int            # the new epoch the instance restarts into
    reason: str

    def as_dict(self) -> Dict[str, Any]:
        return {"t": self.t, "task": self.task, "instance": self.instance,
                "attempt": self.attempt, "epoch": self.epoch,
                "reason": self.reason}


# ---------------------------------------------------------------------------
# elastic rescale: events, interrupts, and the per-task rescale operation
# ---------------------------------------------------------------------------
class RescaleInterrupt(Exception):
    """Raised out of a blocked/next channel operation to pull a sibling
    instance out of its task callable so the task can be resized.  Not an
    error: the driver converts it into op participation, never a failure."""

    def __init__(self, task: str = "?", instance: int = -1):
        super().__init__(f"rescale interrupt: {task}[{instance}]")
        self.task = task
        self.instance = instance


class SupersededError(RuntimeError):
    """Raised by a retired incarnation's checkpoint/channel surface after a
    rescale replaced it -- a fenced zombie (e.g. a stalled thread that woke
    up late) must exit quietly, not corrupt the new incarnation's state."""


class RescaleError(RuntimeError):
    """A rescale could not be performed safely (lost retention window,
    inconsistent replicated state, missing checkpoint shard...)."""


@dataclass
class RescaleEvent:
    t: float
    task: str
    old_nslots: int
    new_nslots: int
    old_nprocs: int
    new_nprocs: int
    trigger: str          # "policy" (crash), "stall" (watchdog), "api"
    cut_step: int = -1    # checkpoint step the task restarted from (-1 fresh)
    latency_s: float = 0.0
    reason: str = ""

    def as_dict(self) -> Dict[str, Any]:
        return {"t": self.t, "task": self.task,
                "old_nslots": self.old_nslots, "new_nslots": self.new_nslots,
                "old_nprocs": self.old_nprocs, "new_nprocs": self.new_nprocs,
                "trigger": self.trigger, "cut_step": self.cut_step,
                "latency_s": self.latency_s, "reason": self.reason}


@dataclass
class StallEvent:
    t: float
    task: str
    instance: int
    silent_s: float
    timeout_s: float
    action: str           # what the policy did about it: "rescale" / "drop"

    def as_dict(self) -> Dict[str, Any]:
        return {"t": self.t, "task": self.task, "instance": self.instance,
                "silent_s": self.silent_s, "timeout_s": self.timeout_s,
                "action": self.action}


_INSTANCE_RE = re.compile(r"\[\d+\]")


def edge_key(channel_name: str) -> str:
    """Instance-invariant identity of a channel's edge.

    Channel names carry instance indices (``p[0]->c[1]:a.h5``); the rescale
    protocol needs to match per-edge state (consumed seqs at a checkpoint
    step) across instances of different incarnation sizes, so sidecars key by
    the name with the indices stripped (``p->c:a.h5``)."""
    return _INSTANCE_RE.sub("", channel_name)


class RescaleOp:
    """One pending M->N resize of a task: the rendezvous between the
    triggering event, the task's still-live sibling instances, and the driver
    surgery that rebuilds channels/checkpoints at the new size.

    Lifecycle: created under the supervisor lock (capturing the set of
    instances that must stop touching the old channels), siblings *arrive* as
    their channel operations raise ``RescaleInterrupt``; the LAST arriver
    becomes the leader and executes the surgery callback.  If the required
    set is empty (every instance already finished, or the sole instance is a
    fenced zombie) the triggering thread leads immediately."""

    def __init__(self, task: str, old_nslots: int, new_nslots: int,
                 old_nprocs: int, new_nprocs: int, trigger: str,
                 reason: str = ""):
        self.task = task
        self.old_nslots = old_nslots
        self.new_nslots = new_nslots
        self.old_nprocs = old_nprocs
        self.new_nprocs = new_nprocs
        self.trigger = trigger
        self.reason = reason
        self.t0 = time.monotonic()
        self.required: set = set()
        self.arrived: set = set()
        self.leader_claimed = False
        self.done = threading.Event()
        self.cut_step = -1
        self.error: Optional[BaseException] = None


# ---------------------------------------------------------------------------
# checkpoint / restore surface (TaskComm.checkpoint / restore)
# ---------------------------------------------------------------------------
class RecoveryContext:
    """Per-instance checkpoint surface, wired onto the TaskComm by the driver.

    ``checkpoint(state)`` snapshots a pytree through ``AsyncCheckpointer``
    (atomic container + LATEST pointer) and then *acks* the instance's
    channels: serves/deliveries up to this point are durable, so a later
    quarantine keeps them and replays only what came after.  ``restore``
    returns ``(step, state)`` from the newest checkpoint, or ``None`` on a
    fresh start.  Both are no-ops-by-absence: standalone task code (no
    workflow) sees ``comm.checkpoint(...) is None`` and runs unchanged.
    """

    def __init__(self, task: str, instance: int, directory: str,
                 incoming: Sequence[Any] = (), outgoing: Sequence[Any] = ()):
        self.task = task
        self.instance = instance
        self.directory = directory
        self.incoming = list(incoming)
        self.outgoing = list(outgoing)
        self.attempt = 0
        self.epoch = 0
        self._ck = None
        self._next_step = 0
        self._lock = make_lock("leaf:recovery_ctx")
        # set by a rescale when a newer incarnation owns this (task, instance):
        # every later checkpoint/ack/restore from the fenced zombie raises.
        self.superseded = False

    def _checkpointer(self):
        # lazy: tasks that never checkpoint never create the directory
        with self._lock:
            if self._ck is None:
                from ..train.checkpoint import AsyncCheckpointer
                self._ck = AsyncCheckpointer(self.directory, keep=3)
            return self._ck

    def checkpoint(self, state: Any, step: Optional[int] = None,
                   block: bool = True,
                   sharded_axes: Optional[Dict[str, int]] = None) -> int:
        """Save ``state`` and ack this instance's channels.

        ``block=True`` (the default) waits for the container to be durable
        before acking -- the ack is what tells quarantine "steps up to here
        are consumed/served", so acking an un-durable checkpoint would lose
        data on a crash in the write window.  ``block=False`` overlaps the
        write with compute at the cost of that window (cadence guidance in
        DESIGN.md).

        ``sharded_axes`` declares which top-level keys of a flat-dict state
        hold this instance's *shard* of a task-global array (key -> axis);
        a later M->N rescale re-cuts exactly those leaves through
        ``reshard_blocks`` and requires the rest to be replicas.  The
        declaration is persisted next to the checkpoints (``sharded.json``)
        so the rescale surgery -- which runs with no task code on the stack
        -- can find it."""
        if self.superseded:
            raise SupersededError(
                f"{self.task}[{self.instance}]: checkpoint after rescale "
                f"superseded this incarnation")
        ck = self._checkpointer()
        if step is None:
            step = self._next_step
        ck.save(step, state, block=block)
        self._next_step = step + 1
        if sharded_axes:
            self._write_json("sharded.json", dict(sharded_axes))
        # per-step consumed-seq sidecar: which channel seq each incoming edge
        # had delivered when this step became durable.  The rescale cut
        # replays everything after this watermark into the new partition.
        # Duck-typed stand-ins without the rescale surface just don't get a
        # watermark (they can't be rescaled either).
        self._write_json(
            f"seqs_{step:08d}.json",
            {"step": step,
             "seqs": {edge_key(ch.name): ch.delivered_seq
                      for ch in self.incoming
                      if hasattr(ch, "name")
                      and hasattr(ch, "delivered_seq")}})
        self.ack()
        return step

    def _write_json(self, name: str, payload: Dict[str, Any]) -> None:
        os.makedirs(self.directory, exist_ok=True)
        tmp = os.path.join(self.directory, name + ".tmp")
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, os.path.join(self.directory, name))

    def ack(self) -> None:
        """Mark everything served/delivered so far as durable (checkpointed)."""
        if self.superseded:
            raise SupersededError(
                f"{self.task}[{self.instance}]: ack after rescale superseded "
                f"this incarnation")
        for ch in self.outgoing:
            ch.ack_producer()
        for ch in self.incoming:
            ch.ack_consumer()

    def restore(self, like: Any) -> Optional[Tuple[int, Any]]:
        """(step, state) from the newest checkpoint, or None on fresh start."""
        if self.superseded:
            raise SupersededError(
                f"{self.task}[{self.instance}]: restore after rescale "
                f"superseded this incarnation")
        from ..train.checkpoint import restore_latest
        out = restore_latest(self.directory, like)
        if out is not None:
            self._next_step = out[0] + 1
        return out

    def latest_step(self) -> Optional[int]:
        """Newest durable checkpoint step, without creating the directory."""
        p = os.path.join(self.directory, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip())


# ---------------------------------------------------------------------------
# live M->N rescale of restored state (PlanCache replay)
# ---------------------------------------------------------------------------
def reshard_blocks(blocks: Sequence[Any], new_nranks: int,
                   axis: int = 0) -> List[Any]:
    """Re-split per-rank blocks saved by M ranks onto N ranks.

    The checkpointed decomposition (M contiguous blocks along ``axis``)
    becomes the src side of a redistribution plan and the even N-way split is
    the dst side; the plan comes from the process-wide ``PlanCache`` (so a
    whole ensemble restoring at a new scale compiles the M->N intersection
    once) and executes as the scatter path -- the global array is never
    stitched.  This is how a restart at a different rank count replays a
    checkpoint: the reshard machinery, turned from a startup feature into a
    recovery feature."""
    import numpy as np

    from .redistribute import even_blocks, plan_cache

    arrs = [np.asarray(b) for b in blocks]
    if not arrs:
        raise ValueError("reshard_blocks needs at least one source block")
    if new_nranks < 1:
        raise ValueError(f"new_nranks must be >= 1, got {new_nranks}")
    nd = arrs[0].ndim
    if not 0 <= axis < nd:
        raise ValueError(f"axis {axis} out of range for rank-{nd} blocks")
    gshape = list(arrs[0].shape)
    gshape[axis] = sum(a.shape[axis] for a in arrs)
    gshape = tuple(gshape)
    src = []
    off = 0
    for a in arrs:
        if tuple(a.shape[:axis]) + tuple(a.shape[axis + 1:]) != \
                tuple(gshape[:axis]) + tuple(gshape[axis + 1:]):
            raise ValueError(
                f"source blocks disagree off-axis: {a.shape} vs global "
                f"{gshape} along axis {axis}")
        starts = tuple(off if d == axis else 0 for d in range(nd))
        src.append((starts, tuple(a.shape)))
        off += a.shape[axis]
    dst = even_blocks(gshape, new_nranks, axis=axis)
    plan = plan_cache().get(src, dst, gshape, arrs[0].dtype)
    return plan.execute(arrs)


# ---------------------------------------------------------------------------
# the per-run supervisor
# ---------------------------------------------------------------------------
class RunSupervisor:
    """Per-run task supervision: lifecycle states, epochs, fault firing, and
    the channel surgery behind restart / drop / permanent failure.

    The driver owns one per ``run()``; channels and VOLs get a reference for
    the duration (fault injection + epoch stamping) and are detached on
    teardown.  All channel mutation happens through the channels' own
    epoch-aware verbs (``quarantine_producer``/``quarantine_consumer``/
    ``poison``/``abandon_consumer``/``finish``), so the supervisor holds no
    channel locks itself.
    """

    def __init__(self, policies: Dict[str, FailurePolicy],
                 channels: Sequence[Any],
                 faults: Optional[FaultPlan] = None,
                 task_counts: Optional[Dict[str, int]] = None,
                 stall_timeouts: Optional[Dict[str, float]] = None):
        self.policies = dict(policies)
        self.channels = list(channels)
        self.faults = faults
        self._lock = make_lock("supervisor:run")
        self._state: Dict[Tuple[str, int], str] = {}
        self._attempt: Dict[Tuple[str, int], int] = {}
        self._epoch: Dict[Tuple[str, int], int] = {}
        self.restarts: List[RestartEvent] = []
        self.dropped: List[Tuple[str, int]] = []
        # ---- elastic rescale / watchdog state -----------------------------
        self.task_counts: Dict[str, int] = dict(task_counts or {})
        self.task_nprocs: Dict[str, int] = {}
        self.stall_timeouts: Dict[str, float] = dict(stall_timeouts or {})
        self.rescales: List[RescaleEvent] = []
        self.stalls: List[StallEvent] = []
        self._pending_rescale: Dict[str, RescaleOp] = {}
        self._gen: Dict[str, int] = {}          # bumped per completed rescale
        self._fenced: set = set()               # (task, inst) zombies
        self._hb_lock = make_lock("supervisor.hb:run")
        self._hb: Dict[Tuple[str, int], Tuple[int, float]] = {}
        self._strikes: Dict[Tuple[str, int], Tuple[int, int]] = {}
        # driver-installed callbacks: surgery executor + rescale validator
        self.on_rescale: Optional[Callable[[RescaleOp], None]] = None
        self.validate_rescale: Optional[Callable[..., None]] = None
        # per-run SpanRecorder (driver-attached on traced runs)
        self.tracer: Optional[Any] = None

    # ------------------------------------------------------------- queries
    def policy_for(self, task: str) -> FailurePolicy:
        return self.policies.get(task, FailurePolicy())

    def attempt(self, task: str, instance: int) -> int:
        with self._lock:
            return self._attempt.get((task, instance), 0)

    def epoch(self, task: str, instance: int) -> int:
        with self._lock:
            return self._epoch.get((task, instance), 0)

    def state(self, task: str, instance: int) -> str:
        with self._lock:
            return self._state.get((task, instance), TaskState.PENDING)

    def states(self) -> Dict[Tuple[str, int], str]:
        with self._lock:
            return dict(self._state)

    @property
    def recovery_active(self) -> bool:
        """True when this run can exercise recovery paths (managed restart
        policies or injected faults) -- gates the prep-retry fast path."""
        return self.faults is not None or bool(self.stall_timeouts) or any(
            p.kind in ("restart", "drop", "rescale") and p.managed
            for p in self.policies.values())

    # ----------------------------------------------------------- lifecycle
    def mark(self, task: str, instance: int, state: str) -> None:
        with self._lock:
            self._state[(task, instance)] = state
        if state == TaskState.RUNNING:
            # a fresh incarnation starts with a full stall-timeout budget
            self.heartbeat(task, instance)

    def fire(self, task: str, instance: int, point: str, step: int) -> None:
        """Fault-injection hook: no-op without a plan."""
        if self.faults is not None:
            self.faults.fire(task, instance, point, step,
                             self.attempt(task, instance))

    def _instance_channels(self, task: str, instance: int):
        outgoing = [c for c in self.channels if c.producer == (task, instance)]
        incoming = [c for c in self.channels if c.consumer == (task, instance)]
        return incoming, outgoing

    def begin_restart(self, task: str, instance: int, error: BaseException,
                      vol: Any = None) -> RestartEvent:
        """Quarantine the dead incarnation and open the next epoch.

        Outgoing channels drop un-acked queued payloads (the restarted
        producer regenerates them from its checkpoint; in-flight prefetch
        futures are cancelled) and rewind their serve/flow-control counters
        to the last ack.  Incoming channels requeue delivered-but-unacked
        payloads for replay and rewind the dedup watermark.  Producers
        blocked in ``offer()`` are woken by the queue surgery and
        re-rendezvous against the new epoch."""
        t0 = time.monotonic()
        with self._lock:
            key = (task, instance)
            self._attempt[key] = self._attempt.get(key, 0) + 1
            self._epoch[key] = self._epoch.get(key, 0) + 1
            attempt = self._attempt[key] - 1
            epoch = self._epoch[key]
            self._state[key] = TaskState.RESTARTING
        incoming, outgoing = self._instance_channels(task, instance)
        # the restart window: counters are updated but no queue surgery has
        # happened yet -- the explorer preempts between the two
        sched_point("RunSupervisor.quarantine", key=("restart", task, instance))
        for ch in outgoing:
            ch.quarantine_producer(epoch)
        for ch in incoming:
            ch.quarantine_consumer(epoch)
        if vol is not None:
            vol.reset_for_restart()
        now = time.monotonic()
        if self.tracer is not None:
            self.tracer.record("recovery", "recovery.restart", task, instance,
                               t0, now, attempt=attempt, epoch=epoch,
                               error=type(error).__name__)
        ev = RestartEvent(now, task, instance, attempt, epoch,
                          f"{type(error).__name__}: {error}")
        with self._lock:
            self.restarts.append(ev)
        return ev

    def drop(self, task: str, instance: int) -> None:
        """Degrade the instance's edges to no-ops (optional analysis task)."""
        incoming, outgoing = self._instance_channels(task, instance)
        for ch in outgoing:
            ch.finish()          # consumers see producer-done, exit cleanly
        for ch in incoming:
            ch.abandon_consumer()  # producers' offers become counted drops
        if self.tracer is not None:
            self.tracer.instant("recovery", "task.drop", task, instance)
        with self._lock:
            self._state[(task, instance)] = TaskState.DROPPED
            self.dropped.append((task, instance))

    def poison(self, task: str, instance: int, error: BaseException) -> None:
        """Permanent failure: wake every coupled peer with the bad news.

        Consumers blocked in ``get()`` on the dead producer's channels raise
        a chained ``ChannelError`` naming the task; producers blocked in
        ``offer()`` toward the dead consumer are released (their serves
        become counted drops) so the run winds down instead of hanging to
        the join deadline."""
        incoming, outgoing = self._instance_channels(task, instance)
        for ch in outgoing:
            ch.poison(task, instance, error)
        for ch in incoming:
            ch.abandon_consumer()
        with self._lock:
            self._state[(task, instance)] = TaskState.FAILED

    # ------------------------------------------- heartbeats & the watchdog
    def heartbeat(self, task: str, instance: int) -> None:
        """Progress signal, fed from the VOL step hooks, ``comm.step()``,
        checkpoints, and channel wait loops (a consumer parked on an empty
        channel is *starved*, not stalled -- it keeps heartbeating)."""
        with self._hb_lock:
            c, _ = self._hb.get((task, instance), (0, 0.0))
            self._hb[(task, instance)] = (c + 1, time.monotonic())

    def wait_quantum(self, task: str) -> float:
        """Heartbeat cadence for a parked wait loop (channel rendezvous /
        fan-in mux): well inside ``task``'s stall window, so a
        starved-but-alive instance beats often enough that the watchdog
        never mistakes the gap between keep-alives for silence."""
        t = self.stall_timeouts.get(task)
        if t is None:
            return 0.5
        return max(0.02, min(0.5, t / 4.0))

    def scan_stalls(self) -> List[Tuple[str, int, float, float]]:
        """One watchdog pass: (task, instance, silent_s, timeout_s) for every
        instance newly DECLARED stalled.  Hysteresis: an instance must be
        over its timeout on two consecutive scans with no heartbeat movement
        in between -- a slow-but-progressing task resets its strikes on every
        heartbeat and is never killed."""
        out: List[Tuple[str, int, float, float]] = []
        now = time.monotonic()
        with self._lock:
            states = dict(self._state)
            counts = dict(self.task_counts)
            pending = set(self._pending_rescale)
            fenced = set(self._fenced)
        for task, timeout in self.stall_timeouts.items():
            if task in pending:
                continue                      # already being resized
            for i in range(counts.get(task, 1)):
                key = (task, i)
                if states.get(key) != TaskState.RUNNING or key in fenced:
                    self._strikes.pop(key, None)
                    continue
                with self._hb_lock:
                    c, ts = self._hb.get(key, (0, now))
                silent = now - ts
                if silent <= timeout:
                    self._strikes.pop(key, None)
                    continue
                prev_c, strikes = self._strikes.get(key, (c, 0))
                strikes = strikes + 1 if prev_c == c else 1
                self._strikes[key] = (c, strikes)
                if strikes >= 2:
                    self._strikes.pop(key, None)
                    out.append((task, i, silent, timeout))
        return out

    def record_stall(self, ev: StallEvent) -> None:
        if self.tracer is not None:
            self.tracer.instant("recovery", "stall.declared", ev.task,
                                ev.instance, silent_s=ev.silent_s)
        with self._lock:
            self.stalls.append(ev)

    # --------------------------------------------------- elastic rescale
    def generation(self, task: str) -> int:
        with self._lock:
            return self._gen.get(task, 0)

    def is_superseded(self, task: str, gen: int) -> bool:
        """True when a rescale completed after the caller's incarnation was
        launched -- the caller is a zombie and must exit quietly."""
        return self.generation(task) > gen

    def fence(self, task: str, instance: int) -> None:
        with self._lock:
            self._fenced.add((task, instance))

    def is_fenced(self, task: str, instance: int) -> bool:
        with self._lock:
            return (task, instance) in self._fenced

    def pending_rescale(self, task: str) -> Optional[RescaleOp]:
        with self._lock:
            return self._pending_rescale.get(task)

    def request_rescale(self, task: str, nslots: Optional[int] = None,
                        nprocs: Optional[int] = None, trigger: str = "policy",
                        reason: str = "",
                        fence_instance: Optional[int] = None
                        ) -> Tuple[RescaleOp, bool]:
        """Create (or join) the pending ``RescaleOp`` for ``task``.

        Returns ``(op, lead)``; ``lead`` is True when the CALLER must execute
        the surgery immediately (no live instance remains to arrive last --
        e.g. a watchdog resizing a task whose only instance is the fenced
        zombie).  Joining an existing op never leads."""
        with self._lock:
            op = self._pending_rescale.get(task)
            if op is not None:
                return op, False
            if fence_instance is not None:
                self._fenced.add((task, fence_instance))
            M = self.task_counts.get(task, 1)
            old_np = self.task_nprocs.get(task, 1)
            op = RescaleOp(task, M,
                           nslots if nslots is not None else M,
                           old_np,
                           nprocs if nprocs is not None else old_np,
                           trigger, reason)
            op.required = {
                i for i in range(M)
                if self._state.get((task, i), TaskState.PENDING)
                not in (TaskState.DONE, TaskState.DROPPED)
                and (task, i) not in self._fenced}
            self._pending_rescale[task] = op
            lead = False
            if not op.required:
                op.leader_claimed = True
                lead = True
        # outside the lock: pull every old instance out of its callable --
        # its next (or currently blocked) channel operation raises
        # RescaleInterrupt, which the driver converts into op arrival
        for i in range(op.old_nslots):
            incoming, _ = self._instance_channels(task, i)
            for ch in incoming:
                ch.interrupt_consumer(RescaleInterrupt(task, i))
        return op, lead

    def arrive(self, op: RescaleOp, instance: int) -> bool:
        """An old instance stopped touching the old channels.  Returns True
        when this arrival completed the required set: the caller is the
        leader and must execute the surgery (``lead(op)``)."""
        with self._lock:
            if instance not in op.required:
                return False
            op.arrived.add(instance)
            if op.required <= op.arrived and not op.leader_claimed:
                op.leader_claimed = True
                return True
        return False

    def lead(self, op: RescaleOp) -> None:
        """Execute the surgery through the driver-installed callback."""
        if self.on_rescale is None:
            raise RescaleError(
                f"task {op.task!r}: rescale requested but no surgery "
                f"executor is attached (is the run managed?)")
        self.on_rescale(op)

    def rescale(self, task: str, nslots: Optional[int] = None,
                nprocs: Optional[int] = None, reason: str = "") -> RescaleOp:
        """Programmatic trigger (``RunSupervisor.rescale(task, ...)``): resize
        ``task`` without waiting for a crash.  Asynchronous -- live instances
        are interrupted and the last one to arrive performs the surgery;
        ``op.done.wait()`` blocks until it lands."""
        if self.validate_rescale is not None:
            self.validate_rescale(task, nslots=nslots, nprocs=nprocs)
        op, lead = self.request_rescale(task, nslots=nslots, nprocs=nprocs,
                                        trigger="api", reason=reason)
        if lead:
            self.lead(op)
        return op

    def finish_rescale(self, op: RescaleOp, cut_step: int = -1) -> RescaleEvent:
        """Seal a completed surgery: bump the task's generation (fencing every
        pre-rescale incarnation), adopt the new sizes, and record the event."""
        now = time.monotonic()
        with self._lock:
            self._gen[op.task] = self._gen.get(op.task, 0) + 1
            self.task_counts[op.task] = op.new_nslots
            self.task_nprocs[op.task] = op.new_nprocs
            self._pending_rescale.pop(op.task, None)
            self._fenced = {(t, i) for (t, i) in self._fenced
                            if t != op.task}
            for i in range(max(op.old_nslots, op.new_nslots)):
                key = (op.task, i)
                self._attempt[key] = self._attempt.get(key, 0) + 1
                self._epoch[key] = self._epoch.get(key, 0) + 1
                self._state.pop(key, None)
            ev = RescaleEvent(now, op.task, op.old_nslots, op.new_nslots,
                              op.old_nprocs, op.new_nprocs, op.trigger,
                              cut_step, now - op.t0, op.reason)
            self.rescales.append(ev)
        op.cut_step = cut_step
        op.done.set()
        return ev

    def fail_rescale(self, op: RescaleOp, error: BaseException) -> None:
        with self._lock:
            self._pending_rescale.pop(op.task, None)
        op.error = error
        op.done.set()

    def mark_done_or_join(self, task: str, instance: int
                          ) -> Optional[RescaleOp]:
        """DONE-transition that cannot race a pending rescale: if an op for
        this task exists and the instance is required, return the op (the
        caller must ``arrive`` instead of finishing); else mark DONE."""
        with self._lock:
            op = self._pending_rescale.get(task)
            if op is not None and instance in op.required \
                    and instance not in op.arrived:
                return op
            # a watchdog-dropped instance that later wakes and runs to the
            # end stays DROPPED -- its output was already written off
            if self._state.get((task, instance)) != TaskState.DROPPED:
                self._state[(task, instance)] = TaskState.DONE
            return None

    def replace_channels(self, old: Sequence[Any],
                         new: Sequence[Any]) -> None:
        """Swap a rescaled task's retired channels for the new partition's."""
        with self._lock:
            dead = {id(c) for c in old}
            self.channels = [c for c in self.channels if id(c) not in dead]
            self.channels.extend(new)

    # ------------------------------------------------------------ snapshot
    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "restarts": [e.as_dict() for e in self.restarts],
                "dropped": list(self.dropped),
                "rescales": [e.as_dict() for e in self.rescales],
                "stalls": [e.as_dict() for e in self.stalls],
                "states": {f"{t}[{i}]": s
                           for (t, i), s in sorted(self._state.items())},
                "faults_fired": self.faults.fired() if self.faults else 0,
            }
