"""HDF5-style hierarchical data model (the LowFive/HDF5 data-model layer).

The real Wilkins rides on HDF5's data model via the LowFive VOL plugin.  h5py /
libhdf5 are not available in this environment, so we implement the *data model*
itself -- hierarchical groups, typed n-dimensional datasets, attributes, and
hyperslab (partial) selection -- with numpy/JAX arrays as storage.  The VOL
boundary (``repro.core.vol``) intercepts operations on this tree exactly like
LowFive intercepts HDF5 calls, which is the interface the paper actually
defines.

Objects
-------
``Dataset``  -- typed ndarray leaf + attributes + (optional) per-rank block
                ownership map used by the M->N redistribution layer.
``Group``    -- named children (groups or datasets) + attributes.
``File``     -- root group + filename; knows how to spill to / load from disk
                (npz + json container: *our container, HDF5's data model*).

Paths follow HDF5 conventions: ``/group1/particles`` etc.  Glob matching for
ports ("*.h5", "/particles/*") lives here too since it is a data-model level
concern.
"""

from __future__ import annotations

import fnmatch
import io
import json
import os
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

__all__ = [
    "Dataset",
    "Group",
    "File",
    "BlockOwnership",
    "match_path",
    "match_file",
    "split_path",
]


def split_path(path: str) -> List[str]:
    """Split an HDF5 path into components, ignoring leading/duplicate slashes."""
    return [p for p in path.split("/") if p]


def match_path(pattern: str, path: str) -> bool:
    """HDF5-path glob matching. ``/group1/*`` matches ``/group1/grid``.

    A bare ``*`` component matches one level; a trailing ``*`` after a group
    prefix matches any suffix (LowFive-style prefix semantics), so
    ``/particles/*`` matches ``/particles/pos/value`` as well.
    """
    pat = "/" + "/".join(split_path(pattern))
    p = "/" + "/".join(split_path(path))
    if fnmatch.fnmatch(p, pat):
        return True
    # prefix semantics for trailing '*': /a/* also matches deeper paths
    if pat.endswith("/*") and fnmatch.fnmatch(p, pat + "/*"):
        return True
    # a pattern naming a group matches everything below it
    if fnmatch.fnmatch(p, pat.rstrip("/") + "/*"):
        return True
    return False


def match_file(pattern: str, filename: str) -> bool:
    """Filename glob matching: ``plt*.h5`` matches ``plt00010.h5``."""
    return fnmatch.fnmatch(os.path.basename(filename), os.path.basename(pattern))


@dataclass
class BlockOwnership:
    """Which logical producer rank owns which hyperslab of a dataset.

    ``blocks[rank] = (starts, shape)`` -- the rank's block in global index
    space.  This is the metadata LowFive exchanges to plan M->N
    redistribution; we carry it on the Dataset so the redistribution layer can
    compute overlaps without touching the data.
    """

    blocks: Dict[int, Tuple[Tuple[int, ...], Tuple[int, ...]]] = field(
        default_factory=dict
    )

    def add(self, rank: int, starts: Sequence[int], shape: Sequence[int]) -> None:
        self.blocks[rank] = (tuple(starts), tuple(shape))

    def nranks(self) -> int:
        return len(self.blocks)


class Dataset:
    """A typed n-d array leaf with attributes and hyperslab read/write."""

    def __init__(
        self,
        name: str,
        shape: Tuple[int, ...],
        dtype: Any,
        data: Optional[np.ndarray] = None,
        parent: Optional["Group"] = None,
    ):
        self.name = name
        self.shape = tuple(int(s) for s in shape)
        self.dtype = np.dtype(dtype)
        self.attrs: Dict[str, Any] = {}
        self.parent = parent
        self.ownership: Optional[BlockOwnership] = None
        if data is not None:
            data = np.asarray(data)
            assert data.shape == self.shape, (data.shape, self.shape)
            self._data = np.ascontiguousarray(data, dtype=self.dtype)
        else:
            self._data = np.zeros(self.shape, dtype=self.dtype)

    # -- HDF5-ish surface ---------------------------------------------------
    @property
    def path(self) -> str:
        if self.parent is None:
            return "/" + self.name
        return self.parent.path.rstrip("/") + "/" + self.name

    def __getitem__(self, key) -> np.ndarray:
        return self._data[key]

    def __setitem__(self, key, value) -> None:
        self._data[key] = value

    def read_direct(self) -> np.ndarray:
        return self._data

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * self.dtype.itemsize if self.shape else self.dtype.itemsize

    def select(self, starts: Sequence[int], shape: Sequence[int]) -> np.ndarray:
        """Hyperslab read (contiguous block selection)."""
        slc = tuple(slice(s, s + n) for s, n in zip(starts, shape))
        return self._data[slc]

    def write_slab(self, starts: Sequence[int], block: np.ndarray) -> None:
        slc = tuple(slice(s, s + n) for s, n in zip(starts, block.shape))
        self._data[slc] = block

    def __repr__(self) -> str:
        return f"<Dataset {self.path} shape={self.shape} dtype={self.dtype}>"


class Group:
    """Named collection of sub-groups and datasets."""

    def __init__(self, name: str, parent: Optional["Group"] = None):
        self.name = name
        self.parent = parent
        self.children: Dict[str, Union["Group", Dataset]] = {}
        self.attrs: Dict[str, Any] = {}

    @property
    def path(self) -> str:
        if self.parent is None:
            return "/"
        base = self.parent.path
        return (base if base.endswith("/") else base + "/") + self.name

    def require_group(self, path: str) -> "Group":
        node: Group = self
        for comp in split_path(path):
            child = node.children.get(comp)
            if child is None:
                child = Group(comp, parent=node)
                node.children[comp] = child
            elif not isinstance(child, Group):
                raise TypeError(f"{child.path} is a dataset, not a group")
            node = child
        return node

    def create_dataset(
        self,
        path: str,
        shape: Optional[Tuple[int, ...]] = None,
        dtype: Any = None,
        data: Optional[np.ndarray] = None,
    ) -> Dataset:
        comps = split_path(path)
        if not comps:
            raise ValueError("empty dataset path")
        parent = self.require_group("/".join(comps[:-1])) if len(comps) > 1 else self
        if data is not None:
            data = np.asarray(data)
            shape = data.shape if shape is None else tuple(shape)
            dtype = data.dtype if dtype is None else dtype
        if shape is None or dtype is None:
            raise ValueError("need shape+dtype or data")
        ds = Dataset(comps[-1], tuple(shape), dtype, data=data, parent=parent)
        parent.children[comps[-1]] = ds
        return ds

    def get(self, path: str) -> Optional[Union["Group", Dataset]]:
        node: Union[Group, Dataset] = self
        for comp in split_path(path):
            if not isinstance(node, Group):
                return None
            nxt = node.children.get(comp)
            if nxt is None:
                return None
            node = nxt
        return node

    def __getitem__(self, path: str) -> Union["Group", Dataset]:
        node = self.get(path)
        if node is None:
            raise KeyError(f"no object at {path!r} under {self.path!r}")
        return node

    def __contains__(self, path: str) -> bool:
        return self.get(path) is not None

    def visit_datasets(self) -> Iterator[Dataset]:
        for child in self.children.values():
            if isinstance(child, Dataset):
                yield child
            else:
                yield from child.visit_datasets()

    def __repr__(self) -> str:
        return f"<Group {self.path} ({len(self.children)} children)>"


class File(Group):
    """Root of the tree; also the unit of transport in Wilkins.

    LowFive serves data producer->consumer at file-close granularity; the
    channel layer ships ``File`` objects (or their metadata + selected
    datasets).  ``save``/``load`` implement the *file* transport option
    (``file: 1`` in YAML) -- data spilled through the filesystem in an
    npz+json container (h5py unavailable; data model preserved).
    """

    def __init__(self, filename: str):
        super().__init__("")
        self.filename = filename
        self.closed = False

    @property
    def path(self) -> str:
        return "/"

    # -- disk container (the ``file: 1`` transport path) ---------------------
    def save(self, directory: str) -> str:
        os.makedirs(directory, exist_ok=True)
        target = os.path.join(directory, os.path.basename(self.filename))
        arrays: Dict[str, np.ndarray] = {}
        meta: Dict[str, Any] = {"filename": self.filename, "datasets": {}, "attrs": {}}

        def walk(g: Group, prefix: str) -> None:
            for nm, child in g.children.items():
                p = prefix + "/" + nm
                if isinstance(child, Dataset):
                    key = f"d{len(arrays)}"
                    arrays[key] = child.read_direct()
                    meta["datasets"][p] = {
                        "key": key,
                        "attrs": _jsonable(child.attrs),
                        "ownership": (
                            {str(r): [list(s), list(sh)] for r, (s, sh) in child.ownership.blocks.items()}
                            if child.ownership
                            else None
                        ),
                    }
                else:
                    walk(child, p)

        walk(self, "")
        buf = io.BytesIO()
        np.savez(buf, **arrays)
        tmp = target + ".tmp"
        with open(tmp, "wb") as f:
            header = json.dumps(meta).encode()
            f.write(len(header).to_bytes(8, "little"))
            f.write(header)
            f.write(buf.getvalue())
        os.replace(tmp, target)  # atomic
        return target

    @classmethod
    def load(cls, path: str) -> "File":
        with open(path, "rb") as f:
            hlen = int.from_bytes(f.read(8), "little")
            meta = json.loads(f.read(hlen).decode())
            npz = np.load(io.BytesIO(f.read()))
        out = cls(meta["filename"])
        for dpath, info in meta["datasets"].items():
            ds = out.create_dataset(dpath, data=npz[info["key"]])
            ds.attrs.update(info.get("attrs") or {})
            own = info.get("ownership")
            if own:
                bo = BlockOwnership()
                for r, (s, sh) in own.items():
                    bo.add(int(r), s, sh)
                ds.ownership = bo
        return out

    def total_bytes(self) -> int:
        return sum(d.nbytes for d in self.visit_datasets())

    def copy_meta_only(self) -> "File":
        """Shallow structural copy (metadata broadcast path, cf. Listing 5)."""
        out = File(self.filename)

        def walk(src: Group, dst: Group) -> None:
            dst.attrs.update(src.attrs)
            for nm, child in src.children.items():
                if isinstance(child, Dataset):
                    nd = dst.create_dataset(nm, shape=child.shape, dtype=child.dtype)
                    nd.attrs.update(child.attrs)
                    nd.ownership = child.ownership
                else:
                    walk(child, dst.require_group(nm))

        walk(self, out)
        return out


def _jsonable(d: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in d.items():
        if isinstance(v, (np.integer,)):
            v = int(v)
        elif isinstance(v, (np.floating,)):
            v = float(v)
        elif isinstance(v, np.ndarray):
            v = v.tolist()
        out[k] = v
    return out
