"""HDF5-style hierarchical data model (the LowFive/HDF5 data-model layer).

The real Wilkins rides on HDF5's data model via the LowFive VOL plugin.  h5py /
libhdf5 are not available in this environment, so we implement the *data model*
itself -- hierarchical groups, typed n-dimensional datasets, attributes, and
hyperslab (partial) selection -- with numpy/JAX arrays as storage.  The VOL
boundary (``repro.core.vol``) intercepts operations on this tree exactly like
LowFive intercepts HDF5 calls, which is the interface the paper actually
defines.

Objects
-------
``Dataset``  -- typed ndarray leaf + attributes + (optional) per-rank block
                ownership map used by the M->N redistribution layer.  Supports
                copy-on-write views (``Dataset.view()``): the underlying
                ndarray is shared read-only across any number of views and the
                copy is deferred to the first write, so fan-out transport ships
                metadata, not data.
``Group``    -- named children (groups or datasets) + attributes.
``File``     -- root group + filename; knows how to spill to / load from disk
                (raw binary container: json header + 64-byte-aligned raw array
                segments, loaded zero-copy via ``np.memmap``).

Paths follow HDF5 conventions: ``/group1/particles`` etc.  Glob matching for
ports ("*.h5", "/particles/*") lives here too since it is a data-model level
concern; patterns are compiled once to regexes and LRU-cached (see
``compile_path_pattern`` / ``compile_file_pattern``).

Ownership rules (see DESIGN.md):

* ``Dataset`` mutation goes through ``__setitem__`` / ``write_slab``; both
  materialize a private copy first if the buffer is shared or read-only
  (memmap).  Copies are counted in ``transport_stats()``.
* ``read_direct`` / ``__getitem__`` return a read-only alias while the buffer
  is shared, so a reader cannot silently corrupt a sibling view.
"""

from __future__ import annotations

import fnmatch
import io
import json
import os
import re
import sys
import threading
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..analysis import lockcheck as _lc
from ..analysis.lockcheck import make_lock, sched_point

__all__ = [
    "Dataset",
    "Group",
    "File",
    "BlockOwnership",
    "TransportStats",
    "is_device_array",
    "transport_stats",
    "reset_transport_stats",
    "match_path",
    "match_file",
    "compile_path_pattern",
    "compile_file_pattern",
    "split_path",
]

_SPILL_MAGIC = b"WLKNRAW1"
_SPILL_ALIGN = 64


def is_device_array(a: Any) -> bool:
    """True for a JAX device array (device-resident Dataset buffers).

    Checked via ``sys.modules`` so importing the data model never drags jax
    in: if jax was never imported, no caller can have produced a jax array.
    Device buffers are immutable by construction, so the CoW layer treats
    them as permanently shared -- reads alias them directly and any write
    first materializes a private numpy copy.
    """
    if isinstance(a, np.ndarray):
        return False
    jax = sys.modules.get("jax")
    return jax is not None and isinstance(a, jax.Array)


def _writable_in_place(a: Any) -> bool:
    """Can this buffer be mutated where it sits?  Never true for device
    arrays (immutable) -- only for writable host ndarrays."""
    return isinstance(a, np.ndarray) and a.flags.writeable


# ---------------------------------------------------------------------------
# transport instrumentation
# ---------------------------------------------------------------------------
class TransportStats:
    """Process-wide counters for data-movement work in the transport path.

    ``bytes_copied`` counts actual buffer materializations (eager copies in
    the legacy path, deferred CoW copies in the fast path); ``views`` counts
    zero-copy dataset views handed out.  Benchmarks reset + read these to
    measure the Fig. 4 overhead lever.
    """

    def __init__(self) -> None:
        self._lock = make_lock("leaf:transport_stats")
        self.copies = 0
        self.bytes_copied = 0
        self.cow_copies = 0
        self.views = 0
        # M->N redistribution accounting (planned vs shipped vs whole-file):
        # per served dataset on a redistributing port, ``planned`` is what the
        # compiled plan says must land on this consumer, ``shipped`` the
        # payload bytes the channel actually enqueued (the slab -- or the
        # whole dataset on the aligned view path, which copies nothing but
        # whose bytes a real wire would still carry), ``baseline`` the
        # whole-dataset bytes the pre-plan transport moved.
        self.redist_planned_bytes = 0
        self.redist_shipped_bytes = 0
        self.redist_baseline_bytes = 0
        self.redist_aligned = 0
        self.redist_slabs = 0
        # Async slab prefetch (channels with a RedistSpec serve payload
        # futures): a *hit* is a payload whose preparation finished before
        # the consumer asked for it -- the slab serve was fully hidden behind
        # consumer compute; a *miss* blocked the consumer for
        # ``prefetch_blocked_s`` of the total ``prefetch_prepared_s``.
        self.prefetch_hits = 0
        self.prefetch_misses = 0
        self.prefetch_prepared_s = 0.0
        self.prefetch_blocked_s = 0.0
        # preps cancelled before delivery: pool shutdown mid-run, or a
        # `latest` edge dropping a stale in-flight prep when a newer step
        # superseded it (the autotuner reads this as "depth too deep")
        self.prefetch_cancelled = 0
        # TaskComm.reshard executor dispatch: how many calls ran on the
        # Pallas pack kernels vs the numpy scatter executors (the benchmark
        # and tests assert "no numpy fallback" through these)
        self.reshard_pack = 0
        self.reshard_numpy = 0

    def record_copy(self, nbytes: int, cow: bool = False) -> None:
        with self._lock:
            self.copies += 1
            self.bytes_copied += int(nbytes)
            if cow:
                self.cow_copies += 1

    def record_view(self) -> None:
        with self._lock:
            self.views += 1

    def record_prefetch_prepare(self, elapsed_s: float) -> None:
        with self._lock:
            self.prefetch_prepared_s += float(elapsed_s)

    def record_reshard(self, pack: bool) -> None:
        with self._lock:
            if pack:
                self.reshard_pack += 1
            else:
                self.reshard_numpy += 1

    def record_prefetch_cancelled(self) -> None:
        with self._lock:
            self.prefetch_cancelled += 1

    def record_prefetch(self, hit: bool, blocked_s: float = 0.0) -> None:
        with self._lock:
            if hit:
                self.prefetch_hits += 1
            else:
                self.prefetch_misses += 1
                self.prefetch_blocked_s += float(blocked_s)

    def record_redistribution(self, planned: int, shipped: int, baseline: int,
                              aligned: bool) -> None:
        with self._lock:
            self.redist_planned_bytes += int(planned)
            self.redist_shipped_bytes += int(shipped)
            self.redist_baseline_bytes += int(baseline)
            if aligned:
                self.redist_aligned += 1
            else:
                self.redist_slabs += 1

    def snapshot(self) -> Dict[str, int]:
        with self._lock:
            return {
                "copies": self.copies,
                "bytes_copied": self.bytes_copied,
                "cow_copies": self.cow_copies,
                "views": self.views,
                "redist_planned_bytes": self.redist_planned_bytes,
                "redist_shipped_bytes": self.redist_shipped_bytes,
                "redist_baseline_bytes": self.redist_baseline_bytes,
                "redist_aligned": self.redist_aligned,
                "redist_slabs": self.redist_slabs,
                "prefetch_hits": self.prefetch_hits,
                "prefetch_misses": self.prefetch_misses,
                "prefetch_prepared_s": self.prefetch_prepared_s,
                "prefetch_blocked_s": self.prefetch_blocked_s,
                "prefetch_cancelled": self.prefetch_cancelled,
                "reshard_pack": self.reshard_pack,
                "reshard_numpy": self.reshard_numpy,
            }

    def reset(self) -> None:
        with self._lock:
            self.copies = self.bytes_copied = self.cow_copies = self.views = 0
            self.redist_planned_bytes = self.redist_shipped_bytes = 0
            self.redist_baseline_bytes = 0
            self.redist_aligned = self.redist_slabs = 0
            self.prefetch_hits = self.prefetch_misses = 0
            self.prefetch_cancelled = 0
            self.prefetch_prepared_s = self.prefetch_blocked_s = 0.0
            self.reshard_pack = self.reshard_numpy = 0


_TRANSPORT_STATS = TransportStats()


def transport_stats() -> TransportStats:
    return _TRANSPORT_STATS


def reset_transport_stats() -> None:
    _TRANSPORT_STATS.reset()


# ---------------------------------------------------------------------------
# glob matching (LRU-cached compiled regexes)
# ---------------------------------------------------------------------------
def split_path(path: str) -> List[str]:
    """Split an HDF5 path into components, ignoring leading/duplicate slashes."""
    return [p for p in path.split("/") if p]


@lru_cache(maxsize=4096)
def _compile_fnmatch(pattern: str) -> "re.Pattern[str]":
    return re.compile(fnmatch.translate(pattern))


class PathMatcher:
    """A compiled HDF5-path glob (LowFive prefix semantics, see match_path)."""

    __slots__ = ("pattern", "_regexes")

    def __init__(self, pattern: str):
        self.pattern = pattern
        pat = "/" + "/".join(split_path(pattern))
        regexes = [_compile_fnmatch(pat)]
        if pat.endswith("/*"):
            # prefix semantics for trailing '*': /a/* also matches deeper paths
            regexes.append(_compile_fnmatch(pat + "/*"))
        # a pattern naming a group matches everything below it
        regexes.append(_compile_fnmatch(pat.rstrip("/") + "/*"))
        self._regexes = tuple(regexes)

    def matches(self, path: str) -> bool:
        p = "/" + "/".join(split_path(path))
        return any(r.match(p) is not None for r in self._regexes)


class FileMatcher:
    """A compiled filename glob (basename semantics)."""

    __slots__ = ("pattern", "_regex")

    def __init__(self, pattern: str):
        self.pattern = pattern
        self._regex = _compile_fnmatch(os.path.basename(pattern))

    def matches(self, filename: str) -> bool:
        return self._regex.match(os.path.basename(filename)) is not None


@lru_cache(maxsize=4096)
def compile_path_pattern(pattern: str) -> PathMatcher:
    return PathMatcher(pattern)


@lru_cache(maxsize=4096)
def compile_file_pattern(pattern: str) -> FileMatcher:
    return FileMatcher(pattern)


def match_path(pattern: str, path: str) -> bool:
    """HDF5-path glob matching. ``/group1/*`` matches ``/group1/grid``.

    A bare ``*`` component matches one level; a trailing ``*`` after a group
    prefix matches any suffix (LowFive-style prefix semantics), so
    ``/particles/*`` matches ``/particles/pos/value`` as well.
    """
    return compile_path_pattern(pattern).matches(path)


def match_file(pattern: str, filename: str) -> bool:
    """Filename glob matching: ``plt*.h5`` matches ``plt00010.h5``."""
    return compile_file_pattern(pattern).matches(filename)


@dataclass
class BlockOwnership:
    """Which logical producer rank owns which hyperslab of a dataset.

    ``blocks[rank] = (starts, shape)`` -- the rank's block in global index
    space.  This is the metadata LowFive exchanges to plan M->N
    redistribution; we carry it on the Dataset so the redistribution layer can
    compute overlaps without touching the data.
    """

    blocks: Dict[int, Tuple[Tuple[int, ...], Tuple[int, ...]]] = field(
        default_factory=dict
    )

    def add(self, rank: int, starts: Sequence[int], shape: Sequence[int]) -> None:
        self.blocks[rank] = (tuple(starts), tuple(shape))

    def nranks(self) -> int:
        return len(self.blocks)


def _buffer_key(arr: Any) -> int:
    """Stable identity of the underlying memory for the race detector:
    views of the same allocation map to the same key (walk the ``.base``
    chain, take the data pointer), so a slab view and its source dataset
    are recognized as touching one buffer."""
    base = arr
    while isinstance(base, np.ndarray) and base.base is not None:
        base = base.base
    try:
        return base.__array_interface__["data"][0]
    except (AttributeError, TypeError, KeyError):
        return id(base)


def _race_point(tag: str, arr: Any, mode: str) -> None:
    """Shadow-state access for the explorer's happens-before checker.

    Gated on the raw controller global so the disabled path never walks
    the buffer's base chain -- one module-attribute load and a None test."""
    if _lc._EXPLORE_CONTROLLER is not None:
        sched_point(tag, key=("buf", _buffer_key(arr)), access=mode)


class _Share:
    """Refcount for an ndarray buffer shared across CoW dataset views.

    Every ``count`` mutation happens under ``lock``, and the (share, buffer)
    pair on a Dataset is only ever read or swapped while holding the lock of
    the share being replaced -- see ``Dataset._acquire_share`` /
    ``Dataset._ensure_writable``.  Without that pairing a ``view()`` racing a
    CoW materialization can increment a share the writer is detaching and
    then alias the writer's fresh private buffer (torn capture)."""

    __slots__ = ("count", "lock")

    def __init__(self, count: int = 1):
        self.count = count
        self.lock = make_lock("leaf:share")


class Dataset:
    """A typed n-d array leaf with attributes and hyperslab read/write.

    Buffers are copy-on-write: ``view()`` shares the ndarray (refcounted via
    ``_Share``); the first write through any sharer materializes a private
    copy.  Datasets loaded from the spill container are ``np.memmap`` backed
    and obey the same rule (read-only until first write copies).
    """

    def __init__(
        self,
        name: str,
        shape: Tuple[int, ...],
        dtype: Any,
        data: Optional[np.ndarray] = None,
        parent: Optional["Group"] = None,
        copy: bool = True,
    ):
        self.name = name
        self.shape = tuple(int(s) for s in shape)
        self.dtype = np.dtype(dtype)
        self.attrs: Dict[str, Any] = {}
        self.parent = parent
        self.ownership: Optional[BlockOwnership] = None
        self._share = _Share(1)
        if data is not None:
            # keep subclasses (np.memmap) and device arrays intact on the
            # zero-copy path; everything else coerces to ndarray
            if isinstance(data, np.ndarray) or is_device_array(data):
                arr = data
            else:
                arr = np.asarray(data)
            assert tuple(arr.shape) == self.shape, (arr.shape, self.shape)
            if copy:
                # Snapshot the caller's array into the file (h5py semantics).
                # Adopting a caller-owned buffer would hand the CoW layer an
                # alias the caller can mutate behind its back -- one copy at
                # creation buys a sound invariant: every Dataset buffer is
                # reachable only through Datasets.
                out = np.array(arr, dtype=self.dtype, order="C")
                _TRANSPORT_STATS.record_copy(out.nbytes)
                self._data = out
            else:
                # Internal zero-copy path (spill load, legacy filter): the
                # caller guarantees nothing else writes this buffer.  A
                # read-only buffer (e.g. an np.memmap opened mode="r") stays
                # shared until the first write triggers the CoW copy.
                assert arr.dtype == self.dtype, (arr.dtype, self.dtype)
                self._data = arr
        else:
            self._data = np.zeros(self.shape, dtype=self.dtype)

    # -- copy-on-write ------------------------------------------------------
    def _acquire_share(self) -> Tuple[_Share, np.ndarray]:
        """Atomically (share.count += 1, snapshot (share, data)).

        A concurrent ``_ensure_writable`` may swap ``self._share`` /
        ``self._data`` between our read of the share and taking its lock; the
        identity re-check restarts so the increment always lands on the share
        that actually guards the buffer we alias."""
        while True:
            share = self._share
            # the torn-capture window (PR 3): a writer may swap the share
            # between this read and the lock below -- the identity re-check
            # restarts; the yield point lets the explorer preempt HERE
            sched_point("Dataset._acquire_share", key=("share", id(share)))
            with share.lock:
                if share is self._share:
                    share.count += 1
                    return share, self._data

    def view(self, parent: Optional["Group"] = None) -> "Dataset":
        """Zero-copy view sharing this dataset's buffer (copy deferred to
        first write, on either side).  Attributes are shallow-copied so a
        view can annotate without touching the source."""
        ds = Dataset.__new__(Dataset)
        ds.name = self.name
        ds.shape = self.shape
        ds.dtype = self.dtype
        ds.attrs = dict(self.attrs)
        ds.parent = parent
        ds.ownership = self.ownership
        ds._share, ds._data = self._acquire_share()
        _TRANSPORT_STATS.record_view()
        return ds

    def slab_view(self, starts: Sequence[int], shape: Sequence[int],
                  parent: Optional["Group"] = None) -> "Dataset":
        """Zero-copy hyperslab view: a Dataset over ``self._data[starts:+shape]``.

        Shares this dataset's ``_Share`` (like ``view``), so the CoW rules
        hold: the slab is read-only while shared and a first write through
        either side copies only that side's bytes (the slab copies its slab,
        not the whole buffer).  This is what a redistributing channel ships --
        the consumer's owned box, zero bytes moved at serve time.
        """
        slc = tuple(slice(s, s + n) for s, n in zip(starts, shape))
        ds = Dataset.__new__(Dataset)
        ds.name = self.name
        ds.shape = tuple(int(n) for n in shape)
        ds.dtype = self.dtype
        ds.attrs = dict(self.attrs)
        ds.parent = parent
        ds.ownership = None
        ds._share, base = self._acquire_share()
        ds._data = base[slc]
        _TRANSPORT_STATS.record_view()
        return ds

    @property
    def share_count(self) -> int:
        share = self._share
        with share.lock:
            return share.count

    def _is_exclusive(self) -> bool:
        share = self._share
        with share.lock:
            return share is self._share and share.count == 1 \
                and _writable_in_place(self._data)

    def _ensure_writable(self) -> None:
        """Materialize a private copy if the buffer is shared or read-only
        (memmap, device array -- device buffers are immutable, so a write
        always lands in a private host copy)."""
        while True:
            share = self._share
            sched_point("Dataset._ensure_writable", key=("share", id(share)))
            with share.lock:
                if share is not self._share:
                    continue  # a concurrent writer swapped us; re-read
                if share.count == 1 and _writable_in_place(self._data):
                    return
                # Copy AND swap while holding the share lock: a sibling
                # sharer must not pass its own count==1 fast path and write
                # the buffer in place before this snapshot is complete
                # (torn-copy race), and a concurrent ``view()`` must never
                # observe the new private buffer paired with the old share
                # (torn-capture race -- see _acquire_share).
                new = np.array(self._data)
                share.count -= 1
                self._data = new
                self._share = _Share(1)
                break
        _TRANSPORT_STATS.record_copy(new.nbytes, cow=True)

    # -- HDF5-ish surface ---------------------------------------------------
    @property
    def path(self) -> str:
        if self.parent is None:
            return "/" + self.name
        return self.parent.path.rstrip("/") + "/" + self.name

    def __getitem__(self, key) -> np.ndarray:
        return self.read_direct()[key]

    def __setitem__(self, key, value) -> None:
        self._ensure_writable()
        _race_point("Dataset.__setitem__", self._data, "w")
        self._data[key] = value

    def read_direct(self) -> np.ndarray:
        """The backing array; a read-only alias while the buffer is shared.

        Device-resident buffers (jax arrays) are immutable by construction
        and are returned as-is -- callers see a ``jax.Array`` and may hand it
        straight to the pack-kernel executors without a host round-trip.
        """
        if is_device_array(self._data):
            return self._data
        _race_point("Dataset.read_direct", self._data, "r")
        if self._is_exclusive():
            return self._data
        alias = self._data.view()
        alias.flags.writeable = False
        return alias

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * self.dtype.itemsize if self.shape else self.dtype.itemsize

    def select(self, starts: Sequence[int], shape: Sequence[int]) -> np.ndarray:
        """Hyperslab read (contiguous block selection)."""
        slc = tuple(slice(s, s + n) for s, n in zip(starts, shape))
        return self.read_direct()[slc]

    def write_slab(self, starts: Sequence[int], block: np.ndarray) -> None:
        self._ensure_writable()
        _race_point("Dataset.write_slab", self._data, "w")
        slc = tuple(slice(s, s + n) for s, n in zip(starts, block.shape))
        self._data[slc] = block

    def __repr__(self) -> str:
        return f"<Dataset {self.path} shape={self.shape} dtype={self.dtype}>"


class Group:
    """Named collection of sub-groups and datasets."""

    def __init__(self, name: str, parent: Optional["Group"] = None):
        self.name = name
        self.parent = parent
        self.children: Dict[str, Union["Group", Dataset]] = {}
        self.attrs: Dict[str, Any] = {}

    @property
    def path(self) -> str:
        if self.parent is None:
            return "/"
        base = self.parent.path
        return (base if base.endswith("/") else base + "/") + self.name

    def require_group(self, path: str) -> "Group":
        node: Group = self
        for comp in split_path(path):
            child = node.children.get(comp)
            if child is None:
                child = Group(comp, parent=node)
                node.children[comp] = child
            elif not isinstance(child, Group):
                raise TypeError(f"{child.path} is a dataset, not a group")
            node = child
        return node

    def create_dataset(
        self,
        path: str,
        shape: Optional[Tuple[int, ...]] = None,
        dtype: Any = None,
        data: Optional[np.ndarray] = None,
        copy: bool = True,
    ) -> Dataset:
        comps = split_path(path)
        if not comps:
            raise ValueError("empty dataset path")
        parent = self.require_group("/".join(comps[:-1])) if len(comps) > 1 else self
        if data is not None:
            if not isinstance(data, np.ndarray) and not is_device_array(data):
                data = np.asarray(data)
            shape = tuple(data.shape) if shape is None else tuple(shape)
            dtype = data.dtype if dtype is None else dtype
        if shape is None or dtype is None:
            raise ValueError("need shape+dtype or data")
        ds = Dataset(comps[-1], tuple(shape), dtype, data=data, parent=parent, copy=copy)
        parent.children[comps[-1]] = ds
        return ds

    def attach_view(self, ds: Dataset) -> Dataset:
        """Graft a zero-copy view of ``ds`` at the same path under this root."""
        comps = split_path(ds.path)
        parent = self.require_group("/".join(comps[:-1])) if len(comps) > 1 else self
        v = ds.view(parent=parent)
        parent.children[comps[-1]] = v
        return v

    def attach_slab(self, ds: Dataset, starts: Sequence[int],
                    shape: Sequence[int]) -> Dataset:
        """Graft a zero-copy hyperslab view of ``ds`` at the same path."""
        comps = split_path(ds.path)
        parent = self.require_group("/".join(comps[:-1])) if len(comps) > 1 else self
        v = ds.slab_view(starts, shape, parent=parent)
        parent.children[comps[-1]] = v
        return v

    def get(self, path: str) -> Optional[Union["Group", Dataset]]:
        node: Union[Group, Dataset] = self
        for comp in split_path(path):
            if not isinstance(node, Group):
                return None
            nxt = node.children.get(comp)
            if nxt is None:
                return None
            node = nxt
        return node

    def __getitem__(self, path: str) -> Union["Group", Dataset]:
        node = self.get(path)
        if node is None:
            raise KeyError(f"no object at {path!r} under {self.path!r}")
        return node

    def __contains__(self, path: str) -> bool:
        return self.get(path) is not None

    def visit_datasets(self) -> Iterator[Dataset]:
        for child in self.children.values():
            if isinstance(child, Dataset):
                yield child
            else:
                yield from child.visit_datasets()

    def __repr__(self) -> str:
        return f"<Group {self.path} ({len(self.children)} children)>"


def _align_up(n: int, align: int = _SPILL_ALIGN) -> int:
    return (n + align - 1) // align * align


class File(Group):
    """Root of the tree; also the unit of transport in Wilkins.

    LowFive serves data producer->consumer at file-close granularity; the
    channel layer ships ``File`` objects (or their metadata + selected
    datasets).  ``save``/``load`` implement the *file* transport option
    (``file: 1`` in YAML) -- data spilled through the filesystem in a raw
    binary container: an 8-byte magic, a json header, then each dataset's
    bytes at a 64-byte-aligned offset.  ``load`` maps the segments with
    ``np.memmap`` so reading a spill does zero redundant copies; the CoW rule
    on ``Dataset`` materializes a private buffer only on first write.
    """

    def __init__(self, filename: str):
        super().__init__("")
        self.filename = filename
        self.closed = False

    @property
    def path(self) -> str:
        return "/"

    # -- zero-copy structural view ------------------------------------------
    def view(self) -> "File":
        """Structural clone whose datasets are CoW views of this file's.

        This is what fan-out ships: N consumers get N cheap trees over ONE
        payload; the refcount on each dataset's ``_Share`` tracks the sharing.
        """
        out = File(self.filename)
        out.attrs.update(self.attrs)

        def walk(src: Group, dst: Group) -> None:
            for nm, child in src.children.items():
                if isinstance(child, Dataset):
                    dst.children[nm] = child.view(parent=dst)
                else:
                    g = dst.require_group(nm)
                    g.attrs.update(child.attrs)
                    walk(child, g)

        walk(self, out)
        return out

    # -- disk container (the ``file: 1`` transport path) ---------------------
    def save(self, directory: str, basename: Optional[str] = None) -> str:
        os.makedirs(directory, exist_ok=True)
        target = os.path.join(directory, basename or os.path.basename(self.filename))
        entries: List[Tuple[str, Dataset]] = []

        def walk(g: Group, prefix: str) -> None:
            for nm, child in g.children.items():
                p = prefix + "/" + nm
                if isinstance(child, Dataset):
                    entries.append((p, child))
                else:
                    walk(child, p)

        walk(self, "")
        meta: Dict[str, Any] = {
            "filename": self.filename,
            "attrs": _jsonable(self.attrs),
            "datasets": {},
        }
        rel = 0
        for p, ds in entries:
            rel = _align_up(rel)
            meta["datasets"][p] = {
                "dtype": ds.dtype.str,
                "shape": list(ds.shape),
                "offset": rel,
                "nbytes": ds.nbytes,
                "attrs": _jsonable(ds.attrs),
                "ownership": (
                    {str(r): [list(s), list(sh)] for r, (s, sh) in ds.ownership.blocks.items()}
                    if ds.ownership
                    else None
                ),
            }
            rel += ds.nbytes
        header = json.dumps(meta).encode()
        data_start = _align_up(len(_SPILL_MAGIC) + 8 + len(header))

        tmp = target + ".tmp"
        with open(tmp, "wb") as f:
            f.write(_SPILL_MAGIC)
            f.write(len(header).to_bytes(8, "little"))
            f.write(header)
            f.write(b"\0" * (data_start - f.tell()))
            for p, ds in entries:
                if ds.nbytes == 0:
                    continue  # memoryview can't cast zero-size shapes
                off = data_start + meta["datasets"][p]["offset"]
                f.write(b"\0" * (off - f.tell()))
                arr = ds.read_direct()
                if is_device_array(arr):
                    arr = np.asarray(arr)  # spill needs host bytes
                if not arr.flags.c_contiguous:
                    arr = np.ascontiguousarray(arr)
                f.write(memoryview(arr).cast("B"))
        os.replace(tmp, target)  # atomic
        return target

    @classmethod
    def load(cls, path: str, mmap: bool = True) -> "File":
        with open(path, "rb") as f:
            magic = f.read(len(_SPILL_MAGIC))
            if magic != _SPILL_MAGIC:
                f.seek(0)
                return cls._load_legacy(f)
            hlen = int.from_bytes(f.read(8), "little")
            meta = json.loads(f.read(hlen).decode())
            data_start = _align_up(len(_SPILL_MAGIC) + 8 + hlen)
            out = cls(meta["filename"])
            out.attrs.update(meta.get("attrs") or {})
            for dpath, info in meta["datasets"].items():
                dt = np.dtype(info["dtype"])
                shape = tuple(info["shape"])
                nbytes = int(info["nbytes"])
                off = data_start + int(info["offset"])
                if nbytes == 0:
                    arr = np.zeros(shape, dtype=dt)
                elif mmap:
                    mm = np.memmap(path, dtype=dt, mode="r", offset=off,
                                   shape=shape if shape else (1,))
                    arr = mm if shape else mm.reshape(())
                else:
                    f.seek(off)
                    buf = f.read(nbytes)
                    _TRANSPORT_STATS.record_copy(nbytes)
                    arr = np.frombuffer(bytearray(buf), dtype=dt).reshape(shape)
                ds = out.create_dataset(dpath, data=arr, copy=False)
                ds.attrs.update(info.get("attrs") or {})
                own = info.get("ownership")
                if own:
                    bo = BlockOwnership()
                    for r, (s, sh) in own.items():
                        bo.add(int(r), s, sh)
                    ds.ownership = bo
            return out

    @classmethod
    def _load_legacy(cls, f) -> "File":
        # pre-raw-container format: u64 header length + json + npz blob
        hlen = int.from_bytes(f.read(8), "little")
        meta = json.loads(f.read(hlen).decode())
        npz = np.load(io.BytesIO(f.read()))
        out = cls(meta["filename"])
        for dpath, info in meta["datasets"].items():
            ds = out.create_dataset(dpath, data=npz[info["key"]], copy=False)
            ds.attrs.update(info.get("attrs") or {})
            own = info.get("ownership")
            if own:
                bo = BlockOwnership()
                for r, (s, sh) in own.items():
                    bo.add(int(r), s, sh)
                ds.ownership = bo
        return out

    def total_bytes(self) -> int:
        return sum(d.nbytes for d in self.visit_datasets())

    def copy_meta_only(self) -> "File":
        """Shallow structural copy (metadata broadcast path, cf. Listing 5)."""
        out = File(self.filename)

        def walk(src: Group, dst: Group) -> None:
            dst.attrs.update(src.attrs)
            for nm, child in src.children.items():
                if isinstance(child, Dataset):
                    nd = dst.create_dataset(nm, shape=child.shape, dtype=child.dtype)
                    nd.attrs.update(child.attrs)
                    nd.ownership = child.ownership
                else:
                    walk(child, dst.require_group(nm))

        walk(self, out)
        return out


def _jsonable(d: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in d.items():
        if isinstance(v, (np.integer,)):
            v = int(v)
        elif isinstance(v, (np.floating,)):
            v = float(v)
        elif isinstance(v, np.ndarray):
            v = v.tolist()
        out[k] = v
    return out
