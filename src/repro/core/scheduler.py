"""Adaptive flow-control scheduling: weighted-fair prefetch arbitration,
prefetch-depth autotuning, and a telemetry timeline.

Wilkins' headline claim is that tasks with *disparate data rates* couple
without code changes because the transport absorbs the rate mismatch.  The
static knobs (``io_freq`` -> all/some/latest, per-edge ``prefetch: N``) make
the user hand-tune that absorption per workflow; this module moves the
arbitration to *runtime*, SIM-SITU-style:

* **Queue policies** (``FifoPolicy`` / ``FairPolicy``) -- the PrefetchPool's
  queue discipline is pluggable.  ``fifo`` (the default) is bit-for-bit the
  old single FIFO deque; ``fair`` is deficit-weighted round-robin (DWRR) over
  per-edge queues: each edge earns ``quantum * weight`` credits per round and
  spends one credit per payload prep, so a YAML ``weight: 3`` edge gets ~3x
  the prep completions of a ``weight: 1`` edge under contention while no edge
  ever starves.

* **DepthAutotuner** -- a feedback controller that widens or narrows each
  autotuned edge's prefetch depth within ``[min, max]`` bounds every K step
  events, driven by the per-edge deltas of the existing
  ``prefetch_hits/misses/prepared_s/blocked_s`` counters:

  ========================================  =======================
  per-tick counter deltas                   decision
  ========================================  =======================
  cancelled > 0                             shrink (wasted preps)
  blocked_s > 0 or misses > hits            grow   (consumer waits)
  served > 0, misses == 0, blocked ~= 0,    shrink after 2 idle
  in-flight < depth                         ticks  (depth unused)
  otherwise                                 hold
  ========================================  =======================

  Depth changes go through ``Channel.set_depth``, which resizes the edge's
  ``ResizableSemaphore`` under the channel lock -- in-flight preps above a
  shrunken limit simply drain; new acquires see the new limit.

* **TelemetryTimeline** -- a bounded ring of timestamped per-edge snapshots
  (queue occupancy, in-flight preps, depth, blocked/prepared seconds, bytes
  shipped, hit/miss/cancel counters) sampled at every autotuner tick and once
  at teardown.  ``WorkflowReport.summary()`` surfaces it and ``export()`` /
  ``load()`` round-trip it through JSON for SIM-SITU-style offline replay.

* **SchedulerRuntime** -- the per-run object the driver owns: it builds the
  pool's queue policy from the YAML ``scheduler:`` block, counts step events
  (producer file closes, consumer intercepted opens, explicit
  ``TaskComm.step()`` calls -- the vol/comm step-boundary hooks), and fires
  the autotuner + telemetry tick every ``tick_every`` events.

Nothing here imports ``channel``: channels are duck-typed (``name``,
``stats``, ``prefetch``, ``autotune``, ``set_depth``, ``_lock``, ``_queue``),
so ``channel.py`` can import the policies/semaphore without a cycle.
"""

from __future__ import annotations

import json
import threading

from ..analysis.lockcheck import make_condition, make_lock
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, Hashable, List, Optional, Sequence, Tuple

__all__ = [
    "QueuePolicy",
    "FifoPolicy",
    "FairPolicy",
    "ResizableSemaphore",
    "SchedulerConfig",
    "DepthAutotuner",
    "AutotuneDecision",
    "TelemetryTimeline",
    "SchedulerRuntime",
    "POLICIES",
]

POLICIES = ("fifo", "fair")


# ---------------------------------------------------------------------------
# queue policies (PrefetchPool scheduler hook)
# ---------------------------------------------------------------------------
class QueuePolicy:
    """Queue discipline for pending payload preps inside the PrefetchPool.

    All methods are called with the pool's condition lock held, so
    implementations need no locking of their own.  Items are opaque to the
    policy (the pool passes ``(future, fn, args)`` tuples).
    """

    name = "abstract"

    def push(self, item: Any, edge: Optional[Hashable] = None,
             weight: int = 1) -> None:
        raise NotImplementedError

    def pop(self) -> Optional[Any]:
        raise NotImplementedError

    def pending(self) -> int:
        raise NotImplementedError

    def drain(self) -> List[Any]:
        """Remove and return every queued item (shutdown cancellation)."""
        raise NotImplementedError

    def __len__(self) -> int:
        return self.pending()


class FifoPolicy(QueuePolicy):
    """One FIFO deque: submission order == service order -- bit-for-bit the
    pre-scheduler PrefetchPool behaviour, and the default."""

    name = "fifo"

    def __init__(self) -> None:
        self._q: Deque[Any] = deque()

    def push(self, item: Any, edge: Optional[Hashable] = None,
             weight: int = 1) -> None:
        self._q.append(item)

    def pop(self) -> Optional[Any]:
        return self._q.popleft() if self._q else None

    def pending(self) -> int:
        return len(self._q)

    def drain(self) -> List[Any]:
        out = list(self._q)
        self._q.clear()
        return out


class FairPolicy(QueuePolicy):
    """Deficit-weighted round-robin over per-edge prep queues.

    Each *active* edge (one with queued preps) is visited in round-robin
    order; on each visit its deficit counter is topped up by
    ``quantum * weight`` and one credit is spent per prep served, so an edge
    with weight W completes ~W preps per round of the competition while a
    weight-1 edge still progresses every round (no starvation).  An edge's
    deficit resets when its queue empties, so a long-idle edge cannot hoard
    credit and burst past everyone when it wakes (standard DWRR).
    """

    name = "fair"

    def __init__(self, quantum: int = 1) -> None:
        if quantum < 1:
            raise ValueError(f"quantum must be >= 1, got {quantum}")
        self.quantum = int(quantum)
        self._queues: Dict[Hashable, Deque[Any]] = {}
        self._active: Deque[Hashable] = deque()  # round-robin visit order
        self._deficit: Dict[Hashable, float] = {}
        self._weights: Dict[Hashable, int] = {}
        self._pending = 0

    def push(self, item: Any, edge: Optional[Hashable] = None,
             weight: int = 1) -> None:
        key = edge if edge is not None else "__anon__"
        self._weights[key] = max(1, int(weight))
        q = self._queues.get(key)
        if q is None:
            q = self._queues[key] = deque()
        if not q:  # edge (re)activates: joins the tail of the round
            self._active.append(key)
            self._deficit[key] = 0.0
        q.append(item)
        self._pending += 1

    def pop(self) -> Optional[Any]:
        # Each non-empty edge needs at most one top-up before it can serve
        # (quantum * weight >= 1), so 2 * len(active) + 1 visits always
        # suffice to find a servable edge when anything is pending.
        for _ in range(2 * len(self._active) + 1):
            if not self._active:
                return None
            key = self._active[0]
            q = self._queues.get(key)
            if not q:  # drained edge: leave the round, forfeit credit
                self._active.popleft()
                self._deficit[key] = 0.0
                continue
            if self._deficit[key] >= 1.0:
                self._deficit[key] -= 1.0
                item = q.popleft()
                self._pending -= 1
                if not q:
                    self._active.popleft()
                    self._deficit[key] = 0.0
                return item
            # credit exhausted: top up, move to the back of the round
            self._deficit[key] += self.quantum * self._weights.get(key, 1)
            self._active.rotate(-1)
        return None

    def pending(self) -> int:
        return self._pending

    def drain(self) -> List[Any]:
        out: List[Any] = []
        for q in self._queues.values():
            out.extend(q)
            q.clear()
        self._active.clear()
        self._deficit.clear()
        self._pending = 0
        return out


def make_policy(name: str, quantum: int = 1) -> QueuePolicy:
    if name == "fifo":
        return FifoPolicy()
    if name == "fair":
        return FairPolicy(quantum=quantum)
    raise ValueError(f"unknown scheduler policy {name!r}; use one of {POLICIES}")


# ---------------------------------------------------------------------------
# resizable bounded semaphore (per-edge prefetch depth)
# ---------------------------------------------------------------------------
class ResizableSemaphore:
    """A BoundedSemaphore whose limit can change at runtime.

    ``threading.BoundedSemaphore`` bakes its value in at construction; depth
    autotuning needs to widen/narrow the per-edge in-flight-prep bound while
    producers are blocked in ``acquire``.  Growing the limit wakes waiters;
    shrinking below the current in-use count simply lets the excess drain --
    no prep is ever interrupted.  Like BoundedSemaphore, releasing more times
    than acquired raises ``ValueError`` (the slot-leak regression tests pin
    both directions).
    """

    def __init__(self, value: int, name: str = "channel.sem:prefetch"):
        if value < 0:
            raise ValueError(f"semaphore value must be >= 0, got {value}")
        self._cond = make_condition(name)
        self._limit = int(value)
        self._in_use = 0

    def acquire(self, timeout: Optional[float] = None) -> bool:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._in_use >= self._limit:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return False
                self._cond.wait(remaining)
            self._in_use += 1
            return True

    def release(self) -> None:
        with self._cond:
            if self._in_use <= 0:
                raise ValueError("ResizableSemaphore released too many times")
            self._in_use -= 1
            self._cond.notify()

    def resize(self, limit: int) -> None:
        with self._cond:
            limit = int(limit)
            if limit < 0:
                raise ValueError(f"semaphore limit must be >= 0, got {limit}")
            grew = limit > self._limit
            self._limit = limit
            if grew:
                self._cond.notify_all()

    @property
    def limit(self) -> int:
        with self._cond:
            return self._limit

    @property
    def in_use(self) -> int:
        with self._cond:
            return self._in_use


# ---------------------------------------------------------------------------
# YAML surface
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class SchedulerConfig:
    """The top-level ``scheduler:`` block of the workflow YAML.

    policy:     ``fifo`` (default; today's single-deque order, bit-for-bit)
                or ``fair`` (deficit-weighted round-robin by per-inport
                ``weight:``).
    quantum:    DWRR credit top-up multiplier (``fair`` only).
    tick_every: autotuner/telemetry tick period, in step events (producer
                file closes + consumer intercepted opens + ``comm.step()``).
    telemetry:  timeline ring capacity in samples; 0 disables sampling.
    """

    policy: str = "fifo"
    quantum: int = 1
    tick_every: int = 4
    telemetry: int = 256
    #: True when the YAML carried a ``scheduler:`` block.  The driver wires
    #: the per-step VOL hooks only for explicit configs (or when some edge
    #: autotunes), so a workflow that never opted in pays zero per-step
    #: cost -- its report still gets a snapshot and one teardown sample.
    explicit: bool = False

    @classmethod
    def from_yaml(cls, doc: Any) -> "SchedulerConfig":
        if doc is None:
            return cls()
        if not isinstance(doc, dict):
            raise ValueError(
                f"workflow 'scheduler:' must be a mapping, got {type(doc).__name__}")
        unknown = set(doc) - {"policy", "quantum", "tick_every", "telemetry"}
        if unknown:
            raise ValueError(
                f"scheduler: unknown keys {sorted(unknown)} (expected policy, "
                f"quantum, tick_every, telemetry)")
        policy = str(doc.get("policy", "fifo"))
        if policy not in POLICIES:
            raise ValueError(
                f"scheduler: policy {policy!r} is invalid; use one of {POLICIES}")
        quantum = int(doc.get("quantum", 1))
        if quantum < 1:
            raise ValueError(f"scheduler: quantum must be >= 1, got {quantum}")
        tick_every = int(doc.get("tick_every", 4))
        if tick_every < 1:
            raise ValueError(
                f"scheduler: tick_every must be >= 1, got {tick_every}")
        telemetry = int(doc.get("telemetry", 256))
        if telemetry < 0:
            raise ValueError(
                f"scheduler: telemetry capacity must be >= 0 (0 disables), "
                f"got {telemetry}")
        return cls(policy=policy, quantum=quantum, tick_every=tick_every,
                   telemetry=telemetry, explicit=True)


# ---------------------------------------------------------------------------
# depth autotuner
# ---------------------------------------------------------------------------
@dataclass
class AutotuneDecision:
    t: float
    edge: str
    old: int
    new: int
    reason: str

    def as_dict(self) -> Dict[str, Any]:
        return {"t": self.t, "edge": self.edge, "old": self.old,
                "new": self.new, "reason": self.reason}


#: consecutive idle ticks before the autotuner narrows an unused depth
#: (hysteresis so a single quiet tick cannot start a grow/shrink oscillation)
IDLE_TICKS_TO_SHRINK = 2

#: blocked seconds per tick below which the consumer counts as not blocked
BLOCKED_EPS_S = 1e-4


class DepthAutotuner:
    """Per-edge prefetch-depth feedback controller.

    ``tick(channels)`` reads each autotuned channel's per-edge counters,
    diffs them against the previous tick, and applies the decision table in
    the module docstring via ``Channel.set_depth`` (one step per tick, so the
    controller cannot overshoot the signal that drove it).  Decisions are
    kept for the report and the telemetry export.
    """

    def __init__(self) -> None:
        self._last: Dict[str, Dict[str, float]] = {}
        self._idle_ticks: Dict[str, int] = {}
        self.decisions: List[AutotuneDecision] = []
        self.ticks = 0

    def _snapshot(self, ch: Any) -> Tuple[Dict[str, float], int, int]:
        with ch._lock:
            s = ch.stats
            cur = {
                "hits": float(s.prefetch_hits),
                "misses": float(s.prefetch_misses),
                "cancelled": float(s.prefetch_cancelled),
                "blocked_s": float(s.prefetch_blocked_s),
                "served": float(s.served),
            }
            return cur, int(ch.prefetch), int(s.inflight_preps)

    def tick(self, channels: Sequence[Any]) -> List[AutotuneDecision]:
        made: List[AutotuneDecision] = []
        now = time.monotonic()
        for ch in channels:
            if getattr(ch, "autotune", None) is None:
                continue
            amin, amax = ch.autotune
            cur, depth, inflight = self._snapshot(ch)
            last = self._last.get(ch.name)
            self._last[ch.name] = cur
            if last is None:  # first sight of this edge: baseline only
                continue
            d = {k: cur[k] - last[k] for k in cur}
            new, reason = depth, None
            idle_branch = False
            if d["cancelled"] > 0 and depth > amin:
                new, reason = depth - 1, "cancelled preps -> shrink"
            elif (d["blocked_s"] > BLOCKED_EPS_S or d["misses"] > d["hits"]) \
                    and (d["misses"] > 0 or d["blocked_s"] > BLOCKED_EPS_S) \
                    and depth < amax:
                new, reason = depth + 1, "consumer blocked -> grow"
            elif (d["served"] > 0 and d["misses"] == 0
                    and d["blocked_s"] <= BLOCKED_EPS_S
                    and inflight < depth and depth > amin):
                idle_branch = True
                idle = self._idle_ticks.get(ch.name, 0) + 1
                if idle >= IDLE_TICKS_TO_SHRINK:
                    new, reason = depth - 1, "preps idle -> shrink"
                    idle = 0
                self._idle_ticks[ch.name] = idle
            if not idle_branch:
                # the shrink hysteresis counts CONSECUTIVE idle ticks: any
                # grow/cancel/hold tick in between restarts the count
                self._idle_ticks[ch.name] = 0
            if reason is not None and new != depth:
                ch.set_depth(new)
                dec = AutotuneDecision(now, ch.name, depth, new, reason)
                self.decisions.append(dec)
                made.append(dec)
        self.ticks += 1
        return made


# ---------------------------------------------------------------------------
# telemetry timeline
# ---------------------------------------------------------------------------
#: one row per (tick, edge); field order is the JSON schema
SAMPLE_FIELDS = (
    "t", "edge", "queue_len", "inflight", "depth", "served", "dropped",
    "bytes_moved", "prefetch_hits", "prefetch_misses", "prefetch_cancelled",
    "prepared_s", "blocked_s", "producer_wait_s", "consumer_wait_s",
)


class TelemetryTimeline:
    """Bounded ring of timestamped per-edge transport snapshots.

    Sampled at every scheduler tick (and once at teardown) so a run's rate
    mismatch is replayable offline: queue occupancy, in-flight preps, the
    current autotuned depth, cumulative blocked/prepared seconds, and bytes
    shipped, per edge.  ``export``/``load`` round-trip the ring through JSON
    (same per-edge sample counts after a round trip -- the acceptance
    criterion), so SIM-SITU-style simulators can consume real traces.
    """

    def __init__(self, capacity: int = 256):
        if capacity < 0:
            raise ValueError(f"telemetry capacity must be >= 0, got {capacity}")
        self.capacity = int(capacity)
        self._lock = make_lock("leaf:telemetry")
        self._samples: Deque[Dict[str, Any]] = deque(maxlen=capacity or None)
        self.dropped = 0
        # discrete lifecycle events (task restarts/drops) -- unlike the
        # sampled rows these are rare and never truncated, so a Gantt
        # consumer can always place every recovery on the timeline
        self._events: List[Dict[str, Any]] = []

    @property
    def enabled(self) -> bool:
        return self.capacity > 0

    def sample(self, channels: Sequence[Any], t: Optional[float] = None) -> int:
        """Record one snapshot row per channel; returns rows recorded."""
        if not self.enabled:
            return 0
        now = time.monotonic() if t is None else t
        rows: List[Dict[str, Any]] = []
        for ch in channels:
            with ch._lock:
                s = ch.stats
                rows.append({
                    "t": now,
                    "edge": ch.name,
                    "queue_len": len(ch._queue),
                    "inflight": s.inflight_preps,
                    "depth": ch.prefetch,
                    "served": s.served,
                    "dropped": s.dropped,
                    "bytes_moved": s.bytes_moved,
                    "prefetch_hits": s.prefetch_hits,
                    "prefetch_misses": s.prefetch_misses,
                    "prefetch_cancelled": s.prefetch_cancelled,
                    "prepared_s": s.prefetch_prepared_s,
                    "blocked_s": s.prefetch_blocked_s,
                    "producer_wait_s": s.producer_wait_s,
                    "consumer_wait_s": s.consumer_wait_s,
                })
        with self._lock:
            for row in rows:
                if len(self._samples) == self.capacity:
                    self.dropped += 1
                self._samples.append(row)
        return len(rows)

    def record_event(self, kind: str, t: Optional[float] = None,
                     **detail: Any) -> None:
        """Append one discrete lifecycle event (``kind`` plus free-form
        detail, e.g. a task restart with task/instance/attempt/epoch).
        Recorded even when sampling is disabled (capacity 0): recovery
        events must never be invisible."""
        row = {"t": time.monotonic() if t is None else t, "kind": kind}
        row.update(detail)
        with self._lock:
            self._events.append(row)

    def events(self, kind: Optional[str] = None) -> List[Dict[str, Any]]:
        with self._lock:
            evs = list(self._events)
        return evs if kind is None else [e for e in evs if e["kind"] == kind]

    def samples(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._samples)

    def per_edge_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for row in self.samples():
            counts[row["edge"]] = counts.get(row["edge"], 0) + 1
        return counts

    def __len__(self) -> int:
        with self._lock:
            return len(self._samples)

    # ------------------------------------------------------------ JSON I/O
    def to_json(self) -> str:
        with self._lock:
            payload = {
                "version": 1,
                "capacity": self.capacity,
                "dropped": self.dropped,
                "fields": list(SAMPLE_FIELDS),
                "samples": [[row[f] for f in SAMPLE_FIELDS]
                            for row in self._samples],
                "events": list(self._events),
            }
        return json.dumps(payload, sort_keys=True)

    def export(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
            f.write("\n")
        return path

    @classmethod
    def from_json(cls, text: str) -> "TelemetryTimeline":
        doc = json.loads(text)
        fields = doc.get("fields", list(SAMPLE_FIELDS))
        tl = cls(capacity=int(doc.get("capacity", 0)))
        tl.dropped = int(doc.get("dropped", 0))
        for values in doc.get("samples", []):
            tl._samples.append(dict(zip(fields, values)))
        tl._events = [dict(e) for e in doc.get("events", [])]
        return tl

    @classmethod
    def load(cls, path: str) -> "TelemetryTimeline":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# per-run runtime (driver-owned)
# ---------------------------------------------------------------------------
class SchedulerRuntime:
    """Per-``Wilkins.run`` scheduling state: step counting, autotuner ticks,
    and the telemetry timeline.

    Step events arrive from the VOL layer (producer file closes, consumer
    intercepted opens) and from explicit ``TaskComm.step()`` calls; every
    ``tick_every`` events the runtime samples telemetry and runs one
    autotuner pass.  ``close()`` stops event intake and takes a final sample
    so short runs still carry at least one telemetry row.
    """

    def __init__(self, config: SchedulerConfig, channels: Sequence[Any]):
        self.config = config
        self.channels = list(channels)
        self.autotuner = DepthAutotuner()
        self.timeline = TelemetryTimeline(config.telemetry)
        self._lock = make_lock("scheduler:runtime")
        self._tick_lock = make_lock("scheduler:tick")
        self._steps = 0
        self._ticks = 0
        self._restarts = 0
        self._rescales = 0
        self._stalls = 0
        self._step_sources: Dict[str, int] = {}
        self._closed = False

    def make_policy(self) -> QueuePolicy:
        return make_policy(self.config.policy, self.config.quantum)

    @property
    def steps(self) -> int:
        with self._lock:
            return self._steps

    def notify_step(self, source: str = "step") -> None:
        """One step event; fires a tick every ``tick_every`` events."""
        with self._lock:
            if self._closed:
                return
            self._steps += 1
            self._step_sources[source] = self._step_sources.get(source, 0) + 1
            due = (self._steps % self.config.tick_every) == 0
        if due:
            self.tick()

    def notify_restart(self, task: str, instance: int, attempt: int,
                       epoch: int, reason: str) -> None:
        """A task instance is restarting: land it on the telemetry timeline
        (visible to Gantt consumers) and count it.  Drops and permanent
        failures arrive through the same door with their own kind."""
        with self._lock:
            if self._closed:
                return
            self._restarts += 1
        self.timeline.record_event("restart", task=task, instance=instance,
                                   attempt=attempt, epoch=epoch, reason=reason)
        # an immediate sample brackets the recovery window in the ring
        with self._tick_lock:
            self.timeline.sample(self.channels)

    def notify_rescale(self, task: str, old_nslots: int, new_nslots: int,
                       old_nprocs: int, new_nprocs: int, trigger: str,
                       cut_step: int, latency_s: float,
                       reason: str = "") -> None:
        """An elastic rescale completed: old->new size, what triggered it
        (policy / stall / api), the checkpoint step the new incarnation
        resumed from, and how long the surgery took."""
        with self._lock:
            if self._closed:
                return
            self._rescales += 1
        self.timeline.record_event(
            "rescale", task=task, old_nslots=old_nslots,
            new_nslots=new_nslots, old_nprocs=old_nprocs,
            new_nprocs=new_nprocs, trigger=trigger, cut_step=cut_step,
            latency_s=latency_s, reason=reason)
        with self._tick_lock:
            self.timeline.sample(self.channels)

    def notify_stall(self, task: str, instance: int, silent_s: float,
                     timeout_s: float, action: str) -> None:
        """The watchdog declared an instance stalled (no heartbeat for
        ``silent_s`` against a ``timeout_s`` budget) and is applying
        ``action`` (rescale / drop)."""
        with self._lock:
            if self._closed:
                return
            self._stalls += 1
        self.timeline.record_event(
            "stall", task=task, instance=instance, silent_s=silent_s,
            timeout_s=timeout_s, action=action)

    def tick(self) -> None:
        # Serialized: step events fire from many producer/consumer threads,
        # but one tick at a time keeps the autotuner's deltas coherent.
        with self._tick_lock:
            self._ticks += 1
            self.timeline.sample(self.channels)
            if any(getattr(ch, "autotune", None) is not None
                   for ch in self.channels):
                self.autotuner.tick(self.channels)

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        with self._tick_lock:
            self.timeline.sample(self.channels)  # final state, always recorded

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            steps = self._steps
            sources = dict(self._step_sources)
        return {
            "policy": self.config.policy,
            "quantum": self.config.quantum,
            "tick_every": self.config.tick_every,
            "steps": steps,
            "step_sources": sources,
            "ticks": self._ticks,
            "decisions": [d.as_dict() for d in self.autotuner.decisions],
            "depths": {ch.name: ch.prefetch for ch in self.channels
                       if getattr(ch, "prefetch", 0)},
            "telemetry_samples": len(self.timeline),
            "telemetry_dropped": self.timeline.dropped,
            "restarts": self._restarts,
            "restart_events": self.timeline.events("restart"),
            "rescales": self._rescales,
            "rescale_events": self.timeline.events("rescale"),
            "stalls": self._stalls,
            "stall_events": self.timeline.events("stall"),
        }
