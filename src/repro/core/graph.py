"""Data-centric workflow graph construction from the YAML description.

Users specify *data requirements* (inports/outports: filename + dataset name
patterns), never edges.  Wilkins matches ports to build the task graph
(paper §3.2): a producer outport and a consumer inport are coupled when their
filename patterns match and at least one dataset pattern overlaps.  Any
directed topology results -- pipeline, fan-in, fan-out, NxN, cycles.

Ensembles (§3.2.1): a task with ``taskCount: N`` expands into N instances.
For each matched edge, producer instances and consumer instances are linked
round-robin over the *longer* index list, reproducing Fig. 3 exactly:
4 producers x 2 consumers -> P0-C0, P1-C1, P2-C0, P3-C1;
1 producer  x N consumers -> fan-out; N x N -> one-to-one pairing.

Subset writers (§3.2.2): ``nwriters`` (the paper's ``io_proc``) restricts
which logical ranks of a producer participate in I/O.

Flow control (§3.6): ``io_freq`` on the consumer inport (1/0 = all, N>1 =
some, -1 = latest).
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import yaml

from .datamodel import match_file, match_path
from .recovery import FailurePolicy
from .scheduler import SchedulerConfig

__all__ = ["DsetSpec", "Port", "TaskSpec", "Edge", "WorkflowGraph"]


@dataclass
class DsetSpec:
    name: str
    file: int = 0
    memory: int = 1

    @property
    def mode(self) -> str:
        if self.memory and not self.file:
            return "memory"
        if self.file and not self.memory:
            return "file"
        if self.file and self.memory:
            return "memory"  # prefer in-situ when both allowed
        raise ValueError(f"dataset {self.name}: neither file nor memory transport enabled")


@dataclass
class Port:
    filename: str
    dsets: List[DsetSpec]
    io_freq: int = 1      # flow control (inports only): 0/1 = all, N>1 =
                          # some (every Nth), -1 = latest; anything else is
                          # rejected at parse time with the task/port named
    queue_depth: int = 1  # channel ring-queue depth (inports only); 1 = paper
                          # rendezvous, >=2 pipelines producer ahead of consumer
    redistribute: bool = False  # M->N planning on this inport: the consumer's
                                # instances/ranks own a decomposition of every
                                # matched dataset and the channel ships only
                                # the owned blocks (paper §3.2.2 / LowFive)
    redist_axis: int = 0        # decomposition axis of the owned blocks
    prefetch: Optional[int] = None  # inport knob: per-edge prefetch DEPTH --
                                    # max in-flight async payload preps on
                                    # each channel of this port (0 = sync
                                    # serve; None = default depth whenever
                                    # the port redistributes)
    weight: int = 1             # inport knob: DWRR share under the `fair`
                                # scheduler policy -- this port's edges get
                                # ~weight x the prep completions of a
                                # weight-1 edge under pool contention
    autotune: Optional[Tuple[int, int]] = None  # inport knob: (min, max)
                                # runtime bounds for the prefetch-depth
                                # autotuner; implies prefetch (initial depth
                                # clamps into the bounds); None = static
    ownership: bool = False     # outports only: the producer's logical ranks
                                # own an even decomposition of every written
                                # dataset; the VOL stamps BlockOwnership at
                                # file close (replaces create_dataset(
                                # ownership=...) in task code)
    own_axis: int = 0           # decomposition axis of the producer blocks
    own_nranks: Optional[int] = None  # block count; None = the task's
                                      # io_procs (nwriters | nprocs)


@dataclass
class TaskSpec:
    func: str
    nprocs: int = 1
    task_count: int = 1
    nwriters: Optional[int] = None       # paper's io_proc / subset writers
    actions: Optional[Tuple[str, str]] = None  # (script/module, function)
    inports: List[Port] = field(default_factory=list)
    outports: List[Port] = field(default_factory=list)
    # YAML ``on_failure:`` -- fail (default, today's chained-error behavior),
    # restart: {max_retries, backoff_s, jitter}, drop (optional task: edges
    # degrade to no-ops), or rescale: {nslots, nprocs} (elastic relaunch at a
    # different size).  See recovery.FailurePolicy.
    on_failure: FailurePolicy = field(default_factory=FailurePolicy)
    # YAML ``stall_timeout_s:`` -- health-watchdog window: no heartbeat from
    # an instance for this long (two consecutive scans: hysteresis) declares
    # it stalled and applies the task's on_failure policy.  None = no watchdog.
    stall_timeout_s: Optional[float] = None
    raw: Dict[str, Any] = field(default_factory=dict)

    @property
    def io_procs(self) -> int:
        return self.nwriters if self.nwriters is not None else self.nprocs


@dataclass
class Edge:
    """A matched producer-outport -> consumer-inport coupling (task level)."""

    producer: str
    consumer: str
    filename_pattern: str       # the consumer's view of the filename
    dset_patterns: List[str]    # consumer dataset selections that matched
    mode: str                   # "memory" | "file"
    io_freq: int = 1
    queue_depth: int = 1
    redistribute: bool = False  # consumer inport declared M->N ownership
    redist_axis: int = 0
    prefetch: Optional[int] = None  # consumer inport's per-edge prefetch depth
    weight: int = 1                 # consumer inport's DWRR scheduler share
    autotune: Optional[Tuple[int, int]] = None  # depth-autotuner bounds

    def instance_links(self, np_: int, nc: int) -> List[Tuple[int, int]]:
        """Round-robin instance pairing over the longer list (paper Fig. 3)."""
        n = max(np_, nc)
        return [(i % np_, i % nc) for i in range(n)]


def _parse_port(p: Dict[str, Any], task: str = "?") -> Port:
    dsets = [
        DsetSpec(
            name=d["name"],
            file=int(d.get("file", 0) or 0),
            memory=int(d.get("memory", 0) or 0) if "memory" in d or "file" in d else 1,
        )
        for d in p.get("dsets", [])
    ]
    if not dsets:
        dsets = [DsetSpec(name="*")]
    qd = int(p.get("queue_depth", 1))
    if qd < 1:
        raise ValueError(f"queue_depth must be >= 1, got {qd}")
    # Flow control is validated HERE, with the task and port named -- by the
    # time a bad value used to reach FlowControl.from_io_freq (at channel
    # construction, deep inside the driver) the error no longer said which
    # YAML line to fix, and a typo'd -2 read like a runtime bug.
    io_freq = int(p.get("io_freq", 1))
    if io_freq < -1:
        raise ValueError(
            f"task {task!r} port {p['filename']!r}: io_freq {io_freq} is "
            f"invalid; use 0/1 (all), N>1 (some: every Nth step), or -1 "
            f"(latest)")
    # ``redistribute: 1`` or ``redistribute: {axis: A}`` on a consumer inport
    redist = p.get("redistribute", 0)
    axis = 0
    if isinstance(redist, dict):
        axis = int(redist.get("axis", 0))
        redist = True
    else:
        redist = bool(int(redist or 0))
    if axis < 0:
        raise ValueError(f"redistribute axis must be >= 0, got {axis}")
    # ``prefetch: N`` on a consumer inport: per-edge async-prep depth
    # (0 = synchronous serve, N >= 1 = at most N in-flight preps per
    # channel).  YAML booleans pass through untouched so the legacy
    # ``prefetch: true`` spelling keeps meaning "default depth", not 1.
    prefetch = p.get("prefetch")
    if prefetch is not None and not isinstance(prefetch, bool):
        prefetch = int(prefetch)
        if prefetch < 0:
            raise ValueError(
                f"task {task!r} port {p['filename']!r}: prefetch depth must "
                f"be >= 0 (0 = sync serve, N = per-edge depth), got {prefetch}")
    # ``weight: N`` on a consumer inport: this port's DWRR share under the
    # top-level ``scheduler: {policy: fair}`` arbitration
    weight = int(p.get("weight", 1))
    if weight < 1:
        raise ValueError(
            f"task {task!r} port {p['filename']!r}: scheduler weight must be "
            f">= 1, got {weight}")
    # ``autotune: 1`` / ``autotune: N`` / ``autotune: {min: A, max: B}`` on a
    # consumer inport: runtime prefetch-depth bounds for the autotuner.
    # Spellings: 1/true -> default bounds [1, 8]; an int N >= 2 -> [1, N];
    # a mapping sets both ends.  min >= 1 always (a zero-depth autotuned
    # edge could park a producer forever on an unpassable semaphore; use
    # ``prefetch: 0`` to disable prefetch instead).
    at = p.get("autotune", None)
    autotune: Optional[Tuple[int, int]] = None
    if isinstance(at, dict):
        unknown = set(at) - {"min", "max"}
        if unknown:
            raise ValueError(
                f"task {task!r} port {p['filename']!r}: unknown autotune keys "
                f"{sorted(unknown)} (expected min, max)")
        bounds = {}
        for key, default in (("min", 1), ("max", 8)):
            val = at.get(key, default)
            if isinstance(val, bool) or not isinstance(val, int):
                raise ValueError(
                    f"task {task!r} port {p['filename']!r}: autotune {key} "
                    f"must be an integer depth, got {val!r}")
            bounds[key] = val
        autotune = (bounds["min"], bounds["max"])
    elif at is not None and at is not False and at != 0:
        if at is True or at == 1:
            autotune = (1, 8)
        elif isinstance(at, int) and at >= 2:
            autotune = (1, at)
        else:
            raise ValueError(
                f"task {task!r} port {p['filename']!r}: autotune must be "
                f"1/true, a max depth >= 2, or {{min, max}}, got {at!r}")
    if autotune is not None:
        amin, amax = autotune
        if amin < 1:
            raise ValueError(
                f"task {task!r} port {p['filename']!r}: autotune min must be "
                f">= 1, got {amin} (use prefetch: 0 to disable prefetch)")
        if amax < amin:
            raise ValueError(
                f"task {task!r} port {p['filename']!r}: autotune bounds must "
                f"satisfy min <= max, got [{amin}, {amax}]")
    # ``ownership: 1`` or ``ownership: {axis: A, nranks: K}`` on an outport
    own = p.get("ownership", 0)
    own_axis, own_nranks = 0, None
    if isinstance(own, dict):
        unknown = set(own) - {"axis", "nranks"}
        if unknown:
            raise ValueError(
                f"port {p['filename']!r}: unknown ownership keys {sorted(unknown)} "
                f"(expected axis, nranks)")
        own_axis = int(own.get("axis", 0))
        if "nranks" in own:
            own_nranks = int(own["nranks"])
        own = True
    else:
        own = bool(int(own or 0))
    if own_axis < 0:
        raise ValueError(
            f"port {p['filename']!r}: ownership axis must be >= 0, got {own_axis}")
    if own_nranks is not None and own_nranks < 1:
        raise ValueError(
            f"port {p['filename']!r}: ownership nranks must be >= 1, got {own_nranks}")
    return Port(filename=p["filename"], dsets=dsets,
                io_freq=io_freq, queue_depth=qd,
                redistribute=redist, redist_axis=axis, prefetch=prefetch,
                weight=weight, autotune=autotune,
                ownership=own, own_axis=own_axis, own_nranks=own_nranks)


def _parse_task(t: Dict[str, Any]) -> TaskSpec:
    actions = t.get("actions")
    if actions is not None:
        if not (isinstance(actions, (list, tuple)) and len(actions) == 2):
            raise ValueError(f"actions must be [script, function], got {actions!r}")
        actions = (str(actions[0]), str(actions[1]))
    stall = t.get("stall_timeout_s")
    if stall is not None:
        try:
            stall = float(stall)
        except (TypeError, ValueError):
            raise ValueError(
                f"task {t['func']!r}: stall_timeout_s must be a number of "
                f"seconds, got {t['stall_timeout_s']!r}") from None
        if stall <= 0:
            raise ValueError(
                f"task {t['func']!r}: stall_timeout_s must be > 0, got "
                f"{stall} (omit the key to disable the watchdog)")
    spec = TaskSpec(
        func=t["func"],
        nprocs=int(t.get("nprocs", 1)),
        task_count=int(t.get("taskCount", 1)),
        nwriters=int(t["nwriters"]) if "nwriters" in t else (
            int(t["io_proc"]) if "io_proc" in t else None),
        actions=actions,
        inports=[_parse_port(p, t["func"]) for p in t.get("inports", [])],
        outports=[_parse_port(p, t["func"]) for p in t.get("outports", [])],
        on_failure=FailurePolicy.from_yaml(t.get("on_failure"), t["func"]),
        stall_timeout_s=stall,
        raw=dict(t),
    )
    for p in spec.inports:
        if p.ownership:
            raise ValueError(
                f"task {spec.func!r}: ownership is an outport declaration "
                f"(inport {p.filename!r} declared it); use redistribute: on "
                f"inports")
    for p in spec.inports:
        if p.autotune is not None and p.prefetch == 0:
            raise ValueError(
                f"task {spec.func!r} inport {p.filename!r}: autotune needs "
                f"prefetch enabled, but the port declares prefetch: 0; drop "
                f"one of the two")
    for p in spec.outports:
        if p.prefetch is not None:
            raise ValueError(
                f"task {spec.func!r}: prefetch is an inport declaration "
                f"(outport {p.filename!r} declared it); it rides the "
                f"consumer's redistribute port")
        if p.weight != 1:
            raise ValueError(
                f"task {spec.func!r}: weight is an inport declaration "
                f"(outport {p.filename!r} declared it); the fair scheduler "
                f"arbitrates consumer edges")
        if p.autotune is not None:
            raise ValueError(
                f"task {spec.func!r}: autotune is an inport declaration "
                f"(outport {p.filename!r} declared it); depth is a consumer-"
                f"edge property")
        if p.own_nranks is not None and p.own_nranks not in (
                spec.nprocs, spec.io_procs):
            raise ValueError(
                f"task {spec.func!r} outport {p.filename!r}: ownership nranks "
                f"{p.own_nranks} matches neither nprocs={spec.nprocs} nor "
                f"nwriters={spec.io_procs}")
    if spec.stall_timeout_s is not None:
        # The watchdog turns "no heartbeat" into a *policy application*; on
        # an unmanaged task there is no policy to apply, and restart-on-stall
        # is rejected too (a stalled-but-alive incarnation would keep serving
        # into channels its restarted twin also serves -- rescale fences the
        # old incarnation under a new generation, restart does not).
        pol = spec.on_failure
        managed = (pol.kind == "drop"
                   or (pol.kind == "rescale" and pol.nslots is not None))
        if not managed:
            raise ValueError(
                f"task {spec.func!r}: stall_timeout_s requires a managed "
                f"on_failure policy that can fence the stalled incarnation "
                f"-- rescale: {{nslots: N}} or drop: -- but the task "
                f"declares {pol.kind!r}")
    return spec


class WorkflowGraph:
    """Tasks + matched edges; the driver instantiates channels from this."""

    def __init__(self, tasks: List[TaskSpec],
                 scheduler: Optional[SchedulerConfig] = None):
        names = [t.func for t in tasks]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate task func names: {names}")
        self.tasks: Dict[str, TaskSpec] = {t.func: t for t in tasks}
        self.scheduler = scheduler if scheduler is not None else SchedulerConfig()
        self.edges: List[Edge] = self._match()
        self._validate_rescale()

    # ------------------------------------------------------------- loading
    @classmethod
    def from_yaml(cls, source: Union[str, Dict[str, Any]]) -> "WorkflowGraph":
        if isinstance(source, str):
            if os.path.exists(source):
                with open(source) as f:
                    doc = yaml.safe_load(f)
            else:
                doc = yaml.safe_load(source)
        else:
            doc = source
        if not isinstance(doc, dict) or "tasks" not in doc:
            raise ValueError("workflow YAML must have a top-level 'tasks' list")
        return cls([_parse_task(t) for t in doc["tasks"]],
                   scheduler=SchedulerConfig.from_yaml(doc.get("scheduler")))

    # ------------------------------------------------------------ matching
    def _match(self) -> List[Edge]:
        edges: List[Edge] = []
        for pname, ptask in self.tasks.items():
            for outp in ptask.outports:
                for cname, ctask in self.tasks.items():
                    if cname == pname:
                        continue
                    for inp in ctask.inports:
                        if not (match_file(inp.filename, outp.filename)
                                or match_file(outp.filename, inp.filename)):
                            continue
                        matched: List[str] = []
                        mode = "memory"
                        for ind in inp.dsets:
                            for outd in outp.dsets:
                                if match_path(ind.name, outd.name) or match_path(
                                    outd.name, ind.name
                                ):
                                    matched.append(ind.name)
                                    mode = ind.mode
                                    break
                        if matched:
                            edges.append(
                                Edge(
                                    producer=pname,
                                    consumer=cname,
                                    filename_pattern=inp.filename,
                                    dset_patterns=matched,
                                    mode=mode,
                                    io_freq=inp.io_freq,
                                    queue_depth=inp.queue_depth,
                                    redistribute=inp.redistribute,
                                    redist_axis=inp.redist_axis,
                                    prefetch=inp.prefetch,
                                    weight=inp.weight,
                                    autotune=inp.autotune,
                                )
                            )
        return edges

    # -------------------------------------------------- rescale validation
    def _validate_rescale(self) -> None:
        """Reject unsupportable elastic-rescale declarations at parse time.

        A ``rescale: {nslots: N}`` relaunch re-partitions the task's inbound
        channels and replays undelivered steps from the producers' retention
        rings -- byte-identical replay is only well-defined when:

        * the task is a pure consumer (no outports): re-cutting a producer's
          instance count would re-pair every downstream edge's round-robin
          ``instance_links`` mid-run;
        * every feeding producer runs a single instance (``taskCount: 1``):
          with multiple producer instances the modulo pairing changes which
          producer feeds which consumer slot across sizes;
        * every inbound edge uses memory transport (file-mode edges carry no
          replayable payloads);
        * no inbound edge uses ``io_freq: -1`` (latest-mode seq assignment
          depends on live waiter timing, so the replay set is not
          deterministic across sizes).

        ``rescale: {nprocs: K}`` alone (no nslots) changes only the logical
        rank count and carries none of these restrictions.
        """
        for name, t in self.tasks.items():
            pol = t.on_failure
            if pol.kind != "rescale" or pol.nslots is None:
                continue
            self.validate_rescale_target(name)

    def validate_rescale_target(self, name: str) -> None:
        """Structural rules for resizing ``name``'s instance count; used at
        parse time for declared policies and again by the driver for
        programmatic ``RunSupervisor.rescale(task, nslots=...)`` triggers
        (which have no YAML to validate)."""
        t = self.tasks[name]
        if t.outports:
            raise ValueError(
                f"task {name!r}: rescale: {{nslots: ...}} requires a "
                f"pure consumer (no outports) -- resizing a producer "
                f"would re-pair every downstream edge's round-robin "
                f"instance links mid-run; use rescale: {{nprocs: ...}} "
                f"to resize a producer's logical ranks instead")
        inbound = self.producers_of(name)
        if not inbound:
            raise ValueError(
                f"task {name!r}: rescale: {{nslots: ...}} declared but "
                f"no inport edge matched -- an isolated task has no "
                f"channels to re-partition")
        for e in inbound:
            if self.tasks[e.producer].task_count != 1:
                raise ValueError(
                    f"task {name!r}: rescale: {{nslots: ...}} requires "
                    f"every feeding producer to run a single instance, "
                    f"but {e.producer!r} has taskCount="
                    f"{self.tasks[e.producer].task_count}")
            if e.mode != "memory":
                raise ValueError(
                    f"task {name!r}: rescale: {{nslots: ...}} requires "
                    f"memory transport on every inbound edge, but the "
                    f"edge from {e.producer!r} ({e.filename_pattern!r}) "
                    f"uses file mode")
            if e.io_freq == -1:
                raise ValueError(
                    f"task {name!r}: rescale: {{nslots: ...}} cannot "
                    f"combine with io_freq: -1 (latest) on the edge from "
                    f"{e.producer!r} -- latest-mode step selection "
                    f"depends on live consumer timing, so the replay "
                    f"set is not deterministic across sizes")

    # ----------------------------------------------------------- utilities
    def producers_of(self, task: str) -> List[Edge]:
        return [e for e in self.edges if e.consumer == task]

    def consumers_of(self, task: str) -> List[Edge]:
        return [e for e in self.edges if e.producer == task]

    def total_instances(self) -> int:
        return sum(t.task_count for t in self.tasks.values())

    def total_procs(self) -> int:
        return sum(t.nprocs * t.task_count for t in self.tasks.values())

    def topology_kind(self) -> str:
        """Classify for reporting: pipeline / fan-in / fan-out / NxN / general."""
        if not self.edges:
            return "disconnected"
        kinds = set()
        for e in self.edges:
            np_ = self.tasks[e.producer].task_count
            nc = self.tasks[e.consumer].task_count
            if np_ == 1 and nc == 1:
                kinds.add("pipeline")
            elif np_ == 1:
                kinds.add("fan-out")
            elif nc == 1:
                kinds.add("fan-in")
            elif np_ == nc:
                kinds.add("NxN")
            else:
                kinds.add("MxN")
        return "+".join(sorted(kinds))

    def __repr__(self) -> str:
        return (f"<WorkflowGraph tasks={list(self.tasks)} edges={len(self.edges)} "
                f"topology={self.topology_kind()}>")
