"""Data-centric workflow graph construction from the YAML description.

Users specify *data requirements* (inports/outports: filename + dataset name
patterns), never edges.  Wilkins matches ports to build the task graph
(paper §3.2): a producer outport and a consumer inport are coupled when their
filename patterns match and at least one dataset pattern overlaps.  Any
directed topology results -- pipeline, fan-in, fan-out, NxN, cycles.

Ensembles (§3.2.1): a task with ``taskCount: N`` expands into N instances.
For each matched edge, producer instances and consumer instances are linked
round-robin over the *longer* index list, reproducing Fig. 3 exactly:
4 producers x 2 consumers -> P0-C0, P1-C1, P2-C0, P3-C1;
1 producer  x N consumers -> fan-out; N x N -> one-to-one pairing.

Subset writers (§3.2.2): ``nwriters`` (the paper's ``io_proc``) restricts
which logical ranks of a producer participate in I/O.

Flow control (§3.6): ``io_freq`` on the consumer inport (1/0 = all, N>1 =
some, -1 = latest).
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import yaml

from ..analysis import rules
from ..obs.recorder import TraceConfig
from .datamodel import match_file, match_path
from .recovery import FailurePolicy
from .scheduler import SchedulerConfig

__all__ = ["DsetSpec", "Port", "TaskSpec", "Edge", "WorkflowGraph"]


@dataclass
class DsetSpec:
    name: str
    file: int = 0
    memory: int = 1

    @property
    def mode(self) -> str:
        if self.memory and not self.file:
            return "memory"
        if self.file and not self.memory:
            return "file"
        if self.file and self.memory:
            return "memory"  # prefer in-situ when both allowed
        raise ValueError(f"dataset {self.name}: neither file nor memory transport enabled")


@dataclass
class Port:
    filename: str
    dsets: List[DsetSpec]
    io_freq: int = 1      # flow control (inports only): 0/1 = all, N>1 =
                          # some (every Nth), -1 = latest; anything else is
                          # rejected at parse time with the task/port named
    queue_depth: int = 1  # channel ring-queue depth (inports only); 1 = paper
                          # rendezvous, >=2 pipelines producer ahead of consumer
    redistribute: bool = False  # M->N planning on this inport: the consumer's
                                # instances/ranks own a decomposition of every
                                # matched dataset and the channel ships only
                                # the owned blocks (paper §3.2.2 / LowFive)
    redist_axis: int = 0        # decomposition axis of the owned blocks
    prefetch: Optional[int] = None  # inport knob: per-edge prefetch DEPTH --
                                    # max in-flight async payload preps on
                                    # each channel of this port (0 = sync
                                    # serve; None = default depth whenever
                                    # the port redistributes)
    weight: int = 1             # inport knob: DWRR share under the `fair`
                                # scheduler policy -- this port's edges get
                                # ~weight x the prep completions of a
                                # weight-1 edge under pool contention
    autotune: Optional[Tuple[int, int]] = None  # inport knob: (min, max)
                                # runtime bounds for the prefetch-depth
                                # autotuner; implies prefetch (initial depth
                                # clamps into the bounds); None = static
    ownership: bool = False     # outports only: the producer's logical ranks
                                # own an even decomposition of every written
                                # dataset; the VOL stamps BlockOwnership at
                                # file close (replaces create_dataset(
                                # ownership=...) in task code)
    own_axis: int = 0           # decomposition axis of the producer blocks
    own_nranks: Optional[int] = None  # block count; None = the task's
                                      # io_procs (nwriters | nprocs)


@dataclass
class TaskSpec:
    func: str
    nprocs: int = 1
    task_count: int = 1
    nwriters: Optional[int] = None       # paper's io_proc / subset writers
    actions: Optional[Tuple[str, str]] = None  # (script/module, function)
    inports: List[Port] = field(default_factory=list)
    outports: List[Port] = field(default_factory=list)
    # YAML ``on_failure:`` -- fail (default, today's chained-error behavior),
    # restart: {max_retries, backoff_s, jitter}, drop (optional task: edges
    # degrade to no-ops), or rescale: {nslots, nprocs} (elastic relaunch at a
    # different size).  See recovery.FailurePolicy.
    on_failure: FailurePolicy = field(default_factory=FailurePolicy)
    # YAML ``stall_timeout_s:`` -- health-watchdog window: no heartbeat from
    # an instance for this long (two consecutive scans: hysteresis) declares
    # it stalled and applies the task's on_failure policy.  None = no watchdog.
    stall_timeout_s: Optional[float] = None
    raw: Dict[str, Any] = field(default_factory=dict)

    @property
    def io_procs(self) -> int:
        return self.nwriters if self.nwriters is not None else self.nprocs


@dataclass
class Edge:
    """A matched producer-outport -> consumer-inport coupling (task level)."""

    producer: str
    consumer: str
    filename_pattern: str       # the consumer's view of the filename
    dset_patterns: List[str]    # consumer dataset selections that matched
    mode: str                   # "memory" | "file"
    io_freq: int = 1
    queue_depth: int = 1
    redistribute: bool = False  # consumer inport declared M->N ownership
    redist_axis: int = 0
    prefetch: Optional[int] = None  # consumer inport's per-edge prefetch depth
    weight: int = 1                 # consumer inport's DWRR scheduler share
    autotune: Optional[Tuple[int, int]] = None  # depth-autotuner bounds

    def instance_links(self, np_: int, nc: int) -> List[Tuple[int, int]]:
        """Round-robin instance pairing over the longer list (paper Fig. 3)."""
        n = max(np_, nc)
        return [(i % np_, i % nc) for i in range(n)]


def _parse_port(p: Dict[str, Any], task: str = "?") -> Port:
    # All legality rules live in analysis.rules (shared with the offline
    # analyzer and the driver's programmatic-trigger checks); this wrapper
    # only owns the dataclasses.
    kw = rules.validated_port(p, task)
    kw["dsets"] = [DsetSpec(name=n, file=f, memory=m)
                   for (n, f, m) in kw["dsets"]]
    return Port(**kw)


def _parse_task(t: Dict[str, Any]) -> TaskSpec:
    actions = rules.validated_actions(t.get("actions"))
    stall = rules.validated_stall_timeout(t)
    spec = TaskSpec(
        func=t["func"],
        nprocs=int(t.get("nprocs", 1)),
        task_count=int(t.get("taskCount", 1)),
        nwriters=int(t["nwriters"]) if "nwriters" in t else (
            int(t["io_proc"]) if "io_proc" in t else None),
        actions=actions,
        inports=[_parse_port(p, t["func"]) for p in t.get("inports", [])],
        outports=[_parse_port(p, t["func"]) for p in t.get("outports", [])],
        on_failure=FailurePolicy.from_yaml(t.get("on_failure"), t["func"]),
        stall_timeout_s=stall,
        raw=dict(t),
    )
    rules.check_task(spec)
    return spec


class WorkflowGraph:
    """Tasks + matched edges; the driver instantiates channels from this."""

    def __init__(self, tasks: List[TaskSpec],
                 scheduler: Optional[SchedulerConfig] = None,
                 tracing: Optional[TraceConfig] = None):
        rules.check_duplicate_names([t.func for t in tasks])
        self.tasks: Dict[str, TaskSpec] = {t.func: t for t in tasks}
        self.scheduler = scheduler if scheduler is not None else SchedulerConfig()
        self.tracing = tracing  # None = the zero-cost default (no tracer)
        self.edges: List[Edge] = self._match()
        self._validate_rescale()

    # ------------------------------------------------------------- loading
    @classmethod
    def from_yaml(cls, source: Union[str, Dict[str, Any]]) -> "WorkflowGraph":
        if isinstance(source, str):
            if os.path.exists(source):
                with open(source) as f:
                    doc = yaml.safe_load(f)
            else:
                doc = yaml.safe_load(source)
        else:
            doc = source
        rules.check_workflow_doc(doc)
        return cls([_parse_task(t) for t in doc["tasks"]],
                   scheduler=SchedulerConfig.from_yaml(doc.get("scheduler")),
                   tracing=TraceConfig.from_yaml(doc.get("tracing")))

    # ------------------------------------------------------------ matching
    def _match(self) -> List[Edge]:
        edges: List[Edge] = []
        for pname, ptask in self.tasks.items():
            for outp in ptask.outports:
                for cname, ctask in self.tasks.items():
                    if cname == pname:
                        continue
                    for inp in ctask.inports:
                        if not (match_file(inp.filename, outp.filename)
                                or match_file(outp.filename, inp.filename)):
                            continue
                        matched: List[str] = []
                        mode = "memory"
                        for ind in inp.dsets:
                            for outd in outp.dsets:
                                if match_path(ind.name, outd.name) or match_path(
                                    outd.name, ind.name
                                ):
                                    matched.append(ind.name)
                                    mode = ind.mode
                                    break
                        if matched:
                            edges.append(
                                Edge(
                                    producer=pname,
                                    consumer=cname,
                                    filename_pattern=inp.filename,
                                    dset_patterns=matched,
                                    mode=mode,
                                    io_freq=inp.io_freq,
                                    queue_depth=inp.queue_depth,
                                    redistribute=inp.redistribute,
                                    redist_axis=inp.redist_axis,
                                    prefetch=inp.prefetch,
                                    weight=inp.weight,
                                    autotune=inp.autotune,
                                )
                            )
        return edges

    # -------------------------------------------------- rescale validation
    def _validate_rescale(self) -> None:
        """Reject unsupportable elastic-rescale declarations at parse time.

        A ``rescale: {nslots: N}`` relaunch re-partitions the task's inbound
        channels and replays undelivered steps from the producers' retention
        rings -- byte-identical replay is only well-defined when:

        * the task is a pure consumer (no outports): re-cutting a producer's
          instance count would re-pair every downstream edge's round-robin
          ``instance_links`` mid-run;
        * every feeding producer runs a single instance (``taskCount: 1``):
          with multiple producer instances the modulo pairing changes which
          producer feeds which consumer slot across sizes;
        * every inbound edge uses memory transport (file-mode edges carry no
          replayable payloads);
        * no inbound edge uses ``io_freq: -1`` (latest-mode seq assignment
          depends on live waiter timing, so the replay set is not
          deterministic across sizes).

        ``rescale: {nprocs: K}`` alone (no nslots) changes only the logical
        rank count and carries none of these restrictions.
        """
        for name, t in self.tasks.items():
            pol = t.on_failure
            if pol.kind != "rescale" or pol.nslots is None:
                continue
            self.validate_rescale_target(name)

    def validate_rescale_target(self, name: str) -> None:
        """Structural rules for resizing ``name``'s instance count; used at
        parse time for declared policies and again by the driver for
        programmatic ``RunSupervisor.rescale(task, nslots=...)`` triggers
        (which have no YAML to validate).  The rules themselves live in
        ``analysis.rules`` (shared with the offline analyzer)."""
        rules.validate_rescale_target(self, name)

    # ----------------------------------------------------------- utilities
    def producers_of(self, task: str) -> List[Edge]:
        return [e for e in self.edges if e.consumer == task]

    def consumers_of(self, task: str) -> List[Edge]:
        return [e for e in self.edges if e.producer == task]

    def total_instances(self) -> int:
        return sum(t.task_count for t in self.tasks.values())

    def total_procs(self) -> int:
        return sum(t.nprocs * t.task_count for t in self.tasks.values())

    def topology_kind(self) -> str:
        """Classify for reporting: pipeline / fan-in / fan-out / NxN / general."""
        if not self.edges:
            return "disconnected"
        kinds = set()
        for e in self.edges:
            np_ = self.tasks[e.producer].task_count
            nc = self.tasks[e.consumer].task_count
            if np_ == 1 and nc == 1:
                kinds.add("pipeline")
            elif np_ == 1:
                kinds.add("fan-out")
            elif nc == 1:
                kinds.add("fan-in")
            elif np_ == nc:
                kinds.add("NxN")
            else:
                kinds.add("MxN")
        return "+".join(sorted(kinds))

    def __repr__(self) -> str:
        return (f"<WorkflowGraph tasks={list(self.tasks)} edges={len(self.edges)} "
                f"topology={self.topology_kind()}>")
