"""Flash attention Pallas TPU kernel (forward), GQA-aware.

Layout: q (B, H, Sq, D), k/v (B, KV, Sk, D) -- transposed in ops.py so the
sequence axis tiles cleanly.  Grid = (B, H, Sq/bq, Sk/bk); the innermost grid
axis is sequential on TPU, so the online-softmax running state (m, l, acc)
lives in VMEM scratch carried across k-blocks.  GQA is folded into the K/V
``index_map`` (head h reads kv head h // rep) -- K/V tiles are fetched once
per kv head, not replicated.

Block sizes default to (bq, bk) = (256, 512) with D padded to a multiple of
128: the MXU wants 128-aligned contraction dims, and the VMEM working set is
    bq*D (q) + 2*bk*D (k,v) + bq*D (acc) + O(bq) ~ 1.1 MiB  at D=128, f32
well under the ~16 MiB/core VMEM budget, leaving room for double buffering.

Causal masking is positional (q_pos >= k_pos); fully-masked k-blocks are
skipped via ``pl.when`` on the block index, so the causal kernel does ~half
the block visits.  ``window > 0`` adds a sliding-window lower bound.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               bq: int, bk: int, sk: int, causal: bool, window: int,
               scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk

    # Skip k-blocks entirely above the causal diagonal / below the window.
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)
    if window:
        run = jnp.logical_and(run, k_start + bk - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < sk
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                   # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot(
            p.astype(v.dtype), v)
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jnp.ndarray,  # (B, H, Sq, D)
    k: jnp.ndarray,  # (B, KV, Sk, D)
    v: jnp.ndarray,  # (B, KV, Sk, D)
    causal: bool = True,
    window: int = 0,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    b, h, sq, d = q.shape
    kv, sk = k.shape[1], k.shape[2]
    rep = h // kv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    nq = -(-sq // bq)
    nk = -(-sk // bk)
    pq, pk2 = nq * bq - sq, nk * bk - sk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk2:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk2), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk2), (0, 0)))

    kernel = functools.partial(
        _fa_kernel, bq=bq, bk=bk, sk=sk, causal=causal, window=window,
        scale=1.0 / math.sqrt(d))

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, qi, ki, rep=rep: (b_, h_ // rep, ki, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda b_, h_, qi, ki, rep=rep: (b_, h_ // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, nq * bq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # m
            pltpu.VMEM((bq,), jnp.float32),       # l
            pltpu.VMEM((bq, d), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq]
