"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import _sdpa, blockwise_attention  # noqa: F401  (oracle)


def flash_attention_ref(q, k, v, causal=True, window=0):
    """q (B,S,H,D); k/v (B,S,KV,D) -> (B,S,H,D).  Naive softmax attention."""
    return _sdpa(q, k, v, causal=causal, window=window)


def ssd_intra_chunk_ref(x, dA, Bm, Cm):
    """Reference for kernels.ssd_scan.ssd_intra_chunk (einsum formulation).

    x (B,NC,q,H,P); dA (B,NC,q,H); Bm/Cm (B,NC,q,G,N).
    Returns (y_diag, states) with the same shapes as the kernel.
    """
    b, nc, q, h, p = x.shape
    g, n = Bm.shape[3], Bm.shape[4]
    r = h // g
    xf = x.astype(jnp.float32)
    dAf = dA.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    cs = jnp.cumsum(dAf, axis=2)                               # (b,nc,q,h)
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]         # (b,nc,i,j,h)
    ii = jnp.arange(q)
    mask = ii[:, None] >= ii[None, :]
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)

    scores = jnp.einsum("bcign,bcjgn->bcijg", Cf, Bf)          # (b,nc,i,j,g)
    xg = xf.reshape(b, nc, q, g, r, p)
    Lg = L.reshape(b, nc, q, q, g, r)
    y = jnp.einsum("bcijg,bcijgr,bcjgrp->bcigrp", scores, Lg, xg)
    y = y.reshape(b, nc, q, h, p)

    decay_last = jnp.exp(cs[:, :, -1:, :] - cs)                # (b,nc,q,h)
    xw = xf * decay_last[..., None]
    xwg = xw.reshape(b, nc, q, g, r, p)
    st = jnp.einsum("bcjgn,bcjgrp->bcgrnp", Bf, xwg).reshape(b, nc, h, n, p)
    return y, st


def pack_blocks_ref(src, tile_offsets, tile_rows=8):
    """numpy oracle for kernels.pack.pack_blocks."""
    src = np.asarray(src)
    out = [src[o * tile_rows:(o + 1) * tile_rows] for o in np.asarray(tile_offsets)]
    return np.concatenate(out, axis=0)


def pack_cols_ref(src, tile_offsets, tile_cols=8):
    """numpy oracle for kernels.pack.pack_cols."""
    src = np.asarray(src)
    out = [src[:, o * tile_cols:(o + 1) * tile_cols]
           for o in np.asarray(tile_offsets)]
    return np.concatenate(out, axis=1)
