"""Pallas TPU kernels for the compute hot spots of the orchestrated workloads:

* ``flash_attention`` -- GQA flash attention forward (MXU tiling, online
  softmax in VMEM scratch, causal block skipping);
* ``ssd_scan``        -- Mamba2 SSD intra-chunk quadratic part;
* ``pack``            -- transport block-gather into contiguous send buffers
  (scalar-prefetch index-map DMA), the TPU-native analogue of LowFive's
  serialization path.

Each kernel ships with a pure-jnp oracle in ``ref.py``; ``ops.py`` holds the
jitted public wrappers (interpret=True on CPU, Mosaic on TPU).
"""

from . import ops, ref

__all__ = ["ops", "ref"]
