"""Jitted public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode --
the kernel body runs in Python for correctness validation; on a TPU backend
they compile to Mosaic.  The wrappers also own layout adaptation (BSHD <->
BHSD transposes, chunking/padding) so model code calls a clean surface.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import pack as _pack
from . import ssd_scan as _ssd

__all__ = ["flash_attention", "ssd_chunked_pallas", "pack_blocks", "pack_cols"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash_attention(q, k, v, causal, window, block_q, block_k):
    qt = jnp.swapaxes(q, 1, 2)   # (B,H,S,D)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _fa.flash_attention_bhsd(
        qt, kt, vt, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret())
    return jnp.swapaxes(out, 1, 2)


def _flash_fwd(q, k, v, causal, window, block_q, block_k):
    return _flash_attention(q, k, v, causal, window, block_q, block_k), (q, k, v)


def _flash_bwd(causal, window, block_q, block_k, res, g):
    # Backward recomputes attention blockwise (flash-style: no S^2
    # materialization) via the oracle's VJP -- the standard structure of the
    # flash backward pass, here expressed through XLA instead of a second
    # hand-written kernel.
    from repro.models.layers import blockwise_attention

    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: blockwise_attention(
            q_, k_, v_, causal=causal, window=window,
            q_chunk=block_q, k_chunk=block_k), q, k, v)
    return vjp(g)


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    block_q: int = 256, block_k: int = 512):
    """q (B,S,H,D); k/v (B,S,KV,D) -> (B,S,H,D). Differentiable (custom VJP)."""
    return _flash_attention(q, k, v, causal, window, block_q, block_k)


def _ssd_oracle(x, dA, Bm, Cm, chunk, initial_state):
    from repro.models.ssm import ssd_chunked

    return ssd_chunked(x, dA, Bm, Cm, chunk=chunk, initial_state=initial_state)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _ssd_pallas(x, dA, Bm, Cm, chunk, initial_state):
    return _ssd_impl(x, dA, Bm, Cm, chunk, initial_state)


def _ssd_fwd(x, dA, Bm, Cm, chunk, initial_state):
    return (_ssd_impl(x, dA, Bm, Cm, chunk, initial_state),
            (x, dA, Bm, Cm, initial_state))


def _ssd_bwd(chunk, res, g):
    x, dA, Bm, Cm, initial_state = res
    if initial_state is None:
        _, vjp = jax.vjp(
            lambda *a: _ssd_oracle(*a, chunk, None), x, dA, Bm, Cm)
        return (*vjp(g), None)
    _, vjp = jax.vjp(
        lambda x_, dA_, B_, C_, s0: _ssd_oracle(x_, dA_, B_, C_, chunk, s0),
        x, dA, Bm, Cm, initial_state)
    return vjp(g)


_ssd_pallas.defvjp(_ssd_fwd, _ssd_bwd)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunked_pallas(x, dA, Bm, Cm, chunk: int = 256, initial_state=None):
    """Drop-in for models.ssm.ssd_chunked with the intra-chunk part in Pallas.

    x (B,S,H,P) pre-multiplied by dt; dA (B,S,H); Bm/Cm (B,S,G,N).
    Returns (y (B,S,H,P), final_state (B,H,N,P)). Differentiable (custom VJP;
    backward runs the oracle's VJP -- the recurrence grads stay in XLA).
    """
    return _ssd_pallas(x, dA, Bm, Cm, chunk, initial_state)


def _ssd_impl(x, dA, Bm, Cm, chunk: int = 256, initial_state=None):
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    r = h // g
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s

    def pad3(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))

    xp = pad3(x).reshape(b, nc, q, h, p)
    dAp = pad3(dA).reshape(b, nc, q, h)
    Bp = pad3(Bm).reshape(b, nc, q, g, n)
    Cp = pad3(Cm).reshape(b, nc, q, g, n)

    y_diag, states = _ssd.ssd_intra_chunk(xp, dAp, Bp, Cp, interpret=_interpret())

    # inter-chunk recurrence + off-diagonal correction (cheap, stays in XLA)
    dA_cs = jnp.cumsum(dAp, axis=2)
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])                   # (b,nc,h)
    s0 = (jnp.zeros((b, h, n, p), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def scan_fn(prev, inp):
        st, dec = inp
        new = prev * dec[:, :, None, None] + st
        return new, prev

    final, prevs = jax.lax.scan(
        scan_fn, s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prevs = jnp.moveaxis(prevs, 0, 1)                           # (b,nc,h,n,p)

    in_decay = jnp.exp(dA_cs)                                   # (b,nc,q,h)
    Ch = jnp.repeat(Cp, r, axis=3) if g != h else Cp            # (b,nc,q,h,n)
    y_off = jnp.einsum("bcqhn,bchnp->bcqhp", Ch, prevs)
    y_off = y_off * in_decay[..., None]

    y = (y_diag + y_off).reshape(b, nc * q, h, p)[:, :s]
    return y.astype(x.dtype), final


@functools.partial(jax.jit, static_argnames=("tile_rows",))
def pack_blocks(src, tile_offsets, tile_rows: int = 8):
    return _pack.pack_blocks(src, tile_offsets, tile_rows=tile_rows,
                             interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("tile_cols",))
def pack_cols(src, tile_offsets, tile_cols: int = 8):
    return _pack.pack_cols(src, tile_offsets, tile_cols=tile_cols,
                           interpret=_interpret())
