"""Block-gather pack kernel -- the transport serialization hot path.

The M->N redistribution planner (repro.core.redistribute) reduces every
producer->consumer exchange to "gather these row-blocks of a 2-D buffer into
one contiguous send buffer".  On TPU the natural implementation is an
index-map-driven DMA: the block offsets arrive as a *scalar-prefetch* operand
(pltpu.PrefetchScalarGridSpec), the grid walks output tiles, and each tile's
``index_map`` points the DMA engine at the right source row -- no gather
scatter ops, just strided HBM->VMEM->HBM copies.

Two tile layouts cover the planner's 1-D decompositions of a 2-D buffer:

* ``pack_blocks`` -- row-slab gathers (axis-0 decompositions): tiles are
  (tile_rows, cols) and the scalar operand indexes source row-tiles.
* ``pack_cols``   -- column-slab gathers (axis-1 decompositions): tiles are
  (rows, tile_cols) and the scalar operand indexes source column-tiles, so
  axis!=0 reshards stay on the kernel path instead of falling back to numpy.

The planner pads ragged blocks up to tile granularity (LowFive ships whole
hyperslabs, same idea).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pack_kernel(offs_ref, src_ref, out_ref):
    out_ref[...] = src_ref[...]


def pack_blocks(
    src: jnp.ndarray,          # (R, C) source buffer
    tile_offsets: jnp.ndarray,  # (T,) int32: source row-tile index per out tile
    tile_rows: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Gather T row-tiles of ``tile_rows`` rows each into a contiguous buffer.

    out[t*tile_rows:(t+1)*tile_rows] = src[tile_offsets[t]*tile_rows : ...]

    A ragged source (rows not a multiple of ``tile_rows``) is zero-padded up
    to tile granularity so the last tile's DMA stays in bounds; callers that
    gather the tail tile (the redistribution pack executor) trim the pad rows
    back off the packed output.
    """
    r, c = src.shape
    pad = -r % tile_rows
    if pad:
        src = jnp.pad(src, ((0, pad), (0, 0)))
        r += pad
    t = tile_offsets.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((tile_rows, c), lambda i, offs: (offs[i], 0)),
        ],
        out_specs=pl.BlockSpec((tile_rows, c), lambda i, offs: (i, 0)),
    )
    return pl.pallas_call(
        _pack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t * tile_rows, c), src.dtype),
        interpret=interpret,
    )(tile_offsets, src)


def pack_cols(
    src: jnp.ndarray,           # (R, C) source buffer
    tile_offsets: jnp.ndarray,  # (T,) int32: source col-tile index per out tile
    tile_cols: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Gather T column-tiles of ``tile_cols`` columns each, contiguously.

    out[:, t*tile_cols:(t+1)*tile_cols] = src[:, tile_offsets[t]*tile_cols : ...]

    The column twin of ``pack_blocks``: the grid walks output column tiles
    and the scalar-prefetch operand points each tile's DMA at the right
    source column band (full-height (R, tile_cols) blocks).  A ragged source
    (columns not a multiple of ``tile_cols``) is zero-padded up to tile
    granularity; callers trim the pad columns back off the packed output.
    On real TPU prefer ``tile_cols`` multiples of the 128-lane width.
    """
    r, c = src.shape
    pad = -c % tile_cols
    if pad:
        src = jnp.pad(src, ((0, 0), (0, pad)))
        c += pad
    t = tile_offsets.shape[0]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(t,),
        in_specs=[
            pl.BlockSpec((r, tile_cols), lambda i, offs: (0, offs[i])),
        ],
        out_specs=pl.BlockSpec((r, tile_cols), lambda i, offs: (0, i)),
    )
    return pl.pallas_call(
        _pack_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, t * tile_cols), src.dtype),
        interpret=interpret,
    )(tile_offsets, src)
