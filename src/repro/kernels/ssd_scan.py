"""SSD (Mamba2) intra-chunk Pallas TPU kernel.

The chunked SSD algorithm splits into (i) an intra-chunk quadratic part --
attention-like (q x q) einsums, the MXU hot spot -- and (ii) a cheap
sequential inter-chunk state recurrence.  This kernel computes (i) plus each
chunk's *state contribution*; the recurrence and the off-diagonal correction
stay in jnp (``repro.models.ssm``), which XLA fuses fine because they are
O(S*N*P) not O(S*q).

Grid = (B, NC, H): one program per (batch, chunk, head).  The head axis maps
to its B/C group via ``index_map`` (h // r), mirroring GQA in the attention
kernel.  Per-program working set at q=256, N=P=128, f32:
    x (q,P) + B,C (q,N) + L (q,q) + state (N,P) ~ 0.6 MiB  << VMEM.
All matmuls are (256,128)x(128,256)-shaped -- MXU-aligned.

Outputs: y_diag (B, NC, q, H, P) and states (B, NC, H, N, P).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dA_ref, b_ref, c_ref, y_ref, st_ref, *, q: int):
    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)       # (q, P)
    dA = dA_ref[0, 0, :, 0].astype(jnp.float32)        # (q,)
    Bm = b_ref[0, 0, :, 0, :].astype(jnp.float32)      # (q, N)
    Cm = c_ref[0, 0, :, 0, :].astype(jnp.float32)      # (q, N)

    cs = jnp.cumsum(dA)                                # (q,)
    # L[i,j] = exp(cs[i] - cs[j]) for i >= j else 0   (segment-sum decay)
    diff = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)        # (q, q)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # (q, q)
    y = jax.lax.dot((scores * L).astype(x.dtype), x)   # (q, P)
    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)

    # chunk state contribution: sum_j B[j]^T (x[j] * exp(cs[-1] - cs[j]))
    decay_last = jnp.exp(cs[q - 1] - cs)               # (q,)
    xw = x * decay_last[:, None]
    st = jax.lax.dot_general(Bm, xw, (((0,), (0,)), ((), ())))      # (N, P)
    st_ref[0, 0, 0] = st.astype(st_ref.dtype)


def ssd_intra_chunk(
    x: jnp.ndarray,    # (B, NC, q, H, P)   x pre-multiplied by dt
    dA: jnp.ndarray,   # (B, NC, q, H)
    Bm: jnp.ndarray,   # (B, NC, q, G, N)
    Cm: jnp.ndarray,   # (B, NC, q, G, N)
    interpret: bool = False,
):
    """Returns (y_diag (B,NC,q,H,P), states (B,NC,H,N,P))."""
    b, nc, q, h, p = x.shape
    g, n = Bm.shape[3], Bm.shape[4]
    r = h // g

    kernel = functools.partial(_ssd_kernel, q=q)
    y, st = pl.pallas_call(
        kernel,
        grid=(b, nc, h),
        in_specs=[
            pl.BlockSpec((1, 1, q, 1, p), lambda b_, c_, h_: (b_, c_, 0, h_, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda b_, c_, h_: (b_, c_, 0, h_)),
            pl.BlockSpec((1, 1, q, 1, n),
                         lambda b_, c_, h_, r=r: (b_, c_, 0, h_ // r, 0)),
            pl.BlockSpec((1, 1, q, 1, n),
                         lambda b_, c_, h_, r=r: (b_, c_, 0, h_ // r, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, 1, p), lambda b_, c_, h_: (b_, c_, 0, h_, 0)),
            pl.BlockSpec((1, 1, 1, n, p), lambda b_, c_, h_: (b_, c_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, q, h, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, n, p), jnp.float32),
        ],
        interpret=interpret,
    )(x, dA, Bm, Cm)
    return y, st
