"""Pass 3: deterministic schedule exploration + happens-before race
detection for the transport and rescale protocols.

Entry points:

* ``python -m repro.analysis explore [--scenario NAME | --all]`` -- run the
  clean-scenario corpus (or one scenario) under a bounded schedule budget.
* ``explore(build, ...)`` / ``replay(build, schedule_id)`` -- library use.
* ``WILKINS_EXPLORE=1`` -- makes the ``make_lock``/``make_condition``/
  ``make_semaphore`` factories hand out cooperative model primitives; they
  only behave differently while a :class:`Controller` is installed.

See ``control.py`` for the scheduler/DFS design, ``instrument.py`` for the
model primitives, ``scenarios.py`` for the corpus.
"""

from .control import (Controller, ExploreAbort, ExploreError, ExploreReport,
                      RunResult, decode_schedule, encode_schedule, explore,
                      replay, run_schedule)
from .scenarios import CORPUS, build_scenario, names

__all__ = [
    "Controller", "ExploreAbort", "ExploreError", "ExploreReport",
    "RunResult", "decode_schedule", "encode_schedule", "explore", "replay",
    "run_schedule", "CORPUS", "build_scenario", "names",
]
