"""Pass 3: the deterministic schedule explorer (CHESS/loom-style).

A scenario is a function returning a list of ``(name, fn)`` thread bodies
closed over freshly-built shared state (real ``Channel``/``Dataset``
objects, or seeded-race mockups).  The :class:`Controller` runs those
bodies on real OS threads but serializes them onto ONE runnable-at-a-time
token: every instrumented operation -- ``ExploreLock.acquire``,
``ExploreCondition.wait``/``notify``, ``ExploreSemaphore``, and every
explicit ``lockcheck.sched_point`` in core -- is a *yield point* where the
controller decides which thread proceeds.  Because only the chosen thread
ever runs, an execution is fully determined by the sequence of decisions,
which makes every interleaving reproducible.

Enumeration (``explore``) is a stateless DFS over decision prefixes:

* **bounded preemption** (CHESS): switching away from a thread that could
  still run costs one unit of a small budget (default 2).  Most concurrency
  bugs need very few preemptions, and the bound collapses the schedule
  space from exponential-in-steps to polynomial.
* **sleep sets** (partial-order reduction): after exploring thread *t* at a
  decision node, sibling branches put *t* to sleep until some executed
  operation is *dependent* with the operation *t* was about to perform
  (same object key).  Commuting acquisitions are explored once, not twice.

What the explorer reports (each with a **replayable schedule ID** that
re-runs the exact interleaving):

* **WLK320** -- a data race: two accesses to the same buffer, at least one
  a write, unordered by the happens-before relation (vector clocks stamped
  at lock release->acquire, CV notify->wake, semaphore release->acquire,
  and the explicit ``hb_publish``/``hb_consume`` channel and CoW edges).
  Both stack traces are attached.
* **WLK321** -- deadlock: no thread is runnable and at least one is blocked
  on a lock (or the run spins on timed waits without progress).
* **WLK322** -- lost wakeup: every blocked thread is parked on a condition
  variable no one will ever notify again.
* **WLK323** -- a scenario invariant (assertion) failed under some
  schedule: exactly-once delivery violated, torn value observed, etc.

Schedule IDs are ``<scenario>@s<step>.<thread>[-s<step>.<thread>...]``:
the decisions taken at every multi-candidate yield point of the failing
run.  ``replay`` forces those decisions and lets the deterministic default
policy (run the current thread while it can) fill in the rest.
"""

from __future__ import annotations

import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..diagnostics import Diagnostic, Findings, Location
from .. import lockcheck

__all__ = [
    "Controller", "ExploreAbort", "ExploreError", "RunResult",
    "ExploreReport", "run_schedule", "explore", "replay",
    "encode_schedule", "decode_schedule",
]

#: thread states
RUNNABLE = "runnable"
BLOCKED_LOCK = "lock"      # parked on a model lock; enabled iff lock free
WAITING_CV = "cv-wait"     # parked in Condition.wait; never enabled
REACQ_CV = "cv-reacq"      # notified/timed out; enabled iff the CV lock is free
BLOCKED_SEM = "sem"        # parked on a model semaphore; enabled iff permits
DONE = "done"

#: timed waits fire only when nothing else can run; a run that takes more
#: than this many consecutive timeout-wakes without real progress is spinning
#: on deadlines -- report it as a stall (WLK321) instead of looping forever.
MAX_TIMEOUT_WAKES = 64


class ExploreAbort(BaseException):
    """Raised through parked threads to unwind a finished/failed schedule.

    Derives from ``BaseException`` so scenario code's ``except Exception``
    handlers cannot swallow it."""


class ExploreError(RuntimeError):
    """The explorer itself hit a hard limit (step cap, wedged thread)."""


def _trim_stack(skip: int = 2, limit: int = 10) -> str:
    frames = traceback.extract_stack()[:-skip]
    interesting = [f for f in frames
                   if "explore/control.py" not in f.filename
                   and "explore/instrument.py" not in f.filename
                   and "/threading.py" not in f.filename]
    return "".join(traceback.format_list(interesting[-limit:]))


class _VC:
    """A vector clock over the scenario's thread indices."""

    __slots__ = ("c",)

    def __init__(self, n: int):
        self.c = [0] * n

    def copy(self) -> "_VC":
        out = _VC(0)
        out.c = list(self.c)
        return out

    def join(self, other: "_VC") -> None:
        self.c = [max(a, b) for a, b in zip(self.c, other.c)]

    def leq(self, other: "_VC") -> bool:
        return all(a <= b for a, b in zip(self.c, other.c))


class _Thread:
    """One managed scenario thread plus its model/scheduling state."""

    def __init__(self, idx: int, name: str, fn: Callable[[], None], n: int):
        self.idx = idx
        self.name = name
        self.fn = fn
        self.event = threading.Event()
        self.thread: Optional[threading.Thread] = None
        self.state = RUNNABLE
        self.waiting_on: Any = None     # the model primitive when blocked
        self.timed = False              # parked with a timeout?
        self.wait_result = True         # what Condition.wait returns on resume
        self.pending_join: Optional[_VC] = None  # notifier's clock, if notified
        self.pending_key: Any = ("begin", idx)   # op key for dependence/sleep
        self.clock = _VC(n)
        self.clock.c[idx] = 1


@dataclass
class _Node:
    """A multi-candidate decision point observed during one run."""

    step: int
    candidates: List[Tuple[int, Any]]   # (thread idx, its pending op key)
    chosen: int


@dataclass
class RunResult:
    decisions: List[Tuple[int, int]] = field(default_factory=list)
    nodes: List[_Node] = field(default_factory=list)
    findings: Findings = field(default_factory=Findings)
    pruned: bool = False      # redundant under sleep sets; not counted as clean
    overflow: bool = False    # hit the per-schedule step cap
    steps: int = 0


class Controller:
    """Serializes managed threads onto one token and records decisions.

    One Controller runs ONE schedule; ``explore`` constructs a fresh one
    (and fresh scenario state) per enumerated schedule.
    """

    def __init__(self, bodies: Sequence[Tuple[str, Callable[[], None]]],
                 forced: Optional[Dict[int, int]] = None,
                 sleep_at: Optional[Dict[int, Dict[int, Any]]] = None,
                 preemption_bound: int = 2,
                 max_steps: int = 20000,
                 scenario: str = "scenario"):
        n = len(bodies)
        self.threads = [_Thread(i, name, fn, n)
                        for i, (name, fn) in enumerate(bodies)]
        self.forced = dict(forced or {})
        self.sleep_at = {s: dict(m) for s, m in (sleep_at or {}).items()}
        self.preemption_bound = int(preemption_bound)
        self.max_steps = int(max_steps)
        self.scenario = scenario
        self.step = 0
        self.preemptions = 0
        self.timeout_wakes = 0
        self.live_sleep: Dict[int, Any] = {}
        self.result = RunResult()
        self.abort = False
        self._mu = threading.Lock()  # wilkins: ignore[WLK305] -- controller internals
        self._driver_evt = threading.Event()
        self._by_ident: Dict[int, _Thread] = {}
        # happens-before state
        self._pub: Dict[Any, _VC] = {}       # hb_publish key -> clock
        # shadow memory: addr -> (write (vc, tidx, stack) | None,
        #                         {tidx: (vc, stack)} reads since last write)
        self._shadow: Dict[Any, Tuple[Optional[Tuple[_VC, int, str]],
                                      Dict[int, Tuple[_VC, str]]]] = {}
        self._race_sites: set = set()        # dedupe reported (addr, pair)
        # raw op key -> dense index, assigned in first-reference order.
        # Raw keys embed id() of PER-RUN objects (every schedule rebuilds
        # the scenario), so they are meaningless across runs; the reference
        # ORDER over a shared forced prefix is deterministic, so interned
        # indices recorded in a parent's sleep sets match the sibling run's
        # indices for the same logical operation.  Without this the sleep
        # keys never match, sleepers never wake, and sibling branches get
        # pruned as "redundant" before reaching their bugs.
        self._key_intern: Dict[Any, int] = {}

    # ------------------------------------------------------------ plumbing
    def _me(self) -> Optional[_Thread]:
        return self._by_ident.get(threading.get_ident())

    def managed(self) -> bool:
        return self._me() is not None

    def _park(self, t: _Thread) -> None:
        t.event.wait()
        t.event.clear()
        if self.abort:
            raise ExploreAbort()

    def _switch(self, cur: _Thread, nxt: _Thread) -> None:
        if nxt is cur:
            return
        nxt.event.set()
        self._park(cur)

    def _abort_all(self) -> None:
        with self._mu:
            self.abort = True
            for t in self.threads:
                t.event.set()

    # ----------------------------------------------------------- enabling
    def _enabled(self, t: _Thread) -> bool:
        if t.state == RUNNABLE:
            return True
        if t.state == BLOCKED_LOCK:
            return t.waiting_on.owner is None
        if t.state == REACQ_CV:
            return t.waiting_on._lk.owner is None
        if t.state == BLOCKED_SEM:
            return t.waiting_on.permits > 0
        return False  # WAITING_CV, DONE

    # ----------------------------------------------------------- deciding
    def _decide(self, cur: Optional[_Thread]) -> _Thread:
        if self.abort:
            raise ExploreAbort()
        self.step += 1
        if self.step > self.max_steps:
            self.result.overflow = True
            self._abort_all()
            raise ExploreAbort()
        enabled = [t for t in self.threads if self._enabled(t)]
        if not enabled:
            return self._handle_stuck(cur)
        awake = [t for t in enabled if t.idx not in self.live_sleep]
        if not awake:
            # every runnable thread is asleep: this schedule is equivalent
            # to one already explored -- prune it
            self.result.pruned = True
            self._abort_all()
            raise ExploreAbort()
        cur_enabled = cur is not None and cur in awake
        if cur_enabled and self.preemptions >= self.preemption_bound:
            candidates = [cur]
        else:
            candidates = awake
        chosen: Optional[_Thread] = None
        want = self.forced.get(self.step)
        if want is not None:
            chosen = next((t for t in candidates if t.idx == want), None)
        if chosen is None:
            chosen = cur if cur_enabled else candidates[0]
        if len(candidates) > 1:
            self.result.nodes.append(_Node(
                step=self.step,
                candidates=[(t.idx, t.pending_key) for t in candidates],
                chosen=chosen.idx))
        if len(enabled) > 1:
            # record a decision for every multi-ENABLED step, not just
            # multi-candidate ones: sleep sets narrow `candidates` during
            # exploration but do not exist during replay, so a replay of
            # this schedule faces the full enabled set here and needs the
            # forced entry to stay on the recorded path
            self.result.decisions.append((self.step, chosen.idx))
        if cur_enabled and chosen is not cur:
            self.preemptions += 1
        sl = self.sleep_at.get(self.step)
        if sl:
            self.live_sleep.update(sl)
            self.live_sleep.pop(chosen.idx, None)
        return chosen

    def _handle_stuck(self, cur: Optional[_Thread]) -> _Thread:
        """No thread is enabled: fire a timed wait if one exists, else
        report deadlock (WLK321) / lost wakeup (WLK322) and abort."""
        timed = [t for t in self.threads
                 if t.state == WAITING_CV and t.timed]
        if timed:
            self.timeout_wakes += 1
            if self.timeout_wakes <= MAX_TIMEOUT_WAKES:
                t = timed[0]
                cv = t.waiting_on
                cv.waiters.remove(t.idx)
                t.state = REACQ_CV
                t.wait_result = False      # Condition.wait timeout contract
                return self._decide(cur)   # re-evaluate with t now enabled
            self._report_stuck(
                "WLK321",
                f"no progress after {MAX_TIMEOUT_WAKES} timeout-wakes: "
                f"threads spin on timed waits without the predicate ever "
                f"becoming true")
        else:
            blocked = [t for t in self.threads if t.state != DONE]
            if blocked and all(t.state == WAITING_CV for t in blocked):
                self._report_stuck(
                    "WLK322",
                    "lost wakeup: "
                    + "; ".join(f"thread {t.name!r} is parked in "
                                f"{t.waiting_on.name}.wait() and no live "
                                f"thread will notify it" for t in blocked))
            else:
                self._report_stuck(
                    "WLK321",
                    "deadlock: "
                    + "; ".join(f"thread {t.name!r} blocked ({t.state}) on "
                                f"{getattr(t.waiting_on, 'name', '?')}"
                                for t in blocked))
        self._abort_all()
        raise ExploreAbort()

    def _report_stuck(self, code: str, message: str) -> None:
        self.result.findings.add(Diagnostic(
            code, f"[{self.scenario}] {message}", Location()))

    # ------------------------------------------------ model-primitive ops
    def lock_acquire(self, lk, blocking: bool = True,
                     timeout: Optional[float] = None) -> bool:
        cur = self._me()
        self._set_pending(cur, ("lock", id(lk)))
        self._switch(cur, self._decide(cur))   # the pre-acquire window
        while lk.owner is not None:
            if not blocking:
                return False
            cur.state = BLOCKED_LOCK
            cur.waiting_on = lk
            self._switch(cur, self._decide(cur))
        lk.owner = cur.idx
        cur.state = RUNNABLE
        cur.waiting_on = None
        cur.clock.join(lk.clock)               # HB: release -> acquire
        return True

    def lock_release(self, lk) -> None:
        cur = self._me()
        if lk.owner != cur.idx:
            raise RuntimeError(
                f"{lk.name}: released by thread {cur.name!r} which does "
                f"not hold it (owner={lk.owner})")
        lk.clock.join(cur.clock)
        cur.clock.c[cur.idx] += 1
        lk.owner = None
        self._set_pending(cur, ("lock", id(lk)))
        self._switch(cur, self._decide(cur))   # post-critical-section window

    def cv_wait(self, cv, timeout: Optional[float] = None) -> bool:
        cur = self._me()
        lk = cv._lk
        if lk.owner != cur.idx:
            raise RuntimeError(f"{cv.name}: wait() on un-acquired lock")
        # Pre-park window: the wait is pending but the thread is not yet
        # a waiter.  This keeps the park a single-object step (sleep-set
        # dependency checks compare one pending key per step; a step that
        # silently runs from an earlier yield straight into the park has
        # a hidden CV effect and lets the sleep set prune the lost-wakeup
        # interleaving as "independent").  With proper locking the window
        # is unreachable by a notifier, which must hold the CV's lock.
        self._set_pending(cur, ("cv", id(cv)))
        self._switch(cur, self._decide(cur))
        # release the lock (with the HB edge), park as a waiter
        lk.clock.join(cur.clock)
        cur.clock.c[cur.idx] += 1
        lk.owner = None
        self._op_executed(self._intern_key(("lock", id(lk))))
        cur.state = WAITING_CV
        cur.waiting_on = cv
        cur.timed = timeout is not None
        cur.wait_result = True
        cur.pending_join = None
        cv.waiters.append(cur.idx)
        self._set_pending(cur, ("cv", id(cv)))
        self._switch(cur, self._decide(cur))
        # resumed: state is REACQ_CV (notified, or timed out in _handle_stuck)
        while lk.owner is not None:
            cur.state = BLOCKED_LOCK
            cur.waiting_on = lk
            self._switch(cur, self._decide(cur))
        lk.owner = cur.idx
        cur.state = RUNNABLE
        cur.waiting_on = None
        cur.timed = False
        cur.clock.join(lk.clock)
        self._op_executed(self._intern_key(("lock", id(lk))))
        if cur.pending_join is not None:       # HB: notify -> wake
            cur.clock.join(cur.pending_join)
            cur.pending_join = None
        return cur.wait_result

    def cv_notify(self, cv, n: int = 1) -> None:
        """Wake up to ``n`` waiters.  Deliberately does NOT require the
        caller to hold the CV's lock: a notify racing the check-to-park gap
        of a waiter is exactly the lost-wakeup hazard the explorer models
        (real ``threading`` forbids it; lower-level CVs do not).

        The notify is its own scheduling step: without the yield it runs
        hidden inside whatever step preceded it, its CV effect invisible
        to the sleep set's one-key-per-step dependency check."""
        cur = self._me()
        self._set_pending(cur, ("cv", id(cv)))
        self._switch(cur, self._decide(cur))
        woken = cv.waiters[:max(0, n)] if n >= 0 else list(cv.waiters)
        for idx in woken:
            t = self.threads[idx]
            cv.waiters.remove(idx)
            t.state = REACQ_CV
            t.pending_join = cur.clock.copy()
        if woken:
            cur.clock.c[cur.idx] += 1

    def sem_acquire(self, sem, blocking: bool = True,
                    timeout: Optional[float] = None) -> bool:
        cur = self._me()
        self._set_pending(cur, ("sem", id(sem)))
        self._switch(cur, self._decide(cur))
        while sem.permits <= 0:
            if not blocking:
                return False
            cur.state = BLOCKED_SEM
            cur.waiting_on = sem
            cur.timed = timeout is not None
            self._switch(cur, self._decide(cur))
        sem.permits -= 1
        cur.state = RUNNABLE
        cur.waiting_on = None
        cur.timed = False
        cur.clock.join(sem.clock)              # HB: release -> acquire
        return True

    def sem_release(self, sem, n: int = 1) -> None:
        cur = self._me()
        sem.clock.join(cur.clock)
        cur.clock.c[cur.idx] += 1
        sem.permits += n
        self._set_pending(cur, ("sem", id(sem)))
        self._switch(cur, self._decide(cur))

    # ----------------------------------------------- sched_point + HB/race
    def sched_point(self, tag: str, key: Any = None,
                    access: Optional[str] = None) -> None:
        cur = self._me()
        if cur is None:
            return   # unmanaged thread (e.g. a prefetch worker): no model
        self._set_pending(cur, key if key is not None else ("tag", tag))
        self._switch(cur, self._decide(cur))
        if access is not None:
            self._race_check(cur, tag, cur.pending_key, access)

    def hb_publish(self, key: Any) -> None:
        cur = self._me()
        if cur is None:
            return
        vc = self._pub.setdefault(key, _VC(len(self.threads)))
        vc.join(cur.clock)
        cur.clock.c[cur.idx] += 1

    def hb_consume(self, key: Any) -> None:
        cur = self._me()
        if cur is None:
            return
        vc = self._pub.get(key)
        if vc is not None:
            cur.clock.join(vc)

    def _race_check(self, cur: _Thread, tag: str, addr: Any,
                    mode: str) -> None:
        write, reads = self._shadow.get(addr, (None, {}))
        stack = _trim_stack()
        racy: List[Tuple[str, int, str]] = []
        if write is not None and write[1] != cur.idx \
                and not write[0].leq(cur.clock):
            racy.append(("write", write[1], write[2]))
        if mode == "w":
            for tidx, (vc, rstack) in reads.items():
                if tidx != cur.idx and not vc.leq(cur.clock):
                    racy.append(("read", tidx, rstack))
        for kind, tidx, ostack in racy:
            site = (addr, min(tidx, cur.idx), max(tidx, cur.idx))
            if site in self._race_sites:
                continue
            self._race_sites.add(site)
            self.result.findings.add(Diagnostic(
                "WLK320",
                f"[{self.scenario}] data race at {tag!r}: thread "
                f"{cur.name!r} {'writes' if mode == 'w' else 'reads'} a "
                f"buffer that thread {self.threads[tidx].name!r} "
                f"{kind.replace('e', 'es', 1) if kind == 'write' else kind + 's'} "
                f"with no happens-before edge between them\n"
                f"--- access by {cur.name!r}:\n{stack}"
                f"--- prior {kind} by {self.threads[tidx].name!r}:\n{ostack}",
                Location()))
        if racy:
            self._abort_all()
            raise ExploreAbort()
        if mode == "w":
            self._shadow[addr] = ((cur.clock.copy(), cur.idx, stack), {})
        else:
            reads = dict(reads)
            reads[cur.idx] = (cur.clock.copy(), stack)
            self._shadow[addr] = (write, reads)

    def _intern_key(self, key: Any) -> int:
        idx = self._key_intern.get(key)
        if idx is None:
            idx = len(self._key_intern)
            self._key_intern[key] = idx
        return idx

    def _set_pending(self, cur: _Thread, key: Any) -> None:
        """Stamp ``cur``'s next operation (interned) and count it as
        executed for sleep-set dependence."""
        cur.pending_key = self._intern_key(key)
        self._op_executed(cur.pending_key)

    def _op_executed(self, key: Any) -> None:
        """An operation with ``key`` is about to run: wake sleeping threads
        whose pending operation is dependent (same key) with it.  A thread
        put to sleep before it ever ran carries the opaque ``("begin", i)``
        marker -- its first operation is unknown, so it must wake on ANY
        operation (keeping it asleep on an op it might depend on would be
        unsound)."""
        if self.live_sleep:
            for idx in [i for i, k in self.live_sleep.items()
                        if k == key or (isinstance(k, tuple) and k
                                        and k[0] == "begin")]:
                del self.live_sleep[idx]

    # -------------------------------------------------------- thread loop
    def _run_thread(self, t: _Thread) -> None:
        try:
            self._park(t)      # wait for the first token
            t.fn()
        except ExploreAbort:
            pass
        except BaseException as e:
            if not self.abort:
                self.result.findings.add(Diagnostic(
                    "WLK323",
                    f"[{self.scenario}] thread {t.name!r} failed: "
                    f"{type(e).__name__}: {e}\n"
                    + "".join(traceback.format_exception(
                        type(e), e, e.__traceback__, limit=8)),
                    Location()))
                self._abort_all()
        finally:
            self._finish(t)

    def _finish(self, t: _Thread) -> None:
        t.state = DONE
        with self._mu:
            if all(th.state == DONE for th in self.threads):
                self._driver_evt.set()
                return
            if self.abort:
                return
        try:
            nxt = self._decide(None)
            nxt.event.set()
        except ExploreAbort:
            pass

    # -------------------------------------------------------------- drive
    def run(self, wall_timeout: float = 60.0) -> RunResult:
        self._by_ident.clear()
        for t in self.threads:
            t.thread = threading.Thread(
                target=self._run_thread, args=(t,),
                name=f"explore:{t.name}", daemon=True)
        for t in self.threads:
            t.thread.start()
            # the ident is only known once the thread runs; park() gates the
            # body until the map is filled in below, so register eagerly
            self._by_ident[t.thread.ident] = t
        self.threads[0].event.set()
        if not self._driver_evt.wait(timeout=wall_timeout):
            self.abort = True
            for t in self.threads:
                t.event.set()
            raise ExploreError(
                f"[{self.scenario}] schedule wedged after {wall_timeout}s "
                f"(a managed thread blocked outside the model?)")
        for t in self.threads:
            t.thread.join(timeout=5.0)
        self.result.steps = self.step
        return self.result


# ---------------------------------------------------------------------------
# schedule IDs
# ---------------------------------------------------------------------------
def encode_schedule(scenario: str, decisions: Sequence[Tuple[int, int]]) -> str:
    body = "-".join(f"s{s}.{t}" for s, t in decisions) or "root"
    return f"{scenario}@{body}"


def decode_schedule(schedule_id: str) -> Tuple[str, Dict[int, int]]:
    scenario, _, body = schedule_id.partition("@")
    forced: Dict[int, int] = {}
    if body and body != "root":
        for part in body.split("-"):
            s, _, t = part[1:].partition(".")
            forced[int(s)] = int(t)
    return scenario, forced


# ---------------------------------------------------------------------------
# the DFS driver
# ---------------------------------------------------------------------------
@dataclass
class ExploreReport:
    scenario: str
    schedules: int = 0
    pruned: int = 0
    complete: bool = False          # frontier exhausted within budget
    findings: Findings = field(default_factory=Findings)
    schedule_id: Optional[str] = None
    steps_total: int = 0
    elapsed_s: float = 0.0

    @property
    def found(self) -> bool:
        return len(self.findings) > 0

    def as_dict(self) -> Dict[str, Any]:
        return {
            "scenario": self.scenario,
            "schedules": self.schedules,
            "pruned": self.pruned,
            "complete": self.complete,
            "found": self.found,
            "codes": sorted({d.code for d in self.findings}),
            "schedule_id": self.schedule_id,
            "steps_total": self.steps_total,
            "elapsed_s": self.elapsed_s,
        }


def run_schedule(build: Callable[[], Sequence[Tuple[str, Callable[[], None]]]],
                 forced: Optional[Dict[int, int]] = None,
                 sleep_at: Optional[Dict[int, Dict[int, Any]]] = None,
                 preemption_bound: int = 2,
                 max_steps: int = 20000,
                 scenario: str = "scenario") -> RunResult:
    """Run ONE schedule of ``build()`` under a fresh controller."""
    ctl = Controller(build(), forced=forced, sleep_at=sleep_at,
                     preemption_bound=preemption_bound,
                     max_steps=max_steps, scenario=scenario)
    prev = lockcheck.set_explore_controller(ctl)
    try:
        return ctl.run()
    finally:
        lockcheck.set_explore_controller(prev)


def explore(build: Callable[[], Sequence[Tuple[str, Callable[[], None]]]],
            *, scenario: str = "scenario", max_schedules: int = 256,
            preemption_bound: int = 2, max_steps: int = 20000) -> ExploreReport:
    """Enumerate schedules of ``build`` until a finding, exhaustion, or the
    ``max_schedules`` budget; stops at the FIRST finding (its schedule ID
    replays it)."""
    t0 = time.monotonic()
    report = ExploreReport(scenario=scenario)
    # frontier entries: (forced decisions, sleep_at); LIFO => DFS
    frontier: List[Tuple[List[Tuple[int, int]],
                         Dict[int, Dict[int, Any]]]] = [([], {})]
    while frontier and report.schedules < max_schedules:
        forced_list, sleep_at = frontier.pop()
        forced = dict(forced_list)
        res = run_schedule(build, forced=forced, sleep_at=sleep_at,
                           preemption_bound=preemption_bound,
                           max_steps=max_steps, scenario=scenario)
        report.schedules += 1
        report.steps_total += res.steps
        if res.pruned:
            report.pruned += 1
        if len(res.findings):
            report.findings = res.findings
            report.schedule_id = encode_schedule(scenario, res.decisions)
            report.elapsed_s = time.monotonic() - t0
            return report
        # expand fresh nodes (deeper than this run's forced prefix)
        last_forced = forced_list[-1][0] if forced_list else -1
        for node in res.nodes:
            if node.step <= last_forced:
                continue
            base = [d for d in res.decisions if d[0] < node.step]
            keys = dict(node.candidates)
            slept: Dict[int, Any] = {node.chosen: keys[node.chosen]}
            siblings = [idx for idx, _ in node.candidates
                        if idx != node.chosen]
            # push in reverse so the LIFO explores siblings in order, each
            # sleeping every sibling explored before it (sleep-set POR)
            pending = []
            for idx in siblings:
                new_sleep = {s: dict(m) for s, m in sleep_at.items()
                             if s <= node.step}
                new_sleep[node.step] = dict(slept)
                pending.append((base + [(node.step, idx)], new_sleep))
                slept[idx] = keys[idx]
            frontier.extend(reversed(pending))
    report.complete = not frontier
    report.elapsed_s = time.monotonic() - t0
    return report


def replay(build: Callable[[], Sequence[Tuple[str, Callable[[], None]]]],
           schedule_id: str, *, preemption_bound: Optional[int] = None,
           max_steps: int = 20000) -> RunResult:
    """Re-run the exact interleaving named by ``schedule_id``.

    The preemption bound is lifted to the number of forced decisions (every
    forced switch must be takeable), so a schedule found near the budget
    edge still replays."""
    scenario, forced = decode_schedule(schedule_id)
    bound = preemption_bound if preemption_bound is not None \
        else len(forced) + 2
    return run_schedule(build, forced=forced, preemption_bound=bound,
                        max_steps=max_steps, scenario=scenario)
