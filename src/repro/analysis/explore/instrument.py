"""Explore-mode synchronization primitives.

When ``WILKINS_EXPLORE=1`` the ``make_lock``/``make_condition``/
``make_semaphore`` factories in :mod:`repro.analysis.lockcheck` hand out
these wrappers instead of real ``threading`` objects.  Each wrapper has a
dual personality:

* On a thread **managed** by the active :class:`~.control.Controller`
  (i.e. a scenario thread), every operation routes through the controller:
  it is a yield point, it updates the lock/CV/semaphore *model* state the
  controller schedules against, and it stamps the happens-before vector
  clocks.  No real OS blocking ever happens -- the controller's one-token
  handoff guarantees only one managed thread runs at a time, so the model
  lock IS the mutual exclusion.
* On an **unmanaged** thread (imports at module load, a stray daemon
  worker, test setup code) the wrapper falls back to a real ``threading``
  primitive so code outside a scenario still just works.

The wrappers deliberately implement only the API surface core uses
(context manager, ``acquire``/``release``, ``wait``/``notify``/
``notify_all``, semaphore ``acquire``/``release``).
"""

from __future__ import annotations

import threading
from typing import Any, List, Optional

from .. import lockcheck

__all__ = ["ExploreLock", "ExploreCondition", "ExploreSemaphore",
           "TrackedCell"]


def _controller_for(obj) -> Optional[Any]:
    c = lockcheck.explore_controller()
    if c is not None and c.managed():
        return c
    return None


class ExploreLock:
    """Model mutex: ``owner`` is a thread index or None."""

    def __init__(self, name: str):
        self.name = name
        self.owner: Optional[int] = None
        self.clock = None                      # _VC, sized per controller
        self._ctl = None                       # which run the model state is for
        self._real = threading.Lock()          # wilkins: ignore[WLK305] -- unmanaged-thread fallback

    def _sync(self, c) -> None:
        """Reset the model state when a NEW controller touches this object.

        Module-level locks (transport stats, plan cache) outlive a single
        exploration run; an aborted schedule may have unwound mid-critical-
        section leaving a stale ``owner``, and the vector clock is sized to
        the run's thread count.  Only managed threads of the *current* run
        can genuinely hold a model lock, so resetting on controller change
        is always sound."""
        if self._ctl is not c:
            from .control import _VC
            self._ctl = c
            self.clock = _VC(len(c.threads))
            self.owner = None

    def acquire(self, blocking: bool = True,
                timeout: Optional[float] = None) -> bool:
        c = _controller_for(self)
        if c is None:
            if timeout is not None and timeout >= 0:
                return self._real.acquire(blocking, timeout)
            return self._real.acquire(blocking)
        self._sync(c)
        return c.lock_acquire(self, blocking=blocking, timeout=timeout)

    def release(self) -> None:
        c = _controller_for(self)
        if c is None:
            self._real.release()
            return
        self._sync(c)
        c.lock_release(self)

    def locked(self) -> bool:
        c = _controller_for(self)
        if c is None:
            return self._real.locked()
        return self.owner is not None

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


class ExploreCondition:
    """Model condition variable over an embedded :class:`ExploreLock`.

    ``notify`` does NOT require the lock to be held (see
    :meth:`Controller.cv_notify`): the model permits -- and therefore can
    expose -- the notify-outside-lock lost-wakeup hazard that real
    ``threading.Condition`` turns into a hard error.
    """

    def __init__(self, name: str):
        self.name = name
        self._lk = ExploreLock(name)
        self.waiters: List[int] = []
        self._real = threading.Condition()     # wilkins: ignore[WLK305] -- unmanaged-thread fallback

    def _sync(self, c) -> None:
        if self._lk._ctl is not c:
            self._lk._sync(c)
            self.waiters.clear()

    def acquire(self, blocking: bool = True,
                timeout: Optional[float] = None) -> bool:
        c = _controller_for(self)
        if c is None:
            return self._real.acquire(blocking) if timeout is None \
                else self._real.acquire(blocking, timeout)
        self._sync(c)
        return self._lk.acquire(blocking=blocking, timeout=timeout)

    def release(self) -> None:
        c = _controller_for(self)
        if c is None:
            self._real.release()
            return
        self._lk.release()

    def wait(self, timeout: Optional[float] = None) -> bool:
        c = _controller_for(self)
        if c is None:
            return self._real.wait(timeout)
        self._sync(c)
        return c.cv_wait(self, timeout=timeout)

    def wait_for(self, predicate, timeout: Optional[float] = None) -> bool:
        c = _controller_for(self)
        if c is None:
            return self._real.wait_for(predicate, timeout)
        while not predicate():
            if not self.wait(timeout):
                return predicate()
        return True

    def notify(self, n: int = 1) -> None:
        c = _controller_for(self)
        if c is None:
            with self._real_held_guard():
                self._real.notify(n)
            return
        self._sync(c)
        c.cv_notify(self, n)

    def notify_all(self) -> None:
        c = _controller_for(self)
        if c is None:
            with self._real_held_guard():
                self._real.notify_all()
            return
        self._sync(c)
        c.cv_notify(self, -1)

    def _real_held_guard(self):
        # threading.Condition.notify requires the lock; unmanaged callers
        # are expected to hold it already (core always does), so this is a
        # no-op guard kept for symmetry / future diagnostics.
        class _Noop:
            def __enter__(self_inner): return self_inner
            def __exit__(self_inner, *exc): return False
        return _Noop()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


class ExploreSemaphore:
    """Model counting semaphore: ``permits`` is the available count."""

    def __init__(self, name: str, value: int = 1):
        self.name = name
        self.permits = int(value)
        self._value0 = int(value)
        self.clock = None
        self._ctl = None
        self._real = threading.Semaphore(value)  # wilkins: ignore[WLK305] -- unmanaged-thread fallback

    def _sync(self, c) -> None:
        if self._ctl is not c:
            from .control import _VC
            self._ctl = c
            self.clock = _VC(len(c.threads))
            self.permits = self._value0

    def acquire(self, blocking: bool = True,
                timeout: Optional[float] = None) -> bool:
        c = _controller_for(self)
        if c is None:
            return self._real.acquire(blocking, timeout)
        self._sync(c)
        return c.sem_acquire(self, blocking=blocking, timeout=timeout)

    def release(self, n: int = 1) -> None:
        c = _controller_for(self)
        if c is None:
            self._real.release(n)
            return
        self._sync(c)
        c.sem_release(self, n)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


class TrackedCell:
    """A scalar shared variable whose reads/writes feed the race detector.

    Scenario and fixture code uses this to model an unprotected (or
    mis-protected) field: each access is a yield point tagged with the
    cell's identity and an access mode, so the controller both interleaves
    around it and runs the shadow-state happens-before check on it.
    """

    __slots__ = ("name", "_value")

    def __init__(self, name: str, value: Any = 0):
        self.name = name
        self._value = value

    def read(self) -> Any:
        lockcheck.sched_point(f"cell:{self.name}",
                              key=("cell", id(self)), access="r")
        return self._value

    def write(self, value: Any) -> None:
        lockcheck.sched_point(f"cell:{self.name}",
                              key=("cell", id(self)), access="w")
        self._value = value

    def add(self, delta: Any) -> Any:
        """A deliberately torn read-modify-write: read, yield, write."""
        v = self.read()
        v = v + delta
        self.write(v)
        return v
