"""The explorable-scenario corpus: small fixed workflows over REAL core
objects (``Channel``, ``Dataset``, ``ResizableSemaphore``), each asserting
its protocol invariant inside the thread bodies.

These are the *clean* scenarios: bounded exploration must complete with
zero WLK3xx findings (the CI ``explore`` job and
``tests/test_explore.py`` gate exactly that).  The seeded-race corpus --
the same shapes with the historical bugs re-introduced -- lives in
``tests/analysis_fixtures/races/``.

Each entry in :data:`CORPUS` is a zero-argument *builder* returning the
``[(name, fn), ...]`` thread bodies closed over freshly constructed shared
state, so every enumerated schedule starts from an identical world.
Builders keep prefetch OFF (``prefetch=0`` is the Channel default without
a RedistSpec): pool workers are daemon threads the controller does not
manage, and the corpus targets the *protocol* interleavings, not the
executor.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from .. import lockcheck

__all__ = ["CORPUS", "build_scenario", "names"]


def _mk_channel(io_freq: int = 1, queue_depth: int = 1):
    from ...core.channel import Channel
    return Channel(
        name="p[0]->c[0]:out.h5",
        producer=("p", 0),
        consumer=("c", 0),
        filename_pattern="out.h5",
        dset_patterns=["/data"],
        io_freq=io_freq,
        queue_depth=queue_depth,
        prefetch=0,
        record_events=False,
    )


def _mk_file(step: int):
    from ...core.datamodel import File
    f = File("out.h5")
    f.create_dataset("/data", data=np.full(4, step, dtype=np.int32))
    return f


def _payload_value(f) -> int:
    return int(f["/data"][0])


# ---------------------------------------------------------------------------
# scenario builders
# ---------------------------------------------------------------------------
def rendezvous_depth1() -> Sequence[Tuple[str, Callable[[], None]]]:
    """Depth-1 rendezvous (``io_freq: all``): in-order exactly-once
    delivery of 3 steps, then a clean producer-done."""
    ch = _mk_channel(io_freq=1, queue_depth=1)
    got: List[int] = []

    def producer():
        for step in range(3):
            assert ch.offer(_mk_file(step)), f"serve of step {step} refused"
        ch.finish()

    def consumer():
        while True:
            f = ch.get()
            if f is None:
                break
            got.append(_payload_value(f))
        assert got == [0, 1, 2], f"lost/duplicated/reordered delivery: {got}"

    return [("producer", producer), ("consumer", consumer)]


def latest_fanin() -> Sequence[Tuple[str, Callable[[], None]]]:
    """``latest`` flow control: serves happen only into a waiting consumer,
    so whatever arrives is fresh -- delivered steps must be strictly
    increasing and nothing may deadlock, on EVERY schedule (whether the
    producer saw the waiter or skipped is schedule-dependent by design)."""
    ch = _mk_channel(io_freq=-1, queue_depth=1)
    got: List[int] = []

    def producer():
        for step in range(3):
            ch.offer(_mk_file(step))
        ch.finish()

    def consumer():
        while True:
            f = ch.get()
            if f is None:
                break
            got.append(_payload_value(f))
        assert got == sorted(set(got)), \
            f"`latest` delivered stale or duplicate steps: {got}"
        assert all(0 <= s <= 2 for s in got), f"unknown step in {got}"

    return [("producer", producer), ("consumer", consumer)]


def crash_replay() -> Sequence[Tuple[str, Callable[[], None]]]:
    """Producer crash replay (PR 6): quarantine rewinds the serve counters
    to the last ack and the restarted incarnation re-serves; the seq-dedup
    watermark must give the consumer each step exactly once, no matter how
    far it had drained before the crash."""
    ch = _mk_channel(io_freq=1, queue_depth=4)
    got: List[int] = []

    def producer():
        for step in (0, 1):
            ch.offer(_mk_file(step))
        # crash here: nothing acked, so the restart replays from step 0.
        # Depending on the schedule the consumer drained 0, 1, or 2 items
        # already -- the dedup watermark must absorb every case.
        ch.quarantine_producer(epoch=1)
        for step in (0, 1, 2):
            ch.offer(_mk_file(step))
        ch.finish()

    def consumer():
        while True:
            f = ch.get()
            if f is None:
                break
            got.append(_payload_value(f))
        assert got == [0, 1, 2], \
            f"replay broke exactly-once delivery: {got}"

    return [("producer", producer), ("consumer", consumer)]


def rescale_window() -> Sequence[Tuple[str, Callable[[], None]]]:
    """The rescale surgery window (PR 7): grace-release a retiring channel
    while its producer may be parked in the rendezvous, snapshot it, adopt
    the counters onto a fresh channel, preload the undelivered steps, and
    let the new consumer drain -- every undelivered step must arrive on the
    new edge exactly once, whatever the producer/surgeon interleaving."""
    old = _mk_channel(io_freq=1, queue_depth=1)
    new = _mk_channel(io_freq=1, queue_depth=4)
    got: List[int] = []

    def producer():
        for step in (0, 1):
            old.offer(_mk_file(step))  # step 1 may park in the rendezvous
                                       # until the surgeon's grace release

    def surgeon():
        old.rescale_release_producer()
        snap = old.rescale_snapshot()
        new.rescale_adopt(
            serve_seq=snap["serve_seq"], acked_seq=snap["acked_seq"],
            close_count=snap["close_count"],
            acked_close_count=snap["acked_close_count"],
            done=snap["done"], epoch=2,
            delivered_floor=snap["delivered_seq"])
        for kind, payload, seq, _epoch, _src in snap["items"]:
            assert kind == "memory", kind
            new.rescale_preload(payload, seq)
        new.finish()

    def consumer():
        while True:
            f = new.get()
            if f is None:
                break
            got.append(_payload_value(f))
        # the surgeon snapshots whatever the producer managed to queue
        # before the grace release landed: a prefix of the steps, in order
        assert got == list(range(len(got))), \
            f"surgery lost or duplicated queued steps: {got}"

    return [("producer", producer), ("surgeon", surgeon),
            ("consumer", consumer)]


def traced_rendezvous() -> Sequence[Tuple[str, Callable[[], None]]]:
    """The depth-1 rendezvous with a ``SpanRecorder`` attached (PR 10):
    tracing hooks run inside ``offer``/``get`` while the channel lock is
    held, so exploration must show the obs shard locks introduce no new
    races or lock-order edges (they are ``leaf`` rank, innermost), and
    every delivered step must leave exactly one offer + one get span."""
    from ...obs.recorder import SpanRecorder, TraceConfig
    ch = _mk_channel(io_freq=1, queue_depth=1)
    rec = SpanRecorder(TraceConfig(shards=2, flight_len=32))
    ch.set_tracer(rec)
    got: List[int] = []

    def producer():
        for step in range(3):
            assert ch.offer(_mk_file(step)), f"serve of step {step} refused"
        ch.finish()

    def consumer():
        while True:
            f = ch.get()
            if f is None:
                break
            got.append(_payload_value(f))
        assert got == [0, 1, 2], f"lost/duplicated/reordered delivery: {got}"
        spans = rec.spans()
        offers = [s for s in spans if s["name"] == "channel.offer"
                  and not (s["args"] or {}).get("aborted")]
        gets = [s for s in spans if s["name"] == "channel.get"
                and not (s["args"] or {}).get("aborted")]
        assert len(offers) == 3 and len(gets) == 3, \
            f"span count mismatch: {len(offers)} offers, {len(gets)} gets"
        assert all(s["flow"][0] == "s" for s in offers) and \
               all(s["flow"][0] == "f" for s in gets) and \
               {s["flow"][1] for s in offers} == {g["flow"][1] for g in gets}, \
            "offer/get flow ids do not pair up"

    return [("producer", producer), ("consumer", consumer)]


def sem_resize() -> Sequence[Tuple[str, Callable[[], None]]]:
    """``ResizableSemaphore.resize`` shrink racing a concurrent
    ``release`` (satellite audit): the in-use gauge must return to zero,
    no release may error, and nobody may deadlock on any interleaving."""
    from ...core.scheduler import ResizableSemaphore
    sem = ResizableSemaphore(2, name="channel.sem:scenario")

    def worker():
        assert sem.acquire(), "acquire with free permits returned False"
        lockcheck.sched_point("sem_resize.hold", key=("sem-user", id(sem)))
        sem.release()

    def resizer():
        sem.resize(1)
        lockcheck.sched_point("sem_resize.shrunk", key=("sem-user", id(sem)))
        sem.resize(2)

    def check():
        # runs last under the default schedule; under preempted schedules
        # the final decide() still only lets it finish when runnable, and
        # acquire() blocks until both workers are out
        assert sem.acquire(), "acquire after drain returned False"
        sem.release()

    return [("worker-a", worker), ("worker-b", worker),
            ("resizer", resizer), ("checker", check)]


def cow_share() -> Sequence[Tuple[str, Callable[[], None]]]:
    """CoW hand-off (PR 3 protocol, unbroken): a reader holding a view and
    a writer mutating the source must never touch one buffer unordered --
    the writer's first write materializes a private copy, so the shadow-
    state checker over the real ``Dataset`` buffers must stay silent."""
    from ...core.datamodel import File
    base = File("out.h5")
    ds = base.create_dataset("/data", data=np.zeros(4, dtype=np.int64))
    view = ds.view()

    def writer():
        ds[0] = 7          # CoW: copies before the tracked write lands
        assert int(ds.read_direct()[0]) == 7

    def reader():
        arr = view.read_direct()
        total = int(arr.sum())
        assert total == 0, f"reader saw a torn value: {total}"
        assert int(view.read_direct()[0]) == 0, \
            "view observed the writer's private copy"

    return [("writer", writer), ("reader", reader)]


CORPUS: Dict[str, Callable[[], Sequence[Tuple[str, Callable[[], None]]]]] = {
    "rendezvous_depth1": rendezvous_depth1,
    "latest_fanin": latest_fanin,
    "crash_replay": crash_replay,
    "traced_rendezvous": traced_rendezvous,
    "rescale_window": rescale_window,
    "sem_resize": sem_resize,
    "cow_share": cow_share,
}


def names() -> List[str]:
    return list(CORPUS)


def build_scenario(name: str):
    try:
        return CORPUS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; known: {', '.join(CORPUS)}")
