"""The single validation-rule registry for workflow descriptions.

Every parse-time legality rule lives HERE, once: ``core.graph`` calls in at
YAML parse time (raising on the first violation, exactly as before), the
driver calls in for programmatic ``RunSupervisor.rescale`` triggers, and
``analysis.workflow`` calls in per-field to *collect* every violation as a
diagnostic.  Before this module the same rules lived as three drifting
copies across ``graph.py`` and ``driver.py``.

Rules raise :class:`WorkflowValidationError` -- a ``ValueError`` subclass
carrying the stable diagnostic ``code`` plus the task/port the message
names, so existing callers (and every test asserting on message text) see
byte-identical errors while the analyzer gets structured locations for
free.

This module imports nothing from ``repro.core``: ports are validated into
plain kwarg dicts (the graph builds its ``Port`` dataclass from them) and
task/graph objects are duck-typed, so ``graph.py`` and ``driver.py`` can
both import it without cycles.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

__all__ = ["WorkflowValidationError", "validated_port", "validated_actions",
           "validated_stall_timeout", "check_task", "check_workflow_doc",
           "check_duplicate_names", "validate_rescale_target",
           "validate_rescale_request"]


class WorkflowValidationError(ValueError):
    """A workflow-description rule violation.

    A plain ``ValueError`` to every pre-existing caller; the diagnostic
    ``code`` and the task/port anchors ride along for the analyzer."""

    def __init__(self, message: str, *, code: str = "WLK100",
                 task: Optional[str] = None, port: Optional[str] = None,
                 key: Optional[str] = None):
        super().__init__(message)
        self.code = code
        self.task = task
        self.port = port
        #: the YAML key the rule rejected (``queue_depth``, ``io_freq``...)
        #: -- lets the analyzer anchor the finding at the knob's own line
        self.key = key


def _err(message: str, code: str, task: Optional[str] = None,
         port: Optional[str] = None, key: Optional[str] = None
         ) -> WorkflowValidationError:
    return WorkflowValidationError(message, code=code, task=task, port=port,
                                   key=key)


# ---------------------------------------------------------------------------
# document structure
# ---------------------------------------------------------------------------
def check_workflow_doc(doc: Any) -> None:
    if not isinstance(doc, dict) or "tasks" not in doc:
        raise _err("workflow YAML must have a top-level 'tasks' list",
                   "WLK002")


def check_duplicate_names(names: List[str]) -> None:
    if len(set(names)) != len(names):
        raise _err(f"duplicate task func names: {names}", "WLK116")


# ---------------------------------------------------------------------------
# port-level legality (the old graph._parse_port body)
# ---------------------------------------------------------------------------
def validated_port(p: Dict[str, Any], task: str = "?") -> Dict[str, Any]:
    """Validate one inport/outport mapping and return the ``Port`` kwargs.

    ``dsets`` comes back as ``(name, file, memory)`` tuples -- the caller
    owns the dataclass."""
    dsets = [
        (d["name"],
         int(d.get("file", 0) or 0),
         int(d.get("memory", 0) or 0) if "memory" in d or "file" in d else 1)
        for d in p.get("dsets", [])
    ]
    if not dsets:
        dsets = [("*", 0, 1)]
    qd = int(p.get("queue_depth", 1))
    if qd < 1:
        raise _err(f"queue_depth must be >= 1, got {qd}",
                   "WLK101", task, p.get("filename"), key="queue_depth")
    # Flow control is validated HERE, with the task and port named -- by the
    # time a bad value used to reach FlowControl.from_io_freq (at channel
    # construction, deep inside the driver) the error no longer said which
    # YAML line to fix, and a typo'd -2 read like a runtime bug.
    io_freq = int(p.get("io_freq", 1))
    if io_freq < -1:
        raise _err(
            f"task {task!r} port {p['filename']!r}: io_freq {io_freq} is "
            f"invalid; use 0/1 (all), N>1 (some: every Nth step), or -1 "
            f"(latest)", "WLK102", task, p.get("filename"), key="io_freq")
    # ``redistribute: 1`` or ``redistribute: {axis: A}`` on a consumer inport
    redist = p.get("redistribute", 0)
    axis = 0
    if isinstance(redist, dict):
        axis = int(redist.get("axis", 0))
        redist = True
    else:
        redist = bool(int(redist or 0))
    if axis < 0:
        raise _err(f"redistribute axis must be >= 0, got {axis}",
                   "WLK103", task, p.get("filename"), key="redistribute")
    # ``prefetch: N`` on a consumer inport: per-edge async-prep depth
    # (0 = synchronous serve, N >= 1 = at most N in-flight preps per
    # channel).  YAML booleans pass through untouched so the legacy
    # ``prefetch: true`` spelling keeps meaning "default depth", not 1.
    prefetch = p.get("prefetch")
    if prefetch is not None and not isinstance(prefetch, bool):
        prefetch = int(prefetch)
        if prefetch < 0:
            raise _err(
                f"task {task!r} port {p['filename']!r}: prefetch depth must "
                f"be >= 0 (0 = sync serve, N = per-edge depth), got {prefetch}",
                "WLK104", task, p.get("filename"), key="prefetch")
    # ``weight: N`` on a consumer inport: this port's DWRR share under the
    # top-level ``scheduler: {policy: fair}`` arbitration
    weight = int(p.get("weight", 1))
    if weight < 1:
        raise _err(
            f"task {task!r} port {p['filename']!r}: scheduler weight must be "
            f">= 1, got {weight}", "WLK105", task, p.get("filename"), key="weight")
    # ``autotune: 1`` / ``autotune: N`` / ``autotune: {min: A, max: B}`` on a
    # consumer inport: runtime prefetch-depth bounds for the autotuner.
    # Spellings: 1/true -> default bounds [1, 8]; an int N >= 2 -> [1, N];
    # a mapping sets both ends.  min >= 1 always (a zero-depth autotuned
    # edge could park a producer forever on an unpassable semaphore; use
    # ``prefetch: 0`` to disable prefetch instead).
    at = p.get("autotune", None)
    autotune: Optional[Tuple[int, int]] = None
    if isinstance(at, dict):
        unknown = set(at) - {"min", "max"}
        if unknown:
            raise _err(
                f"task {task!r} port {p['filename']!r}: unknown autotune keys "
                f"{sorted(unknown)} (expected min, max)",
                "WLK106", task, p.get("filename"), key="autotune")
        bounds = {}
        for key, default in (("min", 1), ("max", 8)):
            val = at.get(key, default)
            if isinstance(val, bool) or not isinstance(val, int):
                raise _err(
                    f"task {task!r} port {p['filename']!r}: autotune {key} "
                    f"must be an integer depth, got {val!r}",
                    "WLK106", task, p.get("filename"), key="autotune")
            bounds[key] = val
        autotune = (bounds["min"], bounds["max"])
    elif at is not None and at is not False and at != 0:
        if at is True or at == 1:
            autotune = (1, 8)
        elif isinstance(at, int) and at >= 2:
            autotune = (1, at)
        else:
            raise _err(
                f"task {task!r} port {p['filename']!r}: autotune must be "
                f"1/true, a max depth >= 2, or {{min, max}}, got {at!r}",
                "WLK106", task, p.get("filename"), key="autotune")
    if autotune is not None:
        amin, amax = autotune
        if amin < 1:
            raise _err(
                f"task {task!r} port {p['filename']!r}: autotune min must be "
                f">= 1, got {amin} (use prefetch: 0 to disable prefetch)",
                "WLK106", task, p.get("filename"), key="autotune")
        if amax < amin:
            raise _err(
                f"task {task!r} port {p['filename']!r}: autotune bounds must "
                f"satisfy min <= max, got [{amin}, {amax}]",
                "WLK106", task, p.get("filename"), key="autotune")
    # ``ownership: 1`` or ``ownership: {axis: A, nranks: K}`` on an outport
    own = p.get("ownership", 0)
    own_axis, own_nranks = 0, None
    if isinstance(own, dict):
        unknown = set(own) - {"axis", "nranks"}
        if unknown:
            raise _err(
                f"port {p['filename']!r}: unknown ownership keys {sorted(unknown)} "
                f"(expected axis, nranks)", "WLK107", task, p.get("filename"), key="ownership")
        own_axis = int(own.get("axis", 0))
        if "nranks" in own:
            own_nranks = int(own["nranks"])
        own = True
    else:
        own = bool(int(own or 0))
    if own_axis < 0:
        raise _err(
            f"port {p['filename']!r}: ownership axis must be >= 0, got {own_axis}",
            "WLK107", task, p.get("filename"), key="ownership")
    if own_nranks is not None and own_nranks < 1:
        raise _err(
            f"port {p['filename']!r}: ownership nranks must be >= 1, got {own_nranks}",
            "WLK107", task, p.get("filename"), key="ownership")
    return dict(filename=p["filename"], dsets=dsets,
                io_freq=io_freq, queue_depth=qd,
                redistribute=redist, redist_axis=axis, prefetch=prefetch,
                weight=weight, autotune=autotune,
                ownership=own, own_axis=own_axis, own_nranks=own_nranks)


# ---------------------------------------------------------------------------
# task-level legality (the old graph._parse_task checks)
# ---------------------------------------------------------------------------
def validated_actions(actions: Any) -> Optional[Tuple[str, str]]:
    if actions is None:
        return None
    if not (isinstance(actions, (list, tuple)) and len(actions) == 2):
        raise _err(f"actions must be [script, function], got {actions!r}",
                   "WLK115", key="actions")
    return (str(actions[0]), str(actions[1]))


def validated_stall_timeout(t: Dict[str, Any]) -> Optional[float]:
    stall = t.get("stall_timeout_s")
    if stall is None:
        return None
    try:
        stall = float(stall)
    except (TypeError, ValueError):
        raise _err(
            f"task {t['func']!r}: stall_timeout_s must be a number of "
            f"seconds, got {t['stall_timeout_s']!r}",
            "WLK111", t.get("func"), key="stall_timeout_s") from None
    if stall <= 0:
        raise _err(
            f"task {t['func']!r}: stall_timeout_s must be > 0, got "
            f"{stall} (omit the key to disable the watchdog)",
            "WLK111", t.get("func"), key="stall_timeout_s")
    return stall


def check_task(spec: Any) -> None:
    """Cross-field legality of a parsed task spec (duck-typed: needs
    ``func``/``nprocs``/``io_procs``/``inports``/``outports``/
    ``on_failure``/``stall_timeout_s``).  Raises on the FIRST violation, in
    the same order the old inline checks ran."""
    for p in spec.inports:
        if p.ownership:
            raise _err(
                f"task {spec.func!r}: ownership is an outport declaration "
                f"(inport {p.filename!r} declared it); use redistribute: on "
                f"inports", "WLK108", spec.func, p.filename)
    for p in spec.inports:
        if p.autotune is not None and p.prefetch == 0:
            raise _err(
                f"task {spec.func!r} inport {p.filename!r}: autotune needs "
                f"prefetch enabled, but the port declares prefetch: 0; drop "
                f"one of the two", "WLK109", spec.func, p.filename)
    for p in spec.outports:
        if p.prefetch is not None:
            raise _err(
                f"task {spec.func!r}: prefetch is an inport declaration "
                f"(outport {p.filename!r} declared it); it rides the "
                f"consumer's redistribute port", "WLK108", spec.func,
                p.filename)
        if p.weight != 1:
            raise _err(
                f"task {spec.func!r}: weight is an inport declaration "
                f"(outport {p.filename!r} declared it); the fair scheduler "
                f"arbitrates consumer edges", "WLK108", spec.func, p.filename)
        if p.autotune is not None:
            raise _err(
                f"task {spec.func!r}: autotune is an inport declaration "
                f"(outport {p.filename!r} declared it); depth is a consumer-"
                f"edge property", "WLK108", spec.func, p.filename)
        if p.own_nranks is not None and p.own_nranks not in (
                spec.nprocs, spec.io_procs):
            raise _err(
                f"task {spec.func!r} outport {p.filename!r}: ownership nranks "
                f"{p.own_nranks} matches neither nprocs={spec.nprocs} nor "
                f"nwriters={spec.io_procs}", "WLK110", spec.func, p.filename)
    if spec.stall_timeout_s is not None:
        # The watchdog turns "no heartbeat" into a *policy application*; on
        # an unmanaged task there is no policy to apply, and restart-on-stall
        # is rejected too (a stalled-but-alive incarnation would keep serving
        # into channels its restarted twin also serves -- rescale fences the
        # old incarnation under a new generation, restart does not).
        pol = spec.on_failure
        managed = (pol.kind == "drop"
                   or (pol.kind == "rescale" and pol.nslots is not None))
        if not managed:
            raise _err(
                f"task {spec.func!r}: stall_timeout_s requires a managed "
                f"on_failure policy that can fence the stalled incarnation "
                f"-- rescale: {{nslots: N}} or drop: -- but the task "
                f"declares {pol.kind!r}", "WLK112", spec.func)


# ---------------------------------------------------------------------------
# elastic-rescale structural rules (parse-time AND programmatic triggers)
# ---------------------------------------------------------------------------
def validate_rescale_target(graph: Any, name: str) -> None:
    """Structural rules for resizing ``name``'s instance count.

    ``graph`` is duck-typed: a ``tasks`` mapping (specs with ``outports``/
    ``task_count``) plus ``producers_of(name)`` returning the inbound edges
    (``producer``/``mode``/``filename_pattern``/``io_freq``).  Used at parse
    time for declared ``on_failure: {rescale: ...}`` policies and again by
    the driver for programmatic ``RunSupervisor.rescale`` triggers."""
    t = graph.tasks[name]
    if t.outports:
        raise _err(
            f"task {name!r}: rescale: {{nslots: ...}} requires a "
            f"pure consumer (no outports) -- resizing a producer "
            f"would re-pair every downstream edge's round-robin "
            f"instance links mid-run; use rescale: {{nprocs: ...}} "
            f"to resize a producer's logical ranks instead", "WLK117", name)
    inbound = graph.producers_of(name)
    if not inbound:
        raise _err(
            f"task {name!r}: rescale: {{nslots: ...}} declared but "
            f"no inport edge matched -- an isolated task has no "
            f"channels to re-partition", "WLK117", name)
    for e in inbound:
        if graph.tasks[e.producer].task_count != 1:
            raise _err(
                f"task {name!r}: rescale: {{nslots: ...}} requires "
                f"every feeding producer to run a single instance, "
                f"but {e.producer!r} has taskCount="
                f"{graph.tasks[e.producer].task_count}", "WLK117", name)
        if e.mode != "memory":
            raise _err(
                f"task {name!r}: rescale: {{nslots: ...}} requires "
                f"memory transport on every inbound edge, but the "
                f"edge from {e.producer!r} ({e.filename_pattern!r}) "
                f"uses file mode", "WLK117", name)
        if e.io_freq == -1:
            raise _err(
                f"task {name!r}: rescale: {{nslots: ...}} cannot "
                f"combine with io_freq: -1 (latest) on the edge from "
                f"{e.producer!r} -- latest-mode step selection "
                f"depends on live consumer timing, so the replay "
                f"set is not deterministic across sizes", "WLK117", name)


def validate_rescale_request(graph: Any, task: str,
                             nslots: Optional[int] = None,
                             nprocs: Optional[int] = None) -> None:
    """Programmatic-trigger validation (``RunSupervisor.rescale`` / YAML-free
    callers): request-shape rules, then the same structural rules the graph
    enforces at parse time."""
    if task not in graph.tasks:
        raise _err(f"rescale: unknown task {task!r}", "WLK118", task)
    if nslots is None and nprocs is None:
        raise _err(
            f"rescale {task!r}: nothing to change -- give nslots "
            f"and/or nprocs", "WLK118", task)
    if nslots is not None and int(nslots) < 1:
        raise _err(
            f"rescale {task!r}: nslots must be >= 1, got {nslots}",
            "WLK118", task)
    if nprocs is not None and int(nprocs) < 1:
        raise _err(
            f"rescale {task!r}: nprocs must be >= 1, got {nprocs}",
            "WLK118", task)
    if nslots is not None:
        validate_rescale_target(graph, task)
