"""Pass 2 (static half): the AST lint enforcing the core lock discipline.

The transport's concurrency invariants used to live only in docstrings and
review memory.  This lint codifies them as checkable rules over
``src/repro/core/``:

* **WLK301** -- channel state (the ring queue, seq counters, epoch/poison/
  grace flags, waiter sets) is mutated only under the channel condition
  variable.  Methods whose names end in ``_locked`` declare
  caller-holds-lock and are exempt (the convention the lint enforces
  everywhere else makes the exemption auditable); ``__init__`` runs before
  the object is shared.
* **WLK302** -- ``Condition.wait`` only inside a ``while`` predicate loop:
  an ``if``-guarded wait misses spurious wakeups and missed-notify races.
* **WLK303** -- a wait loop that paces itself by the supervisor's
  ``wait_quantum`` must also ``heartbeat``: a parked-but-alive waiter that
  goes silent gets declared stalled by the watchdog and killed.
* **WLK304** -- ``stats`` counters are mutated only under a lock (or in
  ``_locked`` helpers); torn increments silently undercount.
* **WLK305** -- synchronization primitives are constructed through the
  ``make_lock``/``make_condition``/``make_semaphore`` factories in
  ``analysis.lockcheck``, never via ``threading.Lock()`` and friends
  directly: a raw primitive is invisible to the runtime lock-order
  recorder AND to the schedule explorer, so its interleavings are never
  checked.  The factories themselves (and the explore-mode fallbacks)
  carry line suppressions.

Suppress a finding with a ``# wilkins: ignore[WLK30x]`` comment on the
offending line -- the one legitimate use in-tree (``ChannelMux.wait``'s
if-guarded wait, whose callers rescan by design) documents why.
"""

from __future__ import annotations

import ast
import os
from typing import List, Optional

from .diagnostics import Diagnostic, Findings, Location, line_suppressions

__all__ = ["lint_file", "lint_paths", "PROTECTED_CHANNEL_STATE"]

#: Channel fields owned by the channel CV (the channel.py state block).
PROTECTED_CHANNEL_STATE = frozenset({
    "_queue", "_done", "_serve_seq", "_acked_seq", "_close_count",
    "_acked_close_count", "_delivered_seq", "_acked_delivered_seq",
    "_replay", "_replay_enabled", "_epoch", "_poison", "_abandoned",
    "_grace", "_retention", "_retained", "_interrupt", "_waiters",
})

#: attribute names that identify a condition-variable receiver for the
#: wait-in-while rule
CV_ATTRS = frozenset({"_lock", "_cond", "_cv"})

_MUTATORS = frozenset({"append", "appendleft", "pop", "popleft", "clear",
                       "extend", "add", "remove", "discard", "update",
                       "insert"})

#: constructors the make_* factories wrap; Event/Thread/Barrier stay legal
#: (they are signaling, not mutual exclusion -- nothing for the lock-order
#: recorder or the explorer to model)
_RAW_PRIMITIVES = frozenset({"Lock", "RLock", "Condition", "Semaphore",
                             "BoundedSemaphore"})

_FACTORY_FOR = {"Lock": "make_lock", "RLock": "make_lock",
                "Condition": "make_condition", "Semaphore": "make_semaphore",
                "BoundedSemaphore": "make_semaphore"}


def _ident(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def _is_lockish(expr: ast.AST) -> bool:
    """Does this with-item expression look like a lock/CV acquisition?"""
    if isinstance(expr, ast.Call):      # e.g. ``with self._lock:`` vs call
        expr = expr.func
    s = _ident(expr)
    if s is None:
        return False
    s = s.lower()
    return "lock" in s or "cond" in s or s in ("cv", "_cv", "sem", "_sem")


class _Linter(ast.NodeVisitor):
    def __init__(self, path: str):
        self.path = path
        self.findings: List[Diagnostic] = []
        self._func_stack: List[str] = []
        self._with_lock_depth = 0
        self._while_depth = 0
        # the shared-state rules (WLK301/304) only apply inside classes
        # that own a lock -- a single-threaded queue or a local stats dict
        # has no lock to hold
        self._class_owns_lock: List[bool] = []
        # local aliases bound by ``from threading import Lock [as L]``
        self._threading_aliases: dict = {}

    # ------------------------------------------------------------- helpers
    def _exempt(self) -> bool:
        """True inside a caller-holds-lock helper or a constructor."""
        return any(f.endswith("_locked") or f == "__init__"
                   for f in self._func_stack)

    def _add(self, code: str, message: str, node: ast.AST) -> None:
        self.findings.append(Diagnostic(code, message, Location(
            file=self.path, line=getattr(node, "lineno", None))))

    # -------------------------------------------------------------- scopes
    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._func_stack.append(node.name)
        saved = self._while_depth
        self._while_depth = 0
        self.generic_visit(node)
        self._while_depth = saved
        self._func_stack.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        owns = any(
            isinstance(n, ast.Attribute) and isinstance(n.ctx, ast.Store)
            and isinstance(n.value, ast.Name) and n.value.id == "self"
            and _is_lockish(n)
            for n in ast.walk(node))
        self._class_owns_lock.append(owns)
        self.generic_visit(node)
        self._class_owns_lock.pop()

    def _locked_domain(self) -> bool:
        return bool(self._class_owns_lock) and self._class_owns_lock[-1]

    def visit_With(self, node: ast.With) -> None:
        lockish = any(_is_lockish(item.context_expr) for item in node.items)
        if lockish:
            self._with_lock_depth += 1
        self.generic_visit(node)
        if lockish:
            self._with_lock_depth -= 1

    def visit_While(self, node: ast.While) -> None:
        self._while_depth += 1
        self._check_wait_loop_heartbeat(node)
        self.generic_visit(node)
        self._while_depth -= 1

    # --------------------------------------------------------------- rules
    def _check_wait_loop_heartbeat(self, node: ast.While) -> None:
        calls = [n.func.attr for n in ast.walk(node)
                 if isinstance(n, ast.Call)
                 and isinstance(n.func, ast.Attribute)]
        if "wait_quantum" in calls and "heartbeat" not in calls:
            self._add(
                "WLK303",
                "wait loop paces itself by the supervisor's wait_quantum "
                "but never calls heartbeat -- a parked-but-alive waiter "
                "will be declared stalled by the watchdog", node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "threading":
            for alias in node.names:
                if alias.name in _RAW_PRIMITIVES:
                    self._threading_aliases[alias.asname or alias.name] = \
                        alias.name
        self.generic_visit(node)

    def _check_raw_primitive(self, node: ast.Call) -> None:
        f = node.func
        prim = None
        if isinstance(f, ast.Attribute) and f.attr in _RAW_PRIMITIVES \
                and isinstance(f.value, ast.Name) \
                and f.value.id == "threading":
            prim = f.attr
        elif isinstance(f, ast.Name) and f.id in self._threading_aliases:
            prim = self._threading_aliases[f.id]
        if prim is not None:
            self._add(
                "WLK305",
                f"direct threading.{prim}() construction -- use "
                f"analysis.lockcheck.{_FACTORY_FOR[prim]}(name) so the "
                f"lock-order recorder and the schedule explorer can see "
                f"this primitive", node)

    def visit_Call(self, node: ast.Call) -> None:
        self._check_raw_primitive(node)
        f = node.func
        if isinstance(f, ast.Attribute):
            # WLK302: cv.wait(...) outside a while loop
            if f.attr in ("wait", "wait_for") \
                    and isinstance(f.value, ast.Attribute) \
                    and f.value.attr in CV_ATTRS:
                if self._while_depth == 0:
                    self._add(
                        "WLK302",
                        f"Condition.wait on {ast.unparse(f.value)} outside "
                        f"a while predicate loop -- spurious wakeups and "
                        f"missed notifies slip through an if-guard", node)
            # WLK301/304: mutating method calls on protected state
            if f.attr in _MUTATORS and not self._exempt() \
                    and self._with_lock_depth == 0 and self._locked_domain():
                tgt = f.value
                if isinstance(tgt, ast.Attribute) \
                        and isinstance(tgt.value, ast.Name) \
                        and tgt.value.id == "self" \
                        and tgt.attr in PROTECTED_CHANNEL_STATE:
                    self._add(
                        "WLK301",
                        f"channel state self.{tgt.attr}.{f.attr}(...) "
                        f"mutated outside the channel condition variable",
                        node)
                elif self._chain_has_stats(tgt):
                    self._add(
                        "WLK304",
                        f"stats field {ast.unparse(tgt)}.{f.attr}(...) "
                        f"mutated outside its owning lock", node)
        self.generic_visit(node)

    @staticmethod
    def _chain_has_stats(node: ast.AST) -> bool:
        while isinstance(node, ast.Attribute):
            if node.attr == "stats":
                return True
            node = node.value
        return isinstance(node, ast.Name) and node.id == "stats"

    def _check_store(self, target: ast.AST, node: ast.AST) -> None:
        if self._exempt() or self._with_lock_depth > 0 \
                or not self._locked_domain():
            return
        if isinstance(target, ast.Attribute):
            if isinstance(target.value, ast.Name) \
                    and target.value.id == "self" \
                    and target.attr in PROTECTED_CHANNEL_STATE:
                self._add(
                    "WLK301",
                    f"channel state self.{target.attr} assigned outside "
                    f"the channel condition variable", node)
            elif self._chain_has_stats(target.value):
                self._add(
                    "WLK304",
                    f"stats field {ast.unparse(target)} mutated outside "
                    f"its owning lock", node)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._check_store(elt, node)
        elif isinstance(target, ast.Subscript):
            self._check_store(target.value, node)

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._check_store(t, node)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_store(node.target, node)
        self.generic_visit(node)


def lint_file(path: str) -> Findings:
    with open(path) as f:
        source = f.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return Findings([Diagnostic(
            "WLK001", f"failed to parse {path}: {e}",
            Location(file=path, line=e.lineno))])
    linter = _Linter(path)
    linter.visit(tree)
    return Findings(linter.findings).suppress(
        by_line=line_suppressions(source))


def lint_paths(paths: List[str]) -> Findings:
    out = Findings()
    for p in paths:
        if os.path.isdir(p):
            for dirpath, _, names in sorted(os.walk(p)):
                for n in sorted(names):
                    if n.endswith(".py"):
                        out.extend(lint_file(os.path.join(dirpath, n)))
        elif p.endswith(".py"):
            out.extend(lint_file(p))
    return out
