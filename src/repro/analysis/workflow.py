"""Pass 1: the offline workflow-graph analyzer.

Builds the task/port/edge graph from a workflow YAML *without running it*
and collects every legality and hazard finding as diagnostics:

* **policy/schema legality** (WLK1xx) -- the same ``analysis.rules``
  registry ``core.graph`` enforces at parse time, but run per-field so one
  pass reports *every* violation instead of raising on the first;
* **graph shape** (WLK20x/21x) -- rendezvous deadlock cycles over
  ``io_freq: all`` + ``queue_depth: 1`` edges, self-feeding ports,
  unmatched memory inports, and flow-control hazards (strict/dropping
  mixes, latest x prefetch);
* **decomposition legality** (WLK22x) -- ``redistribute``/``ownership``
  axis vs the declared dataset rank, empty/uneven blocks, and the Pallas
  lane-width hint (the pack kernels tile 128 lanes; for flattened N-D
  plans the effective tile is ``tile_rows * inner``).

Rank/shape checks key on *optional* dataset hints the runtime ignores::

    dsets:
      - name: /particles
        rank: 3                 # or shape: [512, 64, 48]

Entry points: :func:`analyze_source` (YAML text), :func:`analyze_file`
(``.yaml`` or an example ``.py`` with an embedded ``WORKFLOW`` string).
"""

from __future__ import annotations

import math
import os
from typing import Any, Dict, List, Optional, Tuple

import yaml

from . import plancheck, rules
from .diagnostics import Diagnostic, Findings, Location, line_suppressions
from .rules import WorkflowValidationError

__all__ = ["analyze_source", "analyze_file", "analyze_doc", "load_workflows"]


# ---------------------------------------------------------------------------
# line-tracking YAML loader
# ---------------------------------------------------------------------------
class LineDict(dict):
    """A dict that remembers the 1-based YAML line of its mapping node (and
    of each scalar key) -- a plain dict to every consumer (iteration,
    unknown-key checks, ``**kwargs`` expansion all unchanged)."""

    line: Optional[int] = None
    key_lines: Optional[Dict[str, int]] = None


class _LineLoader(yaml.SafeLoader):
    pass


def _construct_mapping(loader, node):
    d = LineDict()
    d.line = node.start_mark.line + 1
    d.key_lines = {
        k.value: k.start_mark.line + 1 for k, _ in node.value
        if isinstance(getattr(k, "value", None), str)}
    yield d
    d.update(loader.construct_mapping(node, deep=True))


_LineLoader.add_constructor(
    yaml.resolver.BaseResolver.DEFAULT_MAPPING_TAG, _construct_mapping)


def _line(obj: Any) -> Optional[int]:
    return getattr(obj, "line", None)


def _key_line(obj: Any, key: Optional[str]) -> Optional[int]:
    """The 1-based line of ``key:`` inside mapping ``obj``, if tracked --
    findings anchor at the offending knob's own line, which is also where
    a ``# wilkins: ignore[...]`` comment must sit to suppress them."""
    kl = getattr(obj, "key_lines", None)
    if kl and key:
        return kl.get(key)
    return None


# ---------------------------------------------------------------------------
# per-document analysis
# ---------------------------------------------------------------------------
def analyze_source(text: str, filename: Optional[str] = None) -> Findings:
    """Analyze one workflow YAML document given as text."""
    try:
        doc = yaml.load(text, Loader=_LineLoader)
    except yaml.YAMLError as e:
        mark = getattr(e, "problem_mark", None)
        return Findings([Diagnostic(
            "WLK001", f"workflow YAML failed to parse: {e}",
            Location(file=filename,
                     line=mark.line + 1 if mark is not None else None))])
    findings = analyze_doc(doc, filename=filename)
    ignore: List[str] = []
    if isinstance(doc, dict):
        lint = doc.get("lint")
        if isinstance(lint, dict):
            ignore = [str(c) for c in lint.get("ignore", [])]
    return findings.suppress(codes=ignore, by_line=line_suppressions(text))


def analyze_file(path: str) -> Findings:
    """Analyze a ``.yaml``/``.yml`` workflow file, or every embedded
    ``WORKFLOW`` string of an example ``.py`` module."""
    if path.endswith(".py"):
        out = Findings()
        for name, text in load_workflows(path):
            out.extend(analyze_source(text, filename=f"{path}::{name}"))
        return out
    with open(path) as f:
        return analyze_source(f.read(), filename=path)


def load_workflows(py_path: str) -> List[Tuple[str, str]]:
    """Import a ``.py`` module and return its embedded workflow strings as
    ``(attr_name, yaml_text)`` -- module-level str attributes named
    ``*WORKFLOW*`` (the examples convention), so f-string workflows come
    back already formatted."""
    import importlib.util
    import sys
    mod_name = "_wilkins_check_" + os.path.splitext(
        os.path.basename(py_path))[0]
    spec = importlib.util.spec_from_file_location(mod_name, py_path)
    mod = importlib.util.module_from_spec(spec)
    argv = sys.argv
    sys.argv = [py_path]   # examples may read CLI args at import time
    try:
        spec.loader.exec_module(mod)
    finally:
        sys.argv = argv
    out = []
    for attr in sorted(dir(mod)):
        if "WORKFLOW" in attr and isinstance(getattr(mod, attr), str):
            out.append((attr, getattr(mod, attr)))
    return out


def analyze_doc(doc: Any, filename: Optional[str] = None) -> Findings:
    """Analyze an already-loaded workflow document (suppressions NOT
    applied -- :func:`analyze_source` owns those)."""
    findings = Findings()

    def add(code: str, message: str, line: Optional[int] = None,
            task: Optional[str] = None, port: Optional[str] = None) -> None:
        findings.add(Diagnostic(code, message, Location(
            file=filename, line=line, task=task, port=port)))

    def add_err(e: WorkflowValidationError, line: Optional[int] = None
                ) -> None:
        add(e.code, str(e), line=line, task=e.task, port=e.port)

    try:
        rules.check_workflow_doc(doc)
    except WorkflowValidationError as e:
        add_err(e, line=_line(doc) if isinstance(doc, dict) else None)
        return findings
    tasks_doc = doc["tasks"]
    if not isinstance(tasks_doc, list):
        add("WLK002", f"'tasks' must be a list, got {type(tasks_doc).__name__}",
            line=_line(doc))
        return findings

    from ..core.graph import WorkflowGraph, _parse_port
    from ..core.recovery import FailurePolicy
    from ..core.scheduler import SchedulerConfig
    from ..core.datamodel import match_file, match_path

    # ---- scheduler block (WLK114) -----------------------------------------
    scheduler = None
    try:
        scheduler = SchedulerConfig.from_yaml(doc.get("scheduler"))
    except ValueError as e:
        add("WLK114", str(e), line=_line(doc.get("scheduler")) or _line(doc))

    # ---- per-task schema/policy legality (WLK1xx), collected --------------
    specs = []            # TaskSpec for tasks that parsed fully
    port_lines: Dict[Tuple[str, str], Optional[int]] = {}
    task_lines: Dict[str, Optional[int]] = {}
    names: List[str] = []
    for t in tasks_doc:
        if not isinstance(t, dict) or "func" not in t:
            add("WLK002", f"task entry must be a mapping with a 'func' key, "
                f"got {t!r}", line=_line(t) if isinstance(t, dict) else None)
            continue
        name = str(t["func"])
        names.append(name)
        task_lines[name] = _line(t)
        broken = False
        inports, outports = [], []
        for side, dest in (("inports", inports), ("outports", outports)):
            for p in t.get(side, []) or []:
                pline = _line(p) or _line(t)
                if isinstance(p, dict) and "filename" in p:
                    port_lines[(name, str(p["filename"]))] = pline
                try:
                    dest.append(_parse_port(p, name))
                except WorkflowValidationError as e:
                    add_err(e, line=_key_line(p, e.key) or pline)
                    broken = True
                except (KeyError, TypeError, ValueError) as e:
                    add("WLK002", f"task {name!r}: malformed {side[:-1]} "
                        f"{p!r} ({e})", line=pline, task=name)
                    broken = True
        policy = FailurePolicy()
        try:
            policy = FailurePolicy.from_yaml(t.get("on_failure"), name)
        except ValueError as e:
            add("WLK113", str(e), line=_line(t), task=name)
            broken = True
        try:
            actions = rules.validated_actions(t.get("actions"))
        except WorkflowValidationError as e:
            add_err(e, line=_key_line(t, e.key) or _line(t))
            broken = True
            actions = None
        stall = None
        try:
            stall = rules.validated_stall_timeout(t)
        except WorkflowValidationError as e:
            add_err(e, line=_key_line(t, e.key) or _line(t))
            broken = True
        try:
            from ..core.graph import TaskSpec
            spec = TaskSpec(
                func=name,
                nprocs=int(t.get("nprocs", 1)),
                task_count=int(t.get("taskCount", 1)),
                nwriters=int(t["nwriters"]) if "nwriters" in t else (
                    int(t["io_proc"]) if "io_proc" in t else None),
                actions=actions, inports=inports, outports=outports,
                on_failure=policy, stall_timeout_s=stall, raw=dict(t))
        except (TypeError, ValueError) as e:
            add("WLK002", f"task {name!r}: malformed task entry ({e})",
                line=_line(t), task=name)
            continue
        try:
            rules.check_task(spec)
        except WorkflowValidationError as e:
            add_err(e, line=port_lines.get((name, e.port or ""), _line(t)))
            broken = True
        if not broken:
            specs.append(spec)

    try:
        rules.check_duplicate_names(names)
    except WorkflowValidationError as e:
        add_err(e, line=_line(doc))

    if not specs:
        return findings

    # ---- the graph, built without parse-time raising ----------------------
    graph = object.__new__(WorkflowGraph)
    graph.tasks = {s.func: s for s in specs}
    graph.scheduler = scheduler if scheduler is not None else SchedulerConfig()
    graph.edges = graph._match()

    def tloc(name: str) -> Optional[int]:
        return task_lines.get(name)

    def ploc(name: str, port: str) -> Optional[int]:
        return port_lines.get((name, port), task_lines.get(name))

    # declared rescale policies: structural rules (WLK117), collected
    for s in specs:
        pol = s.on_failure
        if pol.kind == "rescale" and pol.nslots is not None:
            try:
                rules.validate_rescale_target(graph, s.func)
            except WorkflowValidationError as e:
                add_err(e, line=tloc(s.func))

    _check_graph_shape(graph, add, tloc, ploc, match_file, match_path)
    _check_decomposition(graph, add, ploc)
    return findings


# ---------------------------------------------------------------------------
# graph-shape hazards (WLK20x / WLK21x)
# ---------------------------------------------------------------------------
def _strict(e) -> bool:
    """A rendezvous edge: every step is delivered and the ring holds one
    item, so the producer blocks until the consumer takes each step."""
    return e.io_freq in (0, 1) and e.queue_depth == 1


def _latest(e) -> bool:
    """Latest-mode sheds *rate-dependently*: it only drops when the
    producer outruns the consumer.  (some-mode, io_freq N>1, skips every
    Nth step deterministically at offer and is immune to pacing.)"""
    return e.io_freq == -1


def _sccs(nodes: List[str], succ: Dict[str, set]) -> List[List[str]]:
    """Tarjan's strongly connected components, iterative."""
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: set = set()
    stack: List[str] = []
    out: List[List[str]] = []
    counter = [0]

    for root in nodes:
        if root in index:
            continue
        work = [(root, iter(sorted(succ.get(root, ()))))]
        index[root] = low[root] = counter[0]
        counter[0] += 1
        stack.append(root)
        on_stack.add(root)
        while work:
            v, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(sorted(succ.get(w, ())))))
                    advanced = True
                    break
                if w in on_stack:
                    low[v] = min(low[v], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                pv = work[-1][0]
                low[pv] = min(low[pv], low[v])
            if low[v] == index[v]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.append(w)
                    if w == v:
                        break
                out.append(comp)
    return out


def _check_graph_shape(graph, add, tloc, ploc, match_file, match_path) -> None:
    succ: Dict[str, set] = {}
    for e in graph.edges:
        succ.setdefault(e.producer, set()).add(e.consumer)

    # WLK201 / WLK202: cycles.  A component whose every internal edge is a
    # rendezvous (all + depth-1) deadlocks at step 0: each producer blocks in
    # offer() until its consumer takes, and the consumer is itself parked
    # offering upstream.  With buffering the cycle survives until the rings
    # fill, then deadlocks the same way -- unless a latest-mode edge breaks
    # the blocking chain.
    for comp in _sccs(list(graph.tasks), succ):
        if len(comp) < 2:
            continue
        members = set(comp)
        internal = [e for e in graph.edges
                    if e.producer in members and e.consumer in members]
        path = "->".join(sorted(comp))
        if all(_strict(e) for e in internal):
            add("WLK201",
                f"tasks {sorted(comp)} form a rendezvous cycle: every edge "
                f"is io_freq: all with queue_depth: 1, so each producer "
                f"blocks in offer() until its consumer takes -- the cycle "
                f"deadlocks at the first step ({path})",
                line=tloc(sorted(comp)[0]), task=sorted(comp)[0])
        elif not any(e.io_freq == -1 for e in internal):
            add("WLK202",
                f"tasks {sorted(comp)} form a cycle over bounded queues "
                f"(no latest-mode edge to shed steps): the cycle deadlocks "
                f"once every ring fills ({path})",
                line=tloc(sorted(comp)[0]), task=sorted(comp)[0])

    # WLK203: an outport matching the task's own inport -- the matcher skips
    # self-edges, so the coupling the YAML appears to declare never exists.
    for name, t in graph.tasks.items():
        for outp in t.outports:
            for inp in t.inports:
                if not (match_file(inp.filename, outp.filename)
                        or match_file(outp.filename, inp.filename)):
                    continue
                if any(match_path(i.name, o.name) or match_path(o.name, i.name)
                       for i in inp.dsets for o in outp.dsets):
                    add("WLK203",
                        f"task {name!r}: outport {outp.filename!r} matches "
                        f"the task's own inport {inp.filename!r}; self-edges "
                        f"are ignored at runtime, so this coupling never "
                        f"exists (feed it through a second task or drop the "
                        f"port)", line=ploc(name, inp.filename), task=name,
                        port=inp.filename)

    # WLK204: a memory-mode inport no producer outport matched -- the
    # consumer's intercepted open waits for an in-situ file that no task
    # ever serves.  (File-mode dsets may legitimately read pre-existing
    # files from disk, so only all-memory ports are flagged.)
    for name, t in graph.tasks.items():
        for inp in t.inports:
            if any(d.mode != "memory" for d in inp.dsets):
                continue
            matched = any(e.consumer == name
                          and e.filename_pattern == inp.filename
                          for e in graph.edges)
            if not matched:
                add("WLK204",
                    f"task {name!r}: memory-mode inport {inp.filename!r} "
                    f"matched no producer outport; the consumer will wait "
                    f"forever for an in-situ file no task serves",
                    line=ploc(name, inp.filename), task=name,
                    port=inp.filename)

    # WLK210: fan-in mixing a strict rendezvous edge with a latest edge --
    # the strict edge rate-limits the consumer to its producer, so the
    # latest edge (declared to shed steps when THIS consumer lags) instead
    # sees a consumer that can never catch up to its own pace.
    for name in graph.tasks:
        inbound = graph.producers_of(name)
        stricts = [e for e in inbound if _strict(e)]
        drops = [e for e in inbound if _latest(e)]
        if stricts and drops:
            s, d = stricts[0], drops[0]
            add("WLK210",
                f"task {name!r}: fan-in mixes a strict rendezvous edge from "
                f"{s.producer!r} ({s.filename_pattern!r}) with a latest-mode "
                f"edge from {d.producer!r} ({d.filename_pattern!r}); the "
                f"strict edge paces the consumer, so the latest edge sheds "
                f"steps whenever {s.producer!r} is the slower producer "
                f"(pipeline the strict edge with queue_depth >= 2 if every "
                f"step from {d.producer!r} matters)",
                line=ploc(name, s.filename_pattern), task=name,
                port=s.filename_pattern)

    # WLK211: the mirror image on the producer side -- a producer feeding
    # both a strict rendezvous consumer and a latest consumer is paced by
    # the strict one, so the latest edge's never-block-the-producer intent
    # is defeated: the producer still blocks, on the strict sibling.
    for name in graph.tasks:
        outbound = graph.consumers_of(name)
        stricts = [e for e in outbound if _strict(e)]
        drops = [e for e in outbound if _latest(e)]
        if stricts and drops:
            s, d = stricts[0], drops[0]
            add("WLK211",
                f"task {name!r}: producer feeds a strict rendezvous edge to "
                f"{s.consumer!r} and a latest-mode edge to {d.consumer!r}; "
                f"the strict consumer paces the producer, so io_freq: -1's "
                f"never-block-the-producer intent is defeated (pipeline the "
                f"strict edge with queue_depth >= 2)",
                line=ploc(d.consumer, d.filename_pattern), task=name,
                port=d.filename_pattern)

    # WLK212: latest-mode x prefetch -- async preps are paid for steps the
    # consumer may never take, and an autotuner bumping depth amplifies it.
    for e in graph.edges:
        if e.io_freq == -1 and (e.autotune is not None
                                or (e.prefetch is not None
                                    and e.prefetch != 0)):
            knob = "autotune" if e.autotune is not None else "prefetch"
            add("WLK212",
                f"task {e.consumer!r} port {e.filename_pattern!r}: "
                f"io_freq: -1 (latest) with {knob} preps payloads for "
                f"steps the consumer may drop; prepped-but-dropped steps "
                f"waste pool slots and can starve sibling edges",
                line=ploc(e.consumer, e.filename_pattern), task=e.consumer,
                port=e.filename_pattern)


# ---------------------------------------------------------------------------
# decomposition legality (WLK22x) -- keyed on optional rank/shape dset hints
# ---------------------------------------------------------------------------
def _dset_hints(raw_port: Dict[str, Any]) -> List[Tuple[str, Optional[int],
                                                        Optional[tuple]]]:
    out = []
    for d in raw_port.get("dsets", []) or []:
        if not isinstance(d, dict):
            continue
        shape = d.get("shape")
        shape = tuple(int(x) for x in shape) if isinstance(
            shape, (list, tuple)) else None
        rank = d.get("rank")
        rank = int(rank) if rank is not None else (
            len(shape) if shape is not None else None)
        out.append((str(d.get("name", "*")), rank, shape))
    return out


def _check_decomposition(graph, add, ploc) -> None:
    for name, t in graph.tasks.items():
        # WLK223: subset writers beyond the rank count
        if t.nwriters is not None and t.nwriters > t.nprocs:
            add("WLK223",
                f"task {name!r}: nwriters {t.nwriters} exceeds nprocs "
                f"{t.nprocs}; only nprocs ranks exist to write",
                line=ploc(name, ""), task=name)
        for side, ports in (("inports", t.inports), ("outports", t.outports)):
            raw_ports = t.raw.get(side, []) or []
            for port, raw in zip(ports, raw_ports):
                if side == "inports" and port.redistribute:
                    axis, nranks, what = port.redist_axis, t.nprocs, \
                        "redistribute"
                elif side == "outports" and port.ownership:
                    axis, what = port.own_axis, "ownership"
                    nranks = port.own_nranks if port.own_nranks is not None \
                        else t.io_procs
                else:
                    continue
                if not isinstance(raw, dict):
                    continue
                for dname, rank, shape in _dset_hints(raw):
                    line = ploc(name, port.filename)
                    if rank is not None and axis >= rank:
                        add("WLK220",
                            f"task {name!r} port {port.filename!r}: "
                            f"{what} axis {axis} out of range for dataset "
                            f"{dname!r} with declared rank {rank}",
                            line=line, task=name, port=port.filename)
                        continue
                    if shape is None:
                        continue
                    if shape[axis] < nranks:
                        add("WLK221",
                            f"task {name!r} port {port.filename!r}: "
                            f"dataset {dname!r} extent {shape[axis]} along "
                            f"{what} axis {axis} is smaller than the "
                            f"{nranks}-rank decomposition -- some blocks "
                            f"will be empty",
                            line=line, task=name, port=port.filename)
                    elif shape[axis] % nranks != 0:
                        add("WLK224",
                            f"task {name!r} port {port.filename!r}: "
                            f"dataset {dname!r} extent {shape[axis]} along "
                            f"{what} axis {axis} is not divisible by the "
                            f"{nranks}-rank decomposition (uneven blocks)",
                            line=line, task=name, port=port.filename)
                    inner = math.prod(shape[axis + 1:]) if len(shape) > 1 \
                        else None
                    if inner is not None and inner % 128 != 0:
                        add("WLK222",
                            f"task {name!r} port {port.filename!r}: "
                            f"dataset {dname!r} flattened inner extent "
                            f"{inner} (shape {list(shape)} after axis "
                            f"{axis}) is not a 128-lane multiple; the pack "
                            f"kernel pads each tile_rows*{inner} tile to "
                            f"128 lanes",
                            line=line, task=name, port=port.filename)
                    # WLK225/226: prove the compiled reshard plan for this
                    # edge covers every destination element exactly once
                    # and never indexes out of bounds (plancheck)
                    if side == "inports" and port.redistribute:
                        for e in graph.producers_of(name):
                            if e.filename_pattern != port.filename:
                                continue
                            src_n = graph.tasks[e.producer].io_procs
                            for d in plancheck.verify_edge(
                                    shape, axis, src_n, nranks,
                                    context=(f"edge {e.producer}->{name}:"
                                             f"{port.filename} dataset "
                                             f"{dname!r}")):
                                add(d.code, d.message, line=line,
                                    task=name, port=port.filename)
