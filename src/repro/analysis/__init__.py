"""Pre-run static analysis for Wilkins workflows and the core transport.

Two passes over one diagnostics framework (`analysis.diagnostics`):

* ``analysis.workflow`` -- the offline workflow-graph analyzer
  (``python -m repro.analysis check workflow.yaml``): deadlock cycles,
  flow-control hazards, decomposition legality, policy legality.
* ``analysis.astlint`` + ``analysis.lockcheck`` -- the concurrency
  checker: an AST lint enforcing the codified lock discipline over
  ``src/repro/core/``, and an opt-in (``WILKINS_LOCKCHECK=1``) runtime
  recorder of the cross-thread lock-acquisition graph.

``analysis.rules`` is the shared validation registry ``core.graph`` and
the driver call into at parse time -- import it (or ``lockcheck``) freely
from core modules; submodules resolve lazily so pulling in the rule
registry never drags the analyzer (which itself imports ``core.graph``)
into the import cycle.
"""

from __future__ import annotations

import importlib

__all__ = ["rules", "diagnostics", "workflow", "astlint", "lockcheck", "cli"]


def __getattr__(name):
    if name in __all__:
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
