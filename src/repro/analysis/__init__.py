"""Pre-run static analysis for Wilkins workflows and the core transport.

Three passes over one diagnostics framework (`analysis.diagnostics`):

* ``analysis.workflow`` -- the offline workflow-graph analyzer
  (``python -m repro.analysis check workflow.yaml``): deadlock cycles,
  flow-control hazards, decomposition legality, policy legality, and
  (with dset ``shape:`` hints) reshard-plan coverage (``plancheck``).
* ``analysis.astlint`` + ``analysis.lockcheck`` -- the concurrency
  checker: an AST lint enforcing the codified lock discipline over
  ``src/repro/core/``, and an opt-in (``WILKINS_LOCKCHECK=1``) runtime
  recorder of the cross-thread lock-acquisition graph.
* ``analysis.explore`` -- the deterministic schedule explorer +
  happens-before race detector (``python -m repro.analysis explore``,
  ``WILKINS_EXPLORE=1``): CHESS-style bounded-preemption enumeration of
  thread interleavings over the transport/rescale protocols, with
  replayable schedule IDs for every finding.

``analysis.rules`` is the shared validation registry ``core.graph`` and
the driver call into at parse time -- import it (or ``lockcheck``) freely
from core modules; submodules resolve lazily so pulling in the rule
registry never drags the analyzer (which itself imports ``core.graph``)
into the import cycle.
"""

from __future__ import annotations

import importlib

__all__ = ["rules", "diagnostics", "workflow", "astlint", "lockcheck",
           "plancheck", "explore", "cli"]


def __getattr__(name):
    if name in __all__:
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
