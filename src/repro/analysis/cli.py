"""Command-line face of the analyzers.

::

    python -m repro.analysis check workflow.yaml [more.yaml examples/x.py]
    python -m repro.analysis lint src/repro/core [more paths]
    python -m repro.analysis codes

``check`` runs the workflow-graph analyzer (Pass 1) over YAML files or
example ``.py`` modules with embedded ``WORKFLOW`` strings; ``lint`` runs
the concurrency AST lint (Pass 2, static half).  Both print text findings
(or ``--json``) and exit non-zero when any error-severity finding
survives suppression -- warnings and infos never fail the run unless
``--strict``.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from .diagnostics import REGISTRY, Findings, Severity

__all__ = ["main"]


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Pre-run workflow analyzer and lock-discipline lint")
    sub = ap.add_subparsers(dest="cmd", required=True)

    ck = sub.add_parser("check", help="analyze workflow YAMLs / example "
                                      ".py modules without running them")
    ck.add_argument("files", nargs="+")
    ck.add_argument("--json", action="store_true")
    ck.add_argument("--strict", action="store_true",
                    help="exit non-zero on warnings too")

    ln = sub.add_parser("lint", help="AST lock-discipline lint over "
                                     "Python sources")
    ln.add_argument("paths", nargs="+")
    ln.add_argument("--json", action="store_true")
    ln.add_argument("--strict", action="store_true")

    sub.add_parser("codes", help="list every diagnostic code")

    args = ap.parse_args(argv)

    if args.cmd == "codes":
        for code, (sev, title) in sorted(REGISTRY.items()):
            print(f"{code}  {sev:<7}  {title}")
        return 0

    if args.cmd == "check":
        from .workflow import analyze_file
        findings = Findings()
        for f in args.files:
            findings.extend(analyze_file(f))
    else:
        from .astlint import lint_paths
        findings = lint_paths(args.paths)

    print(findings.render_json() if args.json else findings.render_text())
    if findings.errors():
        return 1
    if args.strict and any(d.severity == Severity.WARNING for d in findings):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
