"""Command-line face of the analyzers.

::

    python -m repro.analysis check workflow.yaml [more.yaml examples/x.py]
    python -m repro.analysis lint src/repro/core [more paths]
    python -m repro.analysis explore [--scenario NAME ...] [--budget N]
    python -m repro.analysis explore --scenario NAME --schedule ID
    python -m repro.analysis codes

``check`` runs the workflow-graph analyzer (Pass 1) over YAML files or
example ``.py`` modules with embedded ``WORKFLOW`` strings; ``lint`` runs
the concurrency AST lint (Pass 2, static half); ``explore`` runs the
deterministic schedule explorer (Pass 3) over the clean-scenario corpus
-- or replays one schedule ID from a previous finding.  All print text
findings (or ``--json``) and exit non-zero when any error-severity
finding survives suppression -- warnings and infos never fail the run
unless ``--strict``.
"""

from __future__ import annotations

import argparse
import os
import sys
from typing import List, Optional

from .diagnostics import REGISTRY, Findings, Severity

__all__ = ["main"]


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Pre-run workflow analyzer and lock-discipline lint")
    sub = ap.add_subparsers(dest="cmd", required=True)

    ck = sub.add_parser("check", help="analyze workflow YAMLs / example "
                                      ".py modules without running them")
    ck.add_argument("files", nargs="+")
    ck.add_argument("--json", action="store_true")
    ck.add_argument("--strict", action="store_true",
                    help="exit non-zero on warnings too")

    ln = sub.add_parser("lint", help="AST lock-discipline lint over "
                                     "Python sources")
    ln.add_argument("paths", nargs="+")
    ln.add_argument("--json", action="store_true")
    ln.add_argument("--strict", action="store_true")

    ex = sub.add_parser("explore", help="enumerate thread schedules over "
                                        "the protocol scenario corpus")
    ex.add_argument("--scenario", action="append", default=None,
                    metavar="NAME", help="scenario(s) to explore "
                    "(default: the whole corpus); see --list")
    ex.add_argument("--list", action="store_true",
                    help="list the scenario corpus and exit")
    ex.add_argument("--budget", type=int, default=256, metavar="N",
                    help="max schedules per scenario (default 256)")
    ex.add_argument("--preemptions", type=int, default=2, metavar="K",
                    help="preemption bound per schedule (default 2)")
    ex.add_argument("--max-steps", type=int, default=20000, metavar="N")
    ex.add_argument("--schedule", metavar="ID",
                    help="replay one schedule ID (requires exactly one "
                    "--scenario; the ID itself names the scenario too)")
    ex.add_argument("--json", action="store_true")

    sub.add_parser("codes", help="list every diagnostic code")

    args = ap.parse_args(argv)

    if args.cmd == "codes":
        for code, (sev, title) in sorted(REGISTRY.items()):
            print(f"{code}  {sev:<7}  {title}")
        return 0

    if args.cmd == "explore":
        return _explore(args)

    if args.cmd == "check":
        from .workflow import analyze_file
        findings = Findings()
        for f in args.files:
            findings.extend(analyze_file(f))
    else:
        from .astlint import lint_paths
        findings = lint_paths(args.paths)

    print(findings.render_json() if args.json else findings.render_text())
    if findings.errors():
        return 1
    if args.strict and any(d.severity == Severity.WARNING for d in findings):
        return 1
    return 0


def _explore(args) -> int:
    # the factories read WILKINS_EXPLORE at make_* time, so the flag must
    # be up before any scenario constructs a core object
    os.environ["WILKINS_EXPLORE"] = "1"
    import json as _json

    from .explore import build_scenario, explore, names, replay

    if args.list:
        for n in names():
            print(n)
        return 0

    if args.schedule:
        scen = args.schedule.partition("@")[0]
        if args.scenario and args.scenario != [scen]:
            print(f"--schedule names scenario {scen!r}, which contradicts "
                  f"--scenario {args.scenario}", file=sys.stderr)
            return 2
        res = replay(build_scenario(scen), args.schedule,
                     max_steps=args.max_steps)
        doc = {"scenario": scen, "schedule_id": args.schedule,
               "found": len(res.findings) > 0,
               "codes": sorted({d.code for d in res.findings})}
        print(_json.dumps(doc, indent=2) if args.json
              else res.findings.render_text())
        return 1 if res.findings.errors() else 0

    targets = args.scenario or names()
    reports = []
    rc = 0
    for name in targets:
        rep = explore(build_scenario(name), scenario=name,
                      max_schedules=args.budget,
                      preemption_bound=args.preemptions,
                      max_steps=args.max_steps)
        reports.append(rep)
        if rep.found:
            rc = 1
    if args.json:
        print(_json.dumps([r.as_dict() for r in reports], indent=2))
        return rc
    for rep in reports:
        status = "FOUND" if rep.found else (
            "clean" if rep.complete else "clean (budget-capped)")
        print(f"{rep.scenario:<20} {rep.schedules:>5} schedules "
              f"({rep.pruned} pruned, {rep.steps_total} steps, "
              f"{rep.elapsed_s:.2f}s)  {status}")
        if rep.found:
            print(rep.findings.render_text())
            print(f"  replay: python -m repro.analysis explore "
                  f"--schedule '{rep.schedule_id}'")
    return rc


if __name__ == "__main__":
    sys.exit(main())
