"""Reshard-plan coverage verifier (WLK225 / WLK226).

The M->N planner in ``core.redistribute`` is pure index arithmetic, which
makes it cheap to *prove* a compiled plan correct instead of trusting it:

* **WLK225** -- exactly-once coverage: the transfers feeding each
  destination rank must tile that rank's declared block exactly -- no
  element left unwritten (a silent hole the executor fills with stale
  bytes) and no element written twice (last-writer-wins nondeterminism
  across source ranks).
* **WLK226** -- bounds: every slab box the plan will index (source blocks,
  destination blocks, and each transfer region) must lie inside the
  dataset's global extent; an out-of-bounds box either crashes the
  executor or silently wraps a negative start.

:func:`verify_plan` checks one :class:`~repro.core.redistribute.CompiledPlan`
(the library call the fault-injection fixtures and tests use);
:func:`verify_edge` compiles the plan for a declared (shape, axis, M, N)
edge and verifies it -- the workflow analyzer runs this for every
``redistribute`` inport whose dsets carry a full ``shape:`` hint, so
``python -m repro.analysis check`` proves plan coverage for every declared
edge before anything runs.

The exactly-once argument needs no coverage bitmap: if every transfer box
is contained in its destination block, no two transfer boxes overlap, and
their volumes sum to the block's volume, the boxes tile the block exactly.
"""

from __future__ import annotations

import math
from typing import Any, Optional, Sequence, Tuple

from .diagnostics import Diagnostic, Findings, Location

__all__ = ["verify_plan", "verify_edge"]

Box = Tuple[Tuple[int, ...], Tuple[int, ...]]  # (starts, shape)


def _volume(shape: Sequence[int]) -> int:
    return math.prod(int(s) for s in shape) if shape else 0


def _overlap(a: Box, b: Box) -> bool:
    return all(max(as_, bs_) < min(as_ + ash, bs_ + bsh)
               for (as_, ash), (bs_, bsh) in zip(zip(*a), zip(*b)))


def _contains(outer: Box, inner: Box) -> bool:
    return all(os_ <= is_ and is_ + ish <= os_ + osh
               for (os_, osh), (is_, ish) in zip(zip(*outer), zip(*inner)))


def _in_bounds(box: Box, extent: Sequence[int]) -> bool:
    starts, shape = box
    if len(starts) != len(extent) or len(shape) != len(extent):
        return False
    return all(0 <= s and 0 <= n and s + n <= e
               for s, n, e in zip(starts, shape, extent))


def verify_plan(plan: Any, *, context: str = "",
                location: Optional[Location] = None) -> Findings:
    """Verify a compiled plan's bounds and exactly-once coverage.

    ``plan`` needs the ``CompiledPlan`` surface: ``shape``, ``src``,
    ``dst`` (global boxes) and ``per_dst[r]`` (the transfers feeding dst
    rank r, each with ``global_starts``/``shape``/``src_rank``).
    ``context`` prefixes every message (e.g. ``"edge sim->viz:data.h5"``);
    ``location`` anchors the findings for the workflow analyzer.
    """
    out = Findings()
    loc = location or Location()
    ctx = f"{context}: " if context else ""
    extent = tuple(int(s) for s in plan.shape)

    def add(code: str, msg: str) -> None:
        out.add(Diagnostic(code, ctx + msg, loc))

    for label, boxes in (("src", plan.src), ("dst", plan.dst)):
        for r, box in enumerate(boxes):
            if not _in_bounds(box, extent):
                add("WLK226",
                    f"{label} rank {r} block {box} out of bounds for "
                    f"global extent {list(extent)}")

    for dr, dbox in enumerate(plan.dst):
        slabs = plan.per_dst[dr]
        regions = [(tuple(t.global_starts), tuple(t.shape)) for t in slabs]
        for t, region in zip(slabs, regions):
            if not _in_bounds(region, extent):
                add("WLK226",
                    f"transfer src {t.src_rank} -> dst {dr} slab box "
                    f"{region} out of bounds for global extent "
                    f"{list(extent)}")
            elif not _contains(dbox, region):
                add("WLK226",
                    f"transfer src {t.src_rank} -> dst {dr} slab box "
                    f"{region} escapes the destination block {dbox}")
        for i in range(len(regions)):
            for j in range(i + 1, len(regions)):
                if _overlap(regions[i], regions[j]):
                    add("WLK225",
                        f"dst rank {dr} element(s) written twice: transfer "
                        f"boxes {regions[i]} and {regions[j]} overlap "
                        f"(last-writer-wins nondeterminism)")
        want = _volume(dbox[1])
        got = sum(_volume(r[1]) for r in regions)
        if got < want:
            add("WLK225",
                f"dst rank {dr} block {dbox} covered by {got} of {want} "
                f"elements -- {want - got} element(s) never written")
        elif got > want:
            add("WLK225",
                f"dst rank {dr} block {dbox} receives {got} elements for "
                f"{want} slots -- duplicated or escaping transfers")
    return out


def verify_edge(shape: Sequence[int], axis: int, src_nranks: int,
                dst_nranks: int, *, context: str = "",
                location: Optional[Location] = None) -> Findings:
    """Compile the plan for one declared edge and verify it.

    ``shape`` is the dataset's ``shape:`` hint; the producer side owns the
    dataset as ``src_nranks`` even blocks along ``axis`` and the consumer
    wants ``dst_nranks`` blocks along the same axis (the runtime's default
    layout for a ``redistribute`` inport).
    """
    from ..core.redistribute import CompiledPlan, even_blocks
    shape = tuple(int(s) for s in shape)
    src = even_blocks(shape, max(1, int(src_nranks)), axis=axis)
    dst = even_blocks(shape, max(1, int(dst_nranks)), axis=axis)
    plan = CompiledPlan(src, dst, shape)
    return verify_plan(plan, context=context, location=location)
