"""Shared diagnostics framework for the pre-run analyzers.

Every finding the workflow analyzer (``analysis.workflow``), the AST lint
(``analysis.astlint``) or the runtime lock checker (``analysis.lockcheck``)
produces is a :class:`Diagnostic`: a stable code (``WLK...``), a severity,
a human message, and a location that names the YAML file/task/port or the
source file/line it anchors to.  The code is the contract -- tests, CI
gates and suppressions key on it, never on message text.

Suppressions come in two spellings:

* a line comment on the offending YAML/source line::

      queue_depth: 1   # wilkins: ignore[WLK201]

  (bare ``# wilkins: ignore`` suppresses every code on that line);

* a workflow-level block in the YAML document::

      lint:
        ignore: [WLK222, WLK224]

Output is text (one finding per line, ``file:line: CODE severity message``)
or JSON (``render_json``), selected by the CLI.
"""

from __future__ import annotations

import json
import re
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence

__all__ = [
    "Severity",
    "Location",
    "Diagnostic",
    "Findings",
    "REGISTRY",
    "severity_of",
    "line_suppressions",
]


class Severity:
    ERROR = "error"
    WARNING = "warning"
    INFO = "info"

    _RANK = {ERROR: 2, WARNING: 1, INFO: 0}

    @classmethod
    def rank(cls, sev: str) -> int:
        return cls._RANK.get(sev, 0)


#: code -> (default severity, one-line title).  The single source of truth:
#: the CLI ``--codes`` listing, the DESIGN.md table and the fixture corpus
#: all enumerate THIS dict, so a code without a fixture is a test failure.
REGISTRY: Dict[str, tuple] = {
    # ---- input / document structure --------------------------------------
    "WLK001": (Severity.ERROR, "workflow YAML failed to parse"),
    "WLK002": (Severity.ERROR, "workflow document structure invalid"),
    # ---- schema / policy legality (shared with core.graph parse time) ----
    "WLK101": (Severity.ERROR, "queue_depth out of range"),
    "WLK102": (Severity.ERROR, "io_freq invalid"),
    "WLK103": (Severity.ERROR, "redistribute axis invalid"),
    "WLK104": (Severity.ERROR, "prefetch depth invalid"),
    "WLK105": (Severity.ERROR, "scheduler weight invalid"),
    "WLK106": (Severity.ERROR, "autotune spelling or bounds invalid"),
    "WLK107": (Severity.ERROR, "ownership spelling invalid"),
    "WLK108": (Severity.ERROR, "knob declared on the wrong port side"),
    "WLK109": (Severity.ERROR, "autotune conflicts with prefetch: 0"),
    "WLK110": (Severity.ERROR, "ownership nranks matches no rank count"),
    "WLK111": (Severity.ERROR, "stall_timeout_s invalid"),
    "WLK112": (Severity.ERROR, "stall_timeout_s needs a managed policy"),
    "WLK113": (Severity.ERROR, "on_failure policy invalid"),
    "WLK114": (Severity.ERROR, "scheduler block invalid"),
    "WLK115": (Severity.ERROR, "actions spelling invalid"),
    "WLK116": (Severity.ERROR, "duplicate task func names"),
    "WLK117": (Severity.ERROR, "rescale target violates structural rules"),
    "WLK118": (Severity.ERROR, "programmatic rescale request invalid"),
    # ---- graph shape ------------------------------------------------------
    "WLK201": (Severity.ERROR, "rendezvous deadlock cycle (all edges "
                               "io_freq: all + queue_depth: 1)"),
    "WLK202": (Severity.WARNING, "bounded-queue cycle can deadlock when "
                                 "rings fill"),
    "WLK203": (Severity.WARNING, "outport matches the task's own inport "
                                 "(self-edge is ignored at runtime)"),
    "WLK204": (Severity.WARNING, "memory-mode inport matched no producer"),
    "WLK210": (Severity.WARNING, "fan-in mixes a strict rendezvous edge "
                                 "with a dropping edge"),
    "WLK211": (Severity.WARNING, "producer gated by a strict edge; sibling "
                                 "dropping edge cannot run ahead"),
    "WLK212": (Severity.INFO, "latest-mode edge with prefetch/autotune "
                              "preps payloads that may be dropped"),
    # ---- decomposition legality ------------------------------------------
    "WLK220": (Severity.ERROR, "decomposition axis out of range for the "
                               "declared dataset rank"),
    "WLK221": (Severity.WARNING, "declared shape yields empty blocks"),
    "WLK222": (Severity.INFO, "flattened inner extent not a 128-lane "
                              "multiple (pack kernel pads)"),
    "WLK223": (Severity.WARNING, "nwriters exceeds nprocs"),
    "WLK224": (Severity.INFO, "shape not divisible by the decomposition "
                              "rank count (uneven blocks)"),
    "WLK225": (Severity.ERROR, "reshard plan does not cover every "
                               "destination element exactly once"),
    "WLK226": (Severity.ERROR, "reshard plan slab box out of bounds"),
    # ---- concurrency: AST lint over core/ --------------------------------
    "WLK301": (Severity.ERROR, "channel state mutated outside the channel "
                               "condition variable"),
    "WLK302": (Severity.ERROR, "Condition.wait outside a while predicate "
                               "loop"),
    "WLK303": (Severity.WARNING, "supervisor-aware wait loop does not "
                                 "heartbeat"),
    "WLK304": (Severity.ERROR, "stats counter mutated outside its owning "
                               "lock"),
    "WLK305": (Severity.ERROR, "direct threading primitive construction in "
                               "core (use the make_* factories)"),
    # ---- concurrency: runtime lock checker (WILKINS_LOCKCHECK=1) ---------
    "WLK310": (Severity.ERROR, "lock-acquisition cycle (potential "
                               "deadlock)"),
    "WLK311": (Severity.ERROR, "blocking call while holding a lock"),
    "WLK312": (Severity.WARNING, "locks acquired against the canonical "
                                 "rank order"),
    # ---- concurrency: schedule explorer (WILKINS_EXPLORE=1) --------------
    "WLK320": (Severity.ERROR, "data race: unordered accesses to a shared "
                               "buffer, at least one a write"),
    "WLK321": (Severity.ERROR, "deadlock or timed-wait livelock under an "
                               "explored schedule"),
    "WLK322": (Severity.ERROR, "lost wakeup: waiter parked with no live "
                               "notifier"),
    "WLK323": (Severity.ERROR, "scenario invariant failed under an "
                               "explored schedule"),
}


def severity_of(code: str) -> str:
    return REGISTRY.get(code, (Severity.ERROR,))[0]


@dataclass(frozen=True)
class Location:
    """Where a finding anchors: a file plus whichever of line/task/port
    applies.  Any field may be absent (runtime lockcheck findings have no
    file at all)."""

    file: Optional[str] = None
    line: Optional[int] = None
    task: Optional[str] = None
    port: Optional[str] = None

    def __str__(self) -> str:
        head = self.file or "<workflow>"
        if self.line is not None:
            head += f":{self.line}"
        tail = []
        if self.task is not None:
            tail.append(f"task {self.task!r}")
        if self.port is not None:
            tail.append(f"port {self.port!r}")
        return head + (" (" + ", ".join(tail) + ")" if tail else "")

    def as_dict(self) -> Dict[str, Any]:
        return {k: v for k, v in (("file", self.file), ("line", self.line),
                                  ("task", self.task), ("port", self.port))
                if v is not None}


@dataclass
class Diagnostic:
    code: str
    message: str
    location: Location = field(default_factory=Location)
    severity: Optional[str] = None  # None = the registry default for code

    def __post_init__(self):
        if self.severity is None:
            self.severity = severity_of(self.code)

    def render(self) -> str:
        return f"{self.location}: {self.code} {self.severity}: {self.message}"

    def as_dict(self) -> Dict[str, Any]:
        return {"code": self.code, "severity": self.severity,
                "message": self.message, "location": self.location.as_dict()}


_IGNORE_RE = re.compile(r"#\s*wilkins:\s*ignore(?:\[([A-Z0-9,\s]+)\])?")


def line_suppressions(text: str) -> Dict[int, Optional[set]]:
    """Map 1-based line number -> set of suppressed codes (None = all codes)
    for every ``# wilkins: ignore[...]`` line comment in ``text``."""
    out: Dict[int, Optional[set]] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _IGNORE_RE.search(line)
        if not m:
            continue
        if m.group(1) is None:
            out[i] = None
        else:
            out[i] = {c.strip() for c in m.group(1).split(",") if c.strip()}
    return out


class Findings:
    """An ordered collection of diagnostics with suppression filtering and
    the two renderers."""

    def __init__(self, diagnostics: Iterable[Diagnostic] = ()):
        self.diagnostics: List[Diagnostic] = list(diagnostics)

    def add(self, diag: Diagnostic) -> None:
        self.diagnostics.append(diag)

    def extend(self, diags: Iterable[Diagnostic]) -> None:
        self.diagnostics.extend(diags)

    def __len__(self) -> int:
        return len(self.diagnostics)

    def __iter__(self):
        return iter(self.diagnostics)

    def errors(self) -> List[Diagnostic]:
        return [d for d in self.diagnostics if d.severity == Severity.ERROR]

    def suppress(self, codes: Sequence[str] = (),
                 by_line: Optional[Dict[int, Optional[set]]] = None
                 ) -> "Findings":
        """A new Findings with document-level ``codes`` and per-line
        ``# wilkins: ignore`` suppressions applied."""
        doc = set(codes or ())
        by_line = by_line or {}
        kept = []
        for d in self.diagnostics:
            if d.code in doc:
                continue
            ln = d.location.line
            if ln is not None and ln in by_line:
                only = by_line[ln]
                if only is None or d.code in only:
                    continue
            kept.append(d)
        return Findings(kept)

    def sorted(self) -> "Findings":
        return Findings(sorted(
            self.diagnostics,
            key=lambda d: (-Severity.rank(d.severity),
                           d.location.file or "", d.location.line or 0,
                           d.code)))

    def render_text(self) -> str:
        if not self.diagnostics:
            return "no findings"
        lines = [d.render() for d in self.sorted()]
        n_err = len(self.errors())
        lines.append(f"{len(self.diagnostics)} finding(s), {n_err} error(s)")
        return "\n".join(lines)

    def render_json(self) -> str:
        return json.dumps({
            "findings": [d.as_dict() for d in self.sorted()],
            "counts": {
                "total": len(self.diagnostics),
                "error": len(self.errors()),
                "warning": sum(1 for d in self.diagnostics
                               if d.severity == Severity.WARNING),
                "info": sum(1 for d in self.diagnostics
                            if d.severity == Severity.INFO),
            }}, indent=2)
