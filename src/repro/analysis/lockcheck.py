"""Pass 2 (runtime half): the instrumented lock/CV wrapper.

Opt-in via ``WILKINS_LOCKCHECK=1``: ``make_lock``/``make_condition``
return checked wrappers that record the cross-thread lock-acquisition
graph while code runs (a tier-1 shard, a benchmark, anything).  Disabled
-- the default -- they return plain ``threading`` primitives with zero
overhead, so adopting the factories costs nothing on production paths.

What the recorder catches:

* **WLK310** -- a cycle in the name-level acquisition graph: thread A
  takes ``x`` then ``y`` while thread B takes ``y`` then ``x`` is a
  potential deadlock even if the runs interleave safely today.
* **WLK311** -- a known-blocking call (``Channel.get``, ``sleep``,
  ``future.result``) entered while holding a fine-grained lock.  Core
  code marks those sites with :func:`check_blocking`, a no-op when the
  checker is off.  Coarse locks (the VOL serve locks, rank < RANK_FINE)
  are exempt: a producer parked in ``offer()`` *holds* its serve lock by
  design -- that is the rescale grace protocol, not a bug.
* **WLK312** -- an acquisition against the canonical rank order (below).

The canonical order (outermost first) is the one the PR-7 rescale surgery
established; the checker turns the convention into an enforced rule::

    10  vol.serve      per-producer-instance VOL serve lock
    20  supervisor     recovery.RunSupervisor._lock
    25  scheduler      SchedulerRuntime._lock/_tick_lock, PrefetchPool cv
    30  channel.cv     the per-channel condition variable
    40  channel.sem    ResizableSemaphore cv, supervisor heartbeat lock
    50  leaf           mux, telemetry, stats, fault plans, driver misc

Same-rank nesting is allowed only for ranks declaring it (the serve locks
are acquired in sorted producer order by the surgery; sibling channel CVs
are snapshotted one at a time).  Reentrant re-acquisition of the *same*
object (Condition wraps an RLock) is never an edge.
"""

from __future__ import annotations

import os
import threading
from typing import Any, Dict, List, Optional, Set, Tuple

from .diagnostics import Diagnostic, Findings, Location

__all__ = ["enabled", "explore_enabled", "make_lock", "make_condition",
           "make_semaphore", "check_blocking", "sched_point", "hb_publish",
           "hb_consume", "set_explore_controller", "explore_controller",
           "registry", "LockCheckRegistry", "RANK_FINE", "RANKS"]

#: canonical rank bands (outermost = smallest); see module docstring
RANKS: Dict[str, int] = {
    "vol.serve": 10,
    "supervisor": 20,
    "scheduler": 25,
    "pool": 25,
    "channel.cv": 30,
    "channel.sem": 40,
    "supervisor.hb": 40,
    "leaf": 50,
}

#: blocking calls are only an error under locks at least this fine --
#: holding a coarse serve lock across a blocking offer IS the grace
#: protocol the rescale surgery depends on.
RANK_FINE = 25

#: ranks where same-rank nesting is legal because the code imposes its own
#: total order (serve locks: sorted producer order; channel CVs: the
#: surgery snapshots siblings one at a time under the serve locks).
SELF_NESTING_RANKS: Set[int] = {10, 30}


def enabled() -> bool:
    return os.environ.get("WILKINS_LOCKCHECK", "") not in ("", "0")


def explore_enabled() -> bool:
    """Pass 3 (``analysis.explore``): the deterministic schedule explorer.

    When ``WILKINS_EXPLORE=1`` the factories hand out *cooperative* model
    primitives that serialize every managed thread onto a single
    runnable-at-a-time token (see ``analysis/explore/control.py``); outside
    an active exploration they delegate to plain ``threading`` primitives,
    so merely having the env var set never changes production behaviour.
    """
    return os.environ.get("WILKINS_EXPLORE", "") not in ("", "0")


# The active schedule-exploration controller.  ``None`` (the default, and
# always the case unless WILKINS_EXPLORE=1 *and* an exploration is running)
# makes every hook below a single global-load + ``is None`` test -- the
# whole instrumentation budget on the production hot path.
_EXPLORE_CONTROLLER: Optional[Any] = None


def set_explore_controller(controller: Optional[Any]) -> Optional[Any]:
    """Install (or clear, with ``None``) the active explore controller;
    returns the previous one so nested use can restore it."""
    global _EXPLORE_CONTROLLER
    prev = _EXPLORE_CONTROLLER
    _EXPLORE_CONTROLLER = controller
    return prev


def explore_controller() -> Optional[Any]:
    return _EXPLORE_CONTROLLER


def sched_point(tag: str, key: Any = None, access: Optional[str] = None) -> None:
    """An explicit scheduler yield point (no-op unless exploring).

    Core code marks the windows that matter to the transport/rescale
    protocols -- the unlocked gap in ``Channel.offer``, the share re-read in
    ``Dataset._acquire_share``, the rescale surgery steps -- so the explorer
    can preempt exactly there.  ``key`` identifies the object the operation
    touches (dependence relation for sleep-set pruning); ``access`` of
    ``"r"``/``"w"`` additionally records a shadow-state data access at
    ``key`` for the happens-before race detector (WLK320).
    """
    c = _EXPLORE_CONTROLLER
    if c is not None:
        c.sched_point(tag, key=key, access=access)


def hb_publish(key: Any) -> None:
    """Stamp a happens-before *publish* edge at ``key`` (channel offer,
    CoW share hand-off): the publisher's vector clock is merged into the
    key's clock so a later ``hb_consume`` is ordered after it.  No-op
    unless exploring."""
    c = _EXPLORE_CONTROLLER
    if c is not None:
        c.hb_publish(key)


def hb_consume(key: Any) -> None:
    """Join the clock published at ``key`` into the consuming thread
    (channel get / delivery).  No-op unless exploring."""
    c = _EXPLORE_CONTROLLER
    if c is not None:
        c.hb_consume(key)


def rank_of(name: str) -> int:
    """Rank from a lock name: the prefix before ``:`` keys into RANKS."""
    return RANKS.get(name.split(":", 1)[0], RANKS["leaf"])


class LockCheckRegistry:
    """Process-wide recorder: per-thread held stacks, the name-level edge
    graph, rank violations, and blocking-under-lock events."""

    def __init__(self):
        self._mu = threading.Lock()  # wilkins: ignore[WLK305] -- checker internals
        self._held = threading.local()
        # (outer_prefix, inner_prefix) -> one example (outer, inner, thread)
        self.edges: Dict[Tuple[str, str], Tuple[str, str, str]] = {}
        self.rank_violations: List[Tuple[str, str, str]] = []
        self.blocking: List[Tuple[str, str, str]] = []

    # ------------------------------------------------------------- held API
    def _stack(self) -> List[Tuple[str, int, int]]:
        st = getattr(self._held, "stack", None)
        if st is None:
            st = self._held.stack = []
        return st

    def held(self) -> List[str]:
        return [name for name, _, _ in self._stack()]

    def push(self, name: str, rank: int, obj_id: int) -> None:
        st = self._stack()
        if any(oid == obj_id for _, _, oid in st):
            # reentrant re-acquisition of the same object (Condition wraps
            # an RLock): never an edge, never a violation
            st.append((name, rank, obj_id))
            return
        if st:
            outer_name, outer_rank, _ = st[-1]
            a, b = _prefix(outer_name), _prefix(name)
            if a != b:
                with self._mu:
                    self.edges.setdefault(
                        (a, b), (outer_name, name,
                                 threading.current_thread().name))
            bad_order = (rank < outer_rank
                         or (rank == outer_rank
                             and rank not in SELF_NESTING_RANKS
                             and a != b))
            if bad_order:
                with self._mu:
                    self.rank_violations.append(
                        (outer_name, name, threading.current_thread().name))
        st.append((name, rank, obj_id))

    def pop(self, obj_id: int) -> None:
        st = self._stack()
        for i in range(len(st) - 1, -1, -1):
            if st[i][2] == obj_id:
                del st[i]
                return

    # --------------------------------------------------------- diagnostics
    def note_blocking(self, what: str) -> None:
        st = self._stack()
        fine = [name for name, rank, _ in st if rank >= RANK_FINE]
        if fine:
            with self._mu:
                self.blocking.append(
                    (what, fine[-1], threading.current_thread().name))

    def cycles(self) -> List[List[str]]:
        """Elementary cycles in the prefix-level edge graph (DFS)."""
        with self._mu:
            succ: Dict[str, Set[str]] = {}
            for (a, b) in self.edges:
                succ.setdefault(a, set()).add(b)
        out: List[List[str]] = []
        seen_cycles: Set[Tuple[str, ...]] = set()
        for start in sorted(succ):
            stack = [(start, [start])]
            while stack:
                node, path = stack.pop()
                for nxt in sorted(succ.get(node, ())):
                    if nxt == start:
                        cyc = path + [start]
                        key = tuple(sorted(set(cyc)))
                        if key not in seen_cycles:
                            seen_cycles.add(key)
                            out.append(cyc)
                    elif nxt not in path:
                        stack.append((nxt, path + [nxt]))
        return out

    def findings(self) -> Findings:
        out = Findings()
        for cyc in self.cycles():
            out.add(Diagnostic(
                "WLK310",
                f"lock-acquisition cycle: {' -> '.join(cyc)} (threads "
                f"acquire these lock groups in conflicting orders)",
                Location()))
        with self._mu:
            for outer, inner, thread in self.rank_violations:
                out.add(Diagnostic(
                    "WLK312",
                    f"thread {thread!r} acquired {inner!r} (rank "
                    f"{rank_of(inner)}) while holding {outer!r} (rank "
                    f"{rank_of(outer)}) -- against the canonical order",
                    Location()))
            for what, under, thread in self.blocking:
                out.add(Diagnostic(
                    "WLK311",
                    f"thread {thread!r} entered blocking call {what!r} "
                    f"while holding {under!r}",
                    Location()))
        return out

    def assert_clean(self) -> None:
        f = self.findings()
        if f.errors():
            raise AssertionError(
                "lock-discipline violations recorded:\n" + f.render_text())

    def reset(self) -> None:
        with self._mu:
            self.edges.clear()
            self.rank_violations.clear()
            self.blocking.clear()


def _prefix(name: str) -> str:
    return name.split(":", 1)[0]


_registry = LockCheckRegistry()


def registry() -> LockCheckRegistry:
    return _registry


# ---------------------------------------------------------------------------
# checked primitives
# ---------------------------------------------------------------------------
class CheckedLock:
    """A named, rank-aware wrapper over ``threading.Lock``."""

    def __init__(self, name: str):
        self.name = name
        self.rank = rank_of(name)
        self._lock = threading.Lock()  # wilkins: ignore[WLK305] -- the wrapped primitive

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._lock.acquire(blocking, timeout)
        if got:
            registry().push(self.name, self.rank, id(self))
        return got

    def release(self) -> None:
        registry().pop(id(self))
        self._lock.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self) -> bool:
        return self._lock.locked()


class CheckedCondition:
    """A named, rank-aware wrapper over ``threading.Condition``.

    ``wait`` pops the held entry while parked (the CV releases its lock)
    and re-records it on wakeup, so the recorder never sees a parked
    waiter as "holding" the lock."""

    def __init__(self, name: str):
        self.name = name
        self.rank = rank_of(name)
        self._cond = threading.Condition()  # wilkins: ignore[WLK305] -- the wrapped primitive

    # -- lock surface
    def acquire(self, *args) -> bool:
        got = self._cond.acquire(*args)
        if got:
            registry().push(self.name, self.rank, id(self))
        return got

    def release(self) -> None:
        registry().pop(id(self))
        self._cond.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    # -- condition surface
    def wait(self, timeout: Optional[float] = None) -> bool:
        registry().pop(id(self))
        try:
            # the wrapper delegates; the while-predicate discipline
            # applies to its CALLERS
            return self._cond.wait(timeout)  # wilkins: ignore[WLK302]
        finally:
            registry().push(self.name, self.rank, id(self))

    def wait_for(self, predicate, timeout: Optional[float] = None):
        registry().pop(id(self))
        try:
            # wrapper pass-through, see wait()
            return self._cond.wait_for(predicate, timeout)  # wilkins: ignore[WLK302]
        finally:
            registry().push(self.name, self.rank, id(self))

    def notify(self, n: int = 1) -> None:
        self._cond.notify(n)

    def notify_all(self) -> None:
        self._cond.notify_all()


# ---------------------------------------------------------------------------
# factories + the blocking-site hook
# ---------------------------------------------------------------------------
# Factory precedence: explore > lockcheck > plain.  The explore wrappers are
# imported lazily (only when WILKINS_EXPLORE=1) so the common path never pays
# the import and there is no lockcheck <-> explore import cycle.
def make_lock(name: str) -> Any:
    """A ``threading.Lock`` -- checked when WILKINS_LOCKCHECK=1, a
    cooperative model lock when WILKINS_EXPLORE=1."""
    if explore_enabled():
        from .explore.instrument import ExploreLock
        return ExploreLock(name)
    return CheckedLock(name) if enabled() else threading.Lock()  # wilkins: ignore[WLK305] -- the factory itself


def make_condition(name: str) -> Any:
    """A ``threading.Condition`` -- checked and named when
    WILKINS_LOCKCHECK=1, a cooperative model CV when WILKINS_EXPLORE=1."""
    if explore_enabled():
        from .explore.instrument import ExploreCondition
        return ExploreCondition(name)
    return CheckedCondition(name) if enabled() else threading.Condition()  # wilkins: ignore[WLK305] -- the factory itself


def make_semaphore(name: str, value: int = 1) -> Any:
    """A ``threading.Semaphore`` -- a cooperative model semaphore when
    WILKINS_EXPLORE=1.  Lockcheck has no semaphore discipline to enforce
    (semaphores carry no canonical rank), so the lockcheck path stays
    plain; the name still matters to the explorer's dependence relation."""
    if explore_enabled():
        from .explore.instrument import ExploreSemaphore
        return ExploreSemaphore(name, value)
    return threading.Semaphore(value)  # wilkins: ignore[WLK305] -- the factory itself


def check_blocking(what: str) -> None:
    """Mark a known-blocking call site (``Channel.get``, ``sleep``,
    ``future.result``).  No-op unless the checker is on; records WLK311
    when entered while holding a fine-grained lock."""
    if enabled():
        registry().note_blocking(what)
