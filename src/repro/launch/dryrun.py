import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (lower+compile succeeds, no sharding
    mismatch / unsupported collective),
  * memory fits (``compiled.memory_analysis()`` bytes-per-device),
  * and it yields the roofline terms (``cost_analysis()`` flops/bytes +
    collective bytes parsed from the partitioned HLO).

Results land in ``results/dryrun/<arch>__<shape>__<mesh>[__tag].json`` so the
roofline benchmark and EXPERIMENTS.md read from one place.  Cells are
independent -> the grid can be sharded across processes with --arch/--shape.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback
from typing import Optional

import jax

from repro.configs import ARCH_IDS, SHAPES, get_config, shapes_for
from repro.launch import hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import make_cell
from repro.parallel.sharding import DEFAULT_RULES, SERVE_RULES

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def run_cell(arch: str, shape_name: str, mesh_name: str,
             rules=None, accum_steps: int = 1, tag: str = "",
             compress_grads: bool = False,
             cfg_overrides: Optional[dict] = None,
             variant: Optional[str] = None) -> dict:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = next(s for s in SHAPES if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    n_dev = mesh.devices.size

    t0 = time.monotonic()
    cell = make_cell(cfg, shape, mesh, rules=rules, accum_steps=accum_steps,
                     compress_grads=compress_grads)
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     donate_argnums=cell.donate_argnums)
    lowered = jitted.lower(*cell.args)
    t_lower = time.monotonic() - t0

    t0 = time.monotonic()
    compiled = lowered.compile()
    t_compile = time.monotonic() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = hlo.collective_bytes(compiled.as_text())

    # analytic per-device flops/bytes (cost_analysis counts while bodies once
    # -- see hlo.py module docstring); raw numbers recorded below.
    if mesh_name == "multipod":
        n_data, n_model = mesh.shape["pod"] * mesh.shape["data"], mesh.shape["model"]
    else:
        n_data, n_model = mesh.shape["data"], mesh.shape["model"]
    if variant == "dp":      # pure DP folds the model axis into data
        n_data, n_model = n_data * n_model, 1
    ana = hlo.analytic_stats(cfg, shape, n_data, n_model,
                             accum_steps=accum_steps)
    rf = hlo.Roofline(
        flops=ana["flops"],
        hbm_bytes=ana["hbm_bytes"],
        coll_bytes=float(coll.total_bytes),
        model_flops=hlo.model_flops_per_device(cfg, shape, n_dev),
    )
    out = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "tag": tag,
        "n_devices": n_dev,
        "ok": True,
        "t_lower_s": t_lower,
        "t_compile_s": t_compile,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)
                           + getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "collectives": {
            "bytes_by_kind": coll.bytes_by_kind,
            "count_by_kind": coll.count_by_kind,
        },
        "roofline": rf.to_dict(),
        "raw_cost_analysis": {  # while bodies counted once -- see hlo.py
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
    }
    return out


def save_result(res: dict) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = f"__{res['tag']}" if res.get("tag") else ""
    name = f"{res['arch']}__{res['shape']}__{res['mesh']}{tag}.json"
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)
    return path


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default=None)
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in SHAPES])
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--accum-steps", type=int, default=None,
                    help="grad-accum microbatches for train shapes "
                         "(default 4: fits the 22-80 layer carry stacks in "
                         "16 GB/chip HBM)")
    ap.add_argument("--weight-gather", action="store_true",
                    help="FSDP weight-gather sharding mode (see "
                         "parallel/sharding.py) -- the beyond-baseline layout")
    ap.add_argument("--variant", default=None,
                    help="named rule variant from parallel.sharding."
                         "RULE_VARIANTS (wg/sp/dp/serve_wg/serve_repl); "
                         "becomes the result tag")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--remat", choices=["none", "dots", "full"], default=None,
                    help="override the config's activation-checkpoint policy")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        cell_shapes = ([s for s in shapes_for(cfg) if s.name == args.shape]
                       if args.shape else shapes_for(cfg))
        for shape in cell_shapes:
            for mesh_name in meshes:
                tag = f"__{args.tag}" if args.tag else ""
                path = os.path.join(
                    RESULTS_DIR, f"{arch}__{shape.name}__{mesh_name}{tag}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {arch} {shape.name} {mesh_name}")
                    continue
                accum = args.accum_steps
                if accum is None:
                    accum = 4 if shape.kind == "train" else 1
                rules = None
                tag = args.tag
                if args.variant:
                    from repro.parallel.sharding import RULE_VARIANTS
                    rules = RULE_VARIANTS[args.variant]
                    tag = tag or args.variant
                elif args.weight_gather:
                    base = DEFAULT_RULES if shape.kind == "train" else SERVE_RULES
                    rules = base.with_(weight_gather=True)
                try:
                    overrides = {"remat": args.remat} if args.remat else None
                    if args.variant == "moe_a2a":
                        overrides = dict(overrides or {})
                        overrides["moe_dispatch"] = "a2a"
                    res = run_cell(arch, shape.name, mesh_name, tag=tag,
                                   rules=rules, accum_steps=accum,
                                   compress_grads=args.compress_grads,
                                   cfg_overrides=overrides,
                                   variant=args.variant)
                    p = save_result(res)
                    r = res["roofline"]
                    print(f"[ok] {arch} {shape.name} {mesh_name} "
                          f"compile={res['t_compile_s']:.1f}s "
                          f"mem={res['memory']['peak_bytes']/2**30:.2f}GiB "
                          f"tc={r['t_compute']*1e3:.2f}ms "
                          f"tm={r['t_memory']*1e3:.2f}ms "
                          f"tx={r['t_collective']*1e3:.2f}ms "
                          f"bound={r['bottleneck']} -> {p}")
                except Exception as e:
                    failures += 1
                    print(f"[FAIL] {arch} {shape.name} {mesh_name}: "
                          f"{type(e).__name__}: {e}")
                    traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
