"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs the full production loop on whatever devices exist: config -> mesh ->
sharded init -> data pipeline (prefetched) -> jitted train step -> async
checkpoints -> auto-resume.  On this CPU container use ``--reduced`` (smoke
config) -- the same code path drives a real pod.

Fault tolerance: the driver always tries ``restore_latest`` first, so a
preempted/killed run resumes from the newest atomic checkpoint with the data
iterator fast-forwarded to the right step (the corpus is pure in (seed, step)).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.registry import get_family
from repro.parallel.sharding import DEFAULT_RULES, logical_to_spec, tree_shardings, use_mesh
from repro.train import (AdamWConfig, AsyncCheckpointer, DataConfig,
                         init_state, make_batch_iter, make_train_step,
                         restore_latest, state_specs)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU smoke scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--weight-gather", action="store_true")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the (16,16) pod mesh (needs 256 devices)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    ocfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(1, args.steps // 20),
                      state_dtype=cfg.opt_state_dtype)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh(("data", "model")))
    rules = DEFAULT_RULES.with_(weight_gather=args.weight_gather)

    fam = get_family(cfg)
    step_fn = make_train_step(cfg, ocfg, accum_steps=args.accum_steps,
                              compress_grads=args.compress_grads)

    with use_mesh(mesh, rules):
        st_sh = tree_shardings(mesh, state_specs(cfg), rules)
        init = jax.jit(lambda k: init_state(k, cfg, ocfg), out_shardings=st_sh)
        state = init(jax.random.PRNGKey(0))
        jstep = jax.jit(step_fn, donate_argnums=0)

        start_step = 0
        ckpt = None
        if args.ckpt_dir:
            ckpt = AsyncCheckpointer(args.ckpt_dir, keep=3)
            got = restore_latest(args.ckpt_dir, jax.tree.map(np.asarray, state))
            if got is not None:
                start_step, host_state = got
                state = jax.tree.map(
                    lambda s, h: jax.device_put(np.asarray(h), s.sharding),
                    state, host_state)
                print(f"[resume] restored step {start_step} from {args.ckpt_dir}")

        dcfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                          global_batch=args.global_batch)
        bspec = logical_to_spec(("batch", None), mesh, rules)
        it = make_batch_iter(dcfg, start_step=start_step,
                             num_steps=args.steps - start_step,
                             mesh=mesh, batch_spec=bspec)
        t0 = time.monotonic()
        tokens_done = 0
        for step, batch in it:
            if cfg.family == "vlm":
                batch["vision_embeds"] = jnp.zeros(
                    (args.global_batch, cfg.vision_tokens, cfg.d_model),
                    jnp.dtype(cfg.dtype))
            if cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (args.global_batch, cfg.source_len, cfg.d_model),
                    jnp.dtype(cfg.dtype))
            state, metrics = jstep(state, batch)
            tokens_done += args.global_batch * args.seq_len
            if (step + 1) % args.log_every == 0 or step == start_step:
                loss = float(metrics["loss"])
                dt = time.monotonic() - t0
                print(f"step {step + 1:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"tok/s {tokens_done / max(dt, 1e-9):,.0f}")
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, state)
        if ckpt:
            ckpt.save(args.steps, state, block=True)
        print(f"done: {args.steps} steps in {time.monotonic() - t0:.1f}s")


if __name__ == "__main__":
    main()
