"""HLO post-mortem: collective-byte accounting + roofline terms.

``collective_bytes`` parses the SPMD-partitioned HLO text (per-device module)
and sums operand sizes of every cross-device collective.  Byte factors are the
standard ring estimates (documented, approximate):

    all-gather         : output bytes          (each device receives out-in)
    all-reduce         : 2 x operand bytes     (reduce-scatter + all-gather)
    reduce-scatter     : operand bytes
    all-to-all         : operand bytes
    collective-permute : operand bytes

**While-loop scaling.** XLA's cost analysis (and a naive HLO scan) counts a
``while`` body ONCE, but our models run the layer stack under ``lax.scan`` --
the per-layer weight all-gathers execute n_layers times.  The parser therefore
walks the call graph: collective bytes inside a while body are multiplied by
the loop's trip count (recovered from the loop-condition constant), nested
loops multiply through.  The same limitation makes ``cost_analysis()`` FLOPs
unusable for scanned models, so the compute/memory terms come from documented
*analytic* counters (``analytic_stats``); raw cost_analysis numbers are
recorded alongside for transparency.

Hardware constants are TPU v5e-class, per chip:
    197 TFLOP/s bf16  |  819 GB/s HBM  |  ~50 GB/s/link ICI (x3 links usable,
    we charge the single-link figure -- conservative).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (conservative single-link)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# Factors applied to the RESULT shape (post-optimization HLO prints operands
# without inline types): all-gather out bytes ~ bytes received; all-reduce
# in == out, ring moves ~2x; reduce-scatter in = out * group_size;
# all-to-all / collective-permute in == out.
_COLLECTIVES = {
    "all-gather": 1.0,
    "all-reduce": 2.0,
    "reduce-scatter": None,   # out bytes * group size
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
# one regex per op kind: " = <shape(s)> <kind>(" start/done variants included
_OP_RE = re.compile(
    r"=\s+(.*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(([^)\n]*)\)([^\n]*)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\([^\n]*?\)\s+->", re.M)
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)"
    r"(?:.*?known_trip_count\":\{\"n\":\"(\d+)\"\})?")
_CALL_RE = re.compile(r"\b(?:call|conditional)\(.*?to_apply=%?([\w\.\-]+)")


def _split_computations(hlo_text: str) -> Dict[str, str]:
    """Map computation name -> body text (between its header and final '}')."""
    comps: Dict[str, str] = {}
    headers = [(m.group(1), m.start()) for m in _COMP_RE.finditer(hlo_text)]
    for i, (name, start) in enumerate(headers):
        end = headers[i + 1][1] if i + 1 < len(headers) else len(hlo_text)
        comps[name] = hlo_text[start:end]
    return comps


def _group_size(attrs: str) -> int:
    m = _GROUPS_IOTA_RE.search(attrs)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(attrs)
    if m:
        return m.group(1).count(",") + 1
    return 1


def _direct_collectives(body: str) -> Dict[str, Tuple[int, int]]:
    out: Dict[str, Tuple[int, int]] = {}
    for m in _OP_RE.finditer(body):
        result_txt, kind, _operands_txt, attrs = m.groups()
        factor = _COLLECTIVES[kind]
        if factor is None:  # reduce-scatter: input = output * group size
            factor = float(_group_size(attrs))
        raw = _shape_bytes(result_txt)
        b, c = out.get(kind, (0, 0))
        out[kind] = (b + int(raw * factor), c + 1)
    return out


def collective_bytes(hlo_text: str, entry: Optional[str] = None) -> CollectiveStats:
    """Sum collective traffic (per-device bytes) from partitioned HLO text.

    While bodies are scaled by their trip count (from ``known_trip_count`` in
    the backend config, falling back to the largest integer constant in the
    loop condition), so collectives under ``lax.scan`` are charged once per
    iteration -- XLA's own cost analysis counts them once per *loop*.
    """
    comps = _split_computations(hlo_text)
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
        entry = m.group(1) if m else next(iter(comps), None)

    memo: Dict[str, Dict[str, Tuple[int, int]]] = {}

    def trip_count(cond_name: str, explicit: Optional[str]) -> int:
        if explicit:
            return int(explicit)
        body = comps.get(cond_name, "")
        consts = [int(c) for c in re.findall(r"constant\((\d+)\)", body)]
        return max(consts) if consts else 1

    def acc(dst: Dict[str, Tuple[int, int]], src: Dict[str, Tuple[int, int]],
            mult: int = 1) -> None:
        for k, (b, c) in src.items():
            b0, c0 = dst.get(k, (0, 0))
            dst[k] = (b0 + b * mult, c0 + c * mult)

    def walk(name: str, seen=()) -> Dict[str, Tuple[int, int]]:
        if name in memo:
            return memo[name]
        if name in seen or name not in comps:
            return {}
        body = comps[name]
        total: Dict[str, Tuple[int, int]] = {}
        acc(total, _direct_collectives(body))
        for m in _WHILE_RE.finditer(body):
            cond, wbody, tc = m.groups()
            n = trip_count(cond, tc)
            acc(total, walk(wbody, seen + (name,)), n)
        for m in _CALL_RE.finditer(body):
            acc(total, walk(m.group(1), seen + (name,)))
        memo[name] = total
        return total

    stats = CollectiveStats()
    result = walk(entry) if entry else {}
    for k, (b, c) in result.items():
        stats.bytes_by_kind[k] = b
        stats.count_by_kind[k] = c
    return stats


@dataclass
class Roofline:
    flops: float                 # per-device HLO flops
    hbm_bytes: float             # per-device bytes accessed
    coll_bytes: float            # per-device collective bytes
    model_flops: float           # 6*N*D useful flops (per device)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak the *useful* work achieves at the
        bound: (model_flops/peak) / t_bound."""
        if self.t_bound == 0:
            return 0.0
        return (self.model_flops / PEAK_FLOPS) / self.t_bound

    def to_dict(self) -> Dict[str, float]:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


# --------------------------------------------------------------------------
# Analytic per-device FLOP / HBM-traffic counters (documented napkin math).
#
# XLA's cost_analysis() counts while bodies once, which makes it useless for
# scanned layer stacks; these counters implement the standard accounting:
# matmul flops = 2*m*n*k, attention = 2 * S_ctx * h * hd per token per matmul
# (causal halves the average context), train = fwd * 3 (+1 fwd under full
# remat).  HBM traffic: every device reads its model-axis shard of all weights
# once per pass, plus activation checkpoints, optimizer state, and KV cache.
# --------------------------------------------------------------------------

def _dt_bytes(cfg) -> int:
    return 2 if cfg.dtype == "bfloat16" else 4


def _attn_flops_per_tok(cfg, ctx: float, causal: bool) -> float:
    """Score + AV flops per token with average context ``ctx``."""
    eff = ctx / 2 if causal else ctx
    if cfg.window:
        eff = min(eff, float(cfg.window))
    return 4.0 * eff * cfg.n_heads * cfg.resolved_head_dim


def _attn_proj_flops_per_tok(cfg) -> float:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    return 2.0 * (d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd
                  + cfg.n_heads * hd * d)


def _ffn_flops_per_tok(cfg) -> float:
    return 2.0 * 3 * cfg.d_model * cfg.d_ff


def _moe_flops_per_tok(cfg) -> float:
    cap = cfg.top_k * cfg.capacity_factor
    f = 2.0 * cfg.d_model * cfg.n_experts          # router
    f += 2.0 * 3 * cfg.d_model * cfg.moe_ffn * cap  # experts (padded buffers)
    if cfg.dense_residual:
        f += _ffn_flops_per_tok(cfg)
    return f


def _ssm_flops_per_tok(cfg) -> float:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    p, q = cfg.ssm_head_dim, cfg.ssd_chunk
    conv_dim = di + 2 * g * n
    f = 2.0 * d * (2 * di + 2 * g * n + h)          # in_proj
    f += 2.0 * cfg.conv_width * conv_dim            # causal conv
    f += 2.0 * q * g * n + 2.0 * q * p * h          # intra-chunk scores + y
    f += 4.0 * n * p * h                            # states + y_off
    f += 2.0 * di * d                               # out_proj
    return f


def _layer_flops_per_tok(cfg, ctx: float, causal: bool = True) -> float:
    if cfg.family in ("dense", "vlm"):
        return (_attn_proj_flops_per_tok(cfg) + _attn_flops_per_tok(cfg, ctx, causal)
                + _ffn_flops_per_tok(cfg))
    if cfg.family == "moe":
        return (_attn_proj_flops_per_tok(cfg) + _attn_flops_per_tok(cfg, ctx, causal)
                + _moe_flops_per_tok(cfg))
    if cfg.family == "ssm":
        return _ssm_flops_per_tok(cfg)
    raise ValueError(cfg.family)


def forward_flops(cfg, batch: int, seq: int) -> float:
    """Global forward flops for one pass over (batch, seq) tokens."""
    toks = float(batch * seq)
    if cfg.family == "vlm":
        seq = seq + cfg.vision_tokens
        toks = float(batch * seq)
    unembed = 2.0 * cfg.d_model * cfg.vocab * batch * seq

    if cfg.family in ("dense", "moe", "vlm"):
        per = _layer_flops_per_tok(cfg, float(seq))
        return cfg.n_layers * per * toks + unembed
    if cfg.family == "ssm":
        return cfg.n_layers * _ssm_flops_per_tok(cfg) * toks + unembed
    if cfg.family == "hybrid":
        ng = cfg.n_layers // (cfg.attn_every or cfg.n_layers)
        mamba = cfg.n_layers * _ssm_flops_per_tok(cfg) * toks
        # shared attention block (dense-layer shape) applied ng times
        dense_like = (_attn_proj_flops_per_tok(cfg)
                      + _attn_flops_per_tok(cfg, float(seq), True)
                      + _ffn_flops_per_tok(cfg))
        return mamba + ng * dense_like * toks + unembed
    if cfg.family == "encdec":
        enc_toks = float(batch * cfg.source_len)
        enc = cfg.enc_layers * (_attn_proj_flops_per_tok(cfg)
                                + _attn_flops_per_tok(cfg, float(cfg.source_len), False)
                                + _ffn_flops_per_tok(cfg)) * enc_toks
        cross = (2.0 * (cfg.d_model * cfg.n_heads * cfg.resolved_head_dim * 2)
                 + _attn_flops_per_tok(cfg, float(cfg.source_len), False))
        dec = cfg.n_layers * (_attn_proj_flops_per_tok(cfg)
                              + _attn_flops_per_tok(cfg, float(seq), True)
                              + cross + _ffn_flops_per_tok(cfg)) * toks
        return enc + dec + unembed
    raise ValueError(cfg.family)


def decode_flops(cfg, batch: int, ctx: int) -> float:
    """Global flops for ONE decode step (1 new token/seq, cache length ctx)."""
    b = float(batch)
    unembed = 2.0 * cfg.d_model * cfg.vocab * b
    if cfg.family in ("dense", "moe", "vlm"):
        per = ((_attn_proj_flops_per_tok(cfg) + _attn_flops_per_tok(cfg, ctx, False))
               + (_moe_flops_per_tok(cfg) if cfg.family == "moe"
                  else _ffn_flops_per_tok(cfg)))
        return cfg.n_layers * per * b + unembed
    if cfg.family == "ssm":
        d, di = cfg.d_model, cfg.d_inner
        g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
        per = (2.0 * d * (2 * di + 2 * g * n + h) + 4.0 * di * n + 2.0 * di * d)
        return cfg.n_layers * per * b + unembed
    if cfg.family == "hybrid":
        d, di = cfg.d_model, cfg.d_inner
        g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
        per = (2.0 * d * (2 * di + 2 * g * n + h) + 4.0 * di * n + 2.0 * di * d)
        ng = cfg.n_layers // (cfg.attn_every or cfg.n_layers)
        ring = min(ctx, cfg.window) if cfg.window else ctx
        shared = (_attn_proj_flops_per_tok(cfg)
                  + _attn_flops_per_tok(cfg, float(ring), False)
                  + _ffn_flops_per_tok(cfg))
        return (cfg.n_layers * per + ng * shared) * b + unembed
    if cfg.family == "encdec":
        per = (_attn_proj_flops_per_tok(cfg) + _attn_flops_per_tok(cfg, ctx, False)
               + 2.0 * cfg.d_model * cfg.n_heads * cfg.resolved_head_dim
               + _attn_flops_per_tok(cfg, float(cfg.source_len), False)
               + _ffn_flops_per_tok(cfg))
        return cfg.n_layers * per * b + unembed
    raise ValueError(cfg.family)


def _cache_bytes(cfg, batch: int, max_len: int) -> float:
    hd = cfg.resolved_head_dim
    if cfg.family in ("dense", "moe", "vlm"):
        return 2.0 * cfg.n_layers * batch * max_len * cfg.n_kv_heads * hd * 2
    if cfg.family == "ssm":
        di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
        conv = cfg.n_layers * batch * (cfg.conv_width - 1) * (di + 2 * g * n) * 2
        ssm = cfg.n_layers * batch * cfg.ssm_heads * n * cfg.ssm_head_dim * 4
        return float(conv + ssm)
    if cfg.family == "hybrid":
        base = _cache_bytes(cfg.replace(family="ssm"), batch, max_len)
        ng = cfg.n_layers // (cfg.attn_every or cfg.n_layers)
        ring = min(max_len, cfg.window) if cfg.window else max_len
        return base + 2.0 * ng * batch * ring * cfg.n_kv_heads * hd * 2
    if cfg.family == "encdec":
        self_c = 2.0 * cfg.n_layers * batch * max_len * cfg.n_kv_heads * hd * 2
        cross = 2.0 * cfg.n_layers * batch * cfg.source_len * cfg.n_kv_heads * hd * 2
        return self_c + cross
    raise ValueError(cfg.family)


def analytic_stats(cfg, shape, n_data: int, n_model: int,
                   accum_steps: int = 1) -> Dict[str, float]:
    """Per-device analytic (flops, hbm_bytes) for one step of this cell."""
    b, s = shape.global_batch, shape.seq_len
    batch_sharded = (b % n_data == 0)
    flop_div = n_model * (n_data if batch_sharded else 1)
    pbytes = cfg.param_count() * _dt_bytes(cfg)
    p_loc = pbytes / (n_data * n_model)
    p_gathered = pbytes / n_model          # per-device weight reads per pass
    b_loc = b // n_data if batch_sharded else b
    act = _dt_bytes(cfg)

    if shape.kind == "train":
        fwd = forward_flops(cfg, b, s)
        mult = 4.0 if cfg.remat == "full" else 3.0
        flops = fwd * mult / flop_div
        # weights: fwd + 2x bwd + recompute reads; optimizer: p,m,v r/w; grads
        opt_b = 4 if cfg.opt_state_dtype == "float32" else 2
        n_loc = cfg.param_count() / (n_data * n_model)   # local param count
        weight_traffic = p_gathered * mult * max(1, accum_steps)
        opt_traffic = (p_loc * 2            # param read + write
                       + n_loc * opt_b * 4  # m, v read + write
                       + n_loc * 4 * 2)     # f32 grads write + read
        ckpt = cfg.n_layers * b_loc * s * cfg.d_model * act * 2
        hbm = weight_traffic + opt_traffic + ckpt
        return {"flops": flops, "hbm_bytes": hbm}

    if shape.kind == "prefill":
        fwd = forward_flops(cfg, b, s)
        flops = fwd / flop_div
        cache = _cache_bytes(cfg, b, s) / (max(1, n_data if batch_sharded else 1)
                                           * n_model)
        acts = cfg.n_layers * b_loc * s * cfg.d_model * act * 2
        hbm = p_gathered + acts + cache
        return {"flops": flops, "hbm_bytes": hbm}

    # decode
    flops = decode_flops(cfg, b, s) / flop_div
    cache = _cache_bytes(cfg, b, s) / (max(1, n_data if batch_sharded else 1)
                                       * n_model)
    hbm = p_gathered + cache   # read all local weights + full cache per step
    return {"flops": flops, "hbm_bytes": hbm}


def model_flops_per_device(cfg, shape, n_devices: int) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) for train; 2*N*D forward-only.
    D = tokens processed by the step; per-device share."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        total = 2.0 * n * tokens
    return total / n_devices
