"""Production meshes.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") -- the
leading "pod" axis carries DCN-side data parallelism; "data"/"model" stay
within a pod's ICI domain.

Defined as a FUNCTION so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS for 512 host devices *before* jax init;
smoke tests and benches see the real single CPU device).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)} "
            "(dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            "=512 before any jax import)")
    return jax.sharding.Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_host_mesh(axes: Tuple[str, ...] = ("data",)):
    """Trivial mesh over whatever devices exist (CPU smoke tests)."""
    import jax

    devices = np.asarray(jax.devices())
    shape = (len(devices),) + (1,) * (len(axes) - 1)
    return jax.sharding.Mesh(devices.reshape(shape), axes)
