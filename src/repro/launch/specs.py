"""ShapeDtypeStruct stand-ins + step builders for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns the exact abstract inputs the step
function takes -- weak-type-correct, shardable, never allocated.  ``make_cell``
packages (step_fn, arg_sds, in_shardings) for the dry-run: train shapes lower
``train_step``; prefill shapes lower ``prefill``; decode shapes lower one
``serve_step`` (a single new token against a seq_len KV cache).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import Shape
from repro.models.registry import get_family
from repro.parallel.sharding import (DEFAULT_RULES, SERVE_RULES, ShardingRules,
                                     logical_to_spec, tree_shardings, use_mesh)
from repro.train.optim import AdamWConfig
from repro.train.trainer import TrainState, init_state, make_train_step, state_specs

SDS = jax.ShapeDtypeStruct


def _batch_axes(mesh, global_batch: int):
    """Batch logical axes: shard over (pod, data) when divisible, else
    replicate (long_500k has global_batch=1)."""
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return ("batch",) if global_batch % n == 0 else (None,)


def token_specs(cfg, shape: Shape) -> Dict[str, SDS]:
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": SDS((b, s), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = SDS((b, s), jnp.int32)
    if cfg.family == "vlm":
        specs["vision_embeds"] = SDS((b, cfg.vision_tokens, cfg.d_model),
                                     jnp.dtype(cfg.dtype))
    if cfg.family == "encdec":
        specs["frames"] = SDS((b, cfg.source_len, cfg.d_model),
                              jnp.dtype(cfg.dtype))
    return specs


def input_specs(cfg, shape: Shape) -> Dict[str, Any]:
    """All abstract inputs for this cell's step function."""
    fam = get_family(cfg)
    out: Dict[str, Any] = {"batch": token_specs(cfg, shape)}
    if shape.kind == "train":
        ocfg = AdamWConfig(state_dtype=cfg.opt_state_dtype)
        out["state"] = jax.eval_shape(
            lambda: init_state(jax.random.PRNGKey(0), cfg, ocfg))
    else:
        out["params"] = jax.eval_shape(
            lambda: fam.init(jax.random.PRNGKey(0), cfg))
        max_len = shape.seq_len + (cfg.vision_tokens if cfg.family == "vlm" else 0)
        out["cache"] = jax.eval_shape(
            lambda: fam.init_cache(cfg, shape.global_batch, max_len))
        if shape.kind == "decode":
            out["token"] = SDS((shape.global_batch, 1), jnp.int32)
    return out


@dataclass
class Cell:
    """One lowered-compile unit: fn(*args) with per-arg shardings."""
    name: str
    fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...]


def make_cell(cfg, shape: Shape, mesh,
              rules: Optional[ShardingRules] = None,
              accum_steps: int = 1,
              compress_grads: bool = False) -> Cell:
    fam = get_family(cfg)
    specs = input_specs(cfg, shape)
    baxes = _batch_axes(mesh, shape.global_batch)
    if baxes == (None,) and rules is not None:
        rules = rules.with_(batch=None)       # replicate tiny batches everywhere
    elif baxes == (None,):
        rules = (DEFAULT_RULES if shape.kind == "train" else SERVE_RULES
                 ).with_(batch=None)

    def batch_shardings(batch_specs):
        return {
            k: NamedSharding(mesh, logical_to_spec(
                baxes + (None,) * (v.ndim - 1), mesh,
                rules or DEFAULT_RULES))
            for k, v in batch_specs.items()
        }

    if shape.kind == "train":
        r = rules or DEFAULT_RULES
        step = make_train_step(cfg, accum_steps=accum_steps,
                               compress_grads=compress_grads)

        def fn(state, batch):
            with use_mesh(mesh, r):
                return step(state, batch)

        st_sh = tree_shardings(mesh, state_specs(cfg), r)
        return Cell(
            name=f"{cfg.name}:{shape.name}",
            fn=fn,
            args=(specs["state"], specs["batch"]),
            in_shardings=(st_sh, batch_shardings(specs["batch"])),
            donate_argnums=(0,),
        )

    r = rules or SERVE_RULES
    p_sh = tree_shardings(mesh, fam.param_specs(cfg), r)
    c_sh = tree_shardings(mesh, fam.cache_specs(cfg), r)

    if shape.kind == "prefill":
        def fn(params, batch, cache):
            with use_mesh(mesh, r):
                return fam.prefill(params, cfg, batch, cache)

        return Cell(
            name=f"{cfg.name}:{shape.name}",
            fn=fn,
            args=(specs["params"], specs["batch"], specs["cache"]),
            in_shardings=(p_sh, batch_shardings(specs["batch"]), c_sh),
            donate_argnums=(2,),
        )

    # decode: one new token against a seq_len KV cache
    def fn(params, token, cache):
        with use_mesh(mesh, r):
            return fam.decode_step(params, cfg, token, cache)

    tok_sh = NamedSharding(mesh, logical_to_spec(baxes + (None,), mesh,
                                                 r))
    return Cell(
        name=f"{cfg.name}:{shape.name}",
        fn=fn,
        args=(specs["params"], specs["token"], specs["cache"]),
        in_shardings=(p_sh, tok_sh, c_sh),
        donate_argnums=(2,),
    )
