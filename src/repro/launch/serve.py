"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Batched request serving with the slot-based continuous-batching engine:
admits synthetic requests at a configurable rate, decodes until drained,
reports latency percentiles + throughput.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.serve import Engine, Request, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    eng = Engine(cfg, ServeConfig(max_slots=args.slots, max_len=args.max_len),
                 key=jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    reqs = []
    t0 = time.monotonic()
    for i in range(args.requests):
        r = Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, args.prompt_len,
                                        dtype=np.int32),
                    max_new_tokens=args.max_new_tokens,
                    temperature=args.temperature)
        reqs.append(r)
        eng.submit(r)
    eng.run_until_drained()
    wall = time.monotonic() - t0

    ttfts = sorted(r.t_first - r.t_submit for r in reqs)
    lats = sorted(r.t_done - r.t_submit for r in reqs)
    total_tokens = sum(len(r.out_tokens) for r in reqs)
    pct = lambda xs, p: xs[min(len(xs) - 1, int(p * len(xs)))]
    print(f"requests={len(reqs)} tokens={total_tokens} wall={wall:.2f}s "
          f"tok/s={total_tokens / wall:,.1f}")
    print(f"ttft p50={pct(ttfts, .5) * 1e3:.1f}ms p95={pct(ttfts, .95) * 1e3:.1f}ms | "
          f"latency p50={pct(lats, .5) * 1e3:.1f}ms p95={pct(lats, .95) * 1e3:.1f}ms")


if __name__ == "__main__":
    main()
