"""Decoder-only transformer LM: dense, MoE (incl. dense-residual / Arctic
style), and VLM (precomputed patch-embedding prefix) families.

Layers are stacked along a leading axis and driven by ``jax.lax.scan`` so the
compiled HLO is one layer deep regardless of depth -- essential for the
40-cell x 2-mesh dry-run grid on a single-core host, and standard practice on
real TPU pods (MaxText does the same).

Public surface (used by registry/train/serve):
    init(key, cfg)                      -> params
    param_specs(cfg)                    -> logical partition-spec tree
    forward(params, cfg, tokens, ...)   -> (hidden, aux, new_cache)
    loss_fn(params, cfg, batch)         -> scalar loss
    init_cache(cfg, b, max_len)         -> stacked KV cache
    prefill / decode_step
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain

from . import layers as L

Params = Dict[str, Any]


# ------------------------------------------------------------------ params
def _layer_init(key, cfg) -> Params:
    ka, kf, km = jax.random.split(key, 3)
    p: Params = {
        "ln1": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "ln2": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "attn": L.attention_init(ka, cfg),
    }
    if cfg.family == "moe" or (cfg.family == "hybrid" and cfg.n_experts):
        p["moe"] = L.moe_init(km, cfg)
        if cfg.dense_residual:
            p["ffn"] = L.swiglu_init(kf, cfg.d_model, cfg.d_ff, cfg.n_layers,
                                     jnp.dtype(cfg.dtype))
    else:
        p["ffn"] = L.swiglu_init(kf, cfg.d_model, cfg.d_ff, cfg.n_layers,
                                 jnp.dtype(cfg.dtype))
    return p


def _layer_specs(cfg) -> Params:
    p: Params = {
        "ln1": {"scale": (None,)},
        "ln2": {"scale": (None,)},
        "attn": L.attention_specs(cfg),
    }
    if cfg.family == "moe":
        p["moe"] = L.moe_specs()
        if cfg.dense_residual:
            p["ffn"] = L.swiglu_specs()
    else:
        p["ffn"] = L.swiglu_specs()
    return p


def init(key, cfg) -> Params:
    ke, kl = jax.random.split(key)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    stacked = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    return {
        "embed": L.embed_init(ke, cfg),
        "layers": stacked,
        "ln_f": L.rmsnorm_init(cfg.d_model, jnp.float32),
    }


def param_specs(cfg) -> Params:
    lay = _layer_specs(cfg)
    stacked = jax.tree.map(
        lambda spec: (None,) + tuple(spec),
        lay,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    return {
        "embed": L.embed_specs(cfg),
        "layers": stacked,
        "ln_f": {"scale": (None,)},
    }


# ----------------------------------------------------------------- forward
def _block(p: Params, cfg, h, positions, cache, causal=True):
    a, new_cache = L.attention(p["attn"], cfg, L.rmsnorm(p["ln1"], h, cfg.norm_eps),
                               positions, causal=causal, cache=cache)
    h = h + a
    x2 = L.rmsnorm(p["ln2"], h, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in p:
        mo, aux = L.moe(p["moe"], cfg, x2)
        h = h + mo
        if "ffn" in p:  # arctic dense residual, parallel branch
            h = h + L.swiglu(p["ffn"], x2)
    else:
        h = h + L.swiglu(p["ffn"], x2)
    return h, aux, new_cache


def forward(
    params: Params,
    cfg,
    tokens: jnp.ndarray,
    positions: Optional[jnp.ndarray] = None,
    prefix_embeds: Optional[jnp.ndarray] = None,
    cache: Optional[Params] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Params]]:
    """Returns (hidden (B,S,d) after final norm, aux_loss, new_cache)."""
    h = L.embed_lookup(params["embed"], tokens)
    if prefix_embeds is not None:  # VLM: prepend vision tokens
        h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
    b, s, _ = h.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def block(lp, h, lc):
        return _block(lp, cfg, h, positions, lc)

    if cfg.remat == "full":
        block = jax.checkpoint(block)
    elif cfg.remat == "dots":
        block = jax.checkpoint(block, policy=jax.checkpoint_policies.checkpoint_dots)

    def scan_fn(carry, xs):
        h = carry
        if cache is not None:
            lp, lc = xs
            h, aux, nc = block(lp, h, lc)
            return h, (aux, nc)
        h, aux, _ = block(xs, h, None)
        return h, aux

    if cache is not None:
        h, (auxs, new_cache) = jax.lax.scan(scan_fn, h, (params["layers"], cache))
    else:
        h, auxs = jax.lax.scan(scan_fn, h, params["layers"])
        new_cache = None
    h = L.rmsnorm(params["ln_f"], h, cfg.norm_eps)
    return h, jnp.sum(auxs), new_cache


# -------------------------------------------------------------------- train
def loss_fn(params: Params, cfg, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """batch: tokens (B,S), labels (B,S) [, vision_embeds (B,V,d)]."""
    prefix = batch.get("vision_embeds")
    h, aux, _ = forward(params, cfg, batch["tokens"], prefix_embeds=prefix)
    if prefix is not None:
        h = h[:, prefix.shape[1]:]  # loss on text positions only
    loss = L.chunked_cross_entropy(h, params["embed"], batch["labels"],
                                   cfg.loss_chunk)
    return loss + 0.01 * aux


# -------------------------------------------------------------------- serve
def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    hd = cfg.resolved_head_dim
    kv = cfg.n_kv_heads
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, kv, hd), dtype),
        "len": jnp.zeros((cfg.n_layers,), jnp.int32),
    }


def cache_specs(cfg) -> Params:
    return {
        "k": (None, "batch", "kvseq", "kv", None),
        "v": (None, "batch", "kvseq", "kv", None),
        "len": (),
    }


def prefill(params: Params, cfg, tokens: jnp.ndarray, cache: Params,
            prefix_embeds: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, Params]:
    """Run the full prompt, fill the cache, return last-token logits."""
    h, _, new_cache = forward(params, cfg, tokens,
                              prefix_embeds=prefix_embeds, cache=cache)
    logits = L.unembed(params["embed"], h[:, -1:])
    return logits, new_cache


def decode_step(params: Params, cfg, token: jnp.ndarray, cache: Params
                ) -> Tuple[jnp.ndarray, Params]:
    """One decode step: token (B,1) + cache -> (logits (B,1,V), new cache)."""
    b = token.shape[0]
    pos = jnp.broadcast_to(cache["len"][0][None, None], (b, 1)).astype(jnp.int32)
    h, _, new_cache = forward(params, cfg, token, positions=pos, cache=cache)
    logits = L.unembed(params["embed"], h)
    return logits, new_cache
