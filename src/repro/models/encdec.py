"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The conv/mel frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, source_len, d_model) for the encoder.  The
encoder is a non-causal transformer stack; the decoder interleaves causal
self-attention, cross-attention to the encoder output, and an MLP.

Norm/MLP conventions follow the shared layer library (RMSNorm + SwiGLU); the
shape grid -- which is what the roofline reads -- matches the assigned config.
Cross-attention K/V are computed once from the encoder output and cached, so
decode touches the source only through the (B, S_src, kv, hd) cache.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain, weight

from . import layers as L

Params = Dict[str, Any]


# ------------------------------------------------------------------ params
def _enc_layer_init(key, cfg) -> Params:
    ka, kf = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "ln2": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "attn": L.attention_init(ka, cfg),
        "ffn": L.swiglu_init(kf, cfg.d_model, cfg.d_ff, cfg.n_layers,
                             jnp.dtype(cfg.dtype)),
    }


def _dec_layer_init(key, cfg) -> Params:
    ka, kx, kf = jax.random.split(key, 3)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "ln2": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "ln3": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "attn": L.attention_init(ka, cfg),
        "xattn": L.attention_init(kx, cfg),
        "ffn": L.swiglu_init(kf, cfg.d_model, cfg.d_ff, cfg.n_layers,
                             jnp.dtype(cfg.dtype)),
    }


def init(key, cfg) -> Params:
    ke, kenc, kdec = jax.random.split(key, 3)
    enc = jax.vmap(lambda k: _enc_layer_init(k, cfg))(
        jax.random.split(kenc, cfg.enc_layers))
    dec = jax.vmap(lambda k: _dec_layer_init(k, cfg))(
        jax.random.split(kdec, cfg.n_layers))
    return {"embed": L.embed_init(ke, cfg), "enc": enc, "dec": dec,
            "ln_enc": L.rmsnorm_init(cfg.d_model, jnp.float32),
            "ln_f": L.rmsnorm_init(cfg.d_model, jnp.float32)}


def param_specs(cfg) -> Params:
    enc = {"ln1": {"scale": (None,)}, "ln2": {"scale": (None,)},
           "attn": L.attention_specs(cfg), "ffn": L.swiglu_specs()}
    dec = {"ln1": {"scale": (None,)}, "ln2": {"scale": (None,)},
           "ln3": {"scale": (None,)}, "attn": L.attention_specs(cfg),
           "xattn": L.attention_specs(cfg), "ffn": L.swiglu_specs()}
    st = lambda t: jax.tree.map(lambda s: (None,) + tuple(s), t,
                                is_leaf=lambda x: isinstance(x, tuple))
    return {"embed": L.embed_specs(cfg), "enc": st(enc), "dec": st(dec),
            "ln_enc": {"scale": (None,)}, "ln_f": {"scale": (None,)}}


# ----------------------------------------------------------------- encoder
def encode(params: Params, cfg, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, S_src, d) precomputed embeddings (stub frontend)."""
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    h = constrain(frames.astype(jnp.dtype(cfg.dtype)), ("batch", None, "fsdp"))

    def block(lp, h):
        a, _ = L.attention(lp["attn"], cfg, L.rmsnorm(lp["ln1"], h, cfg.norm_eps),
                           positions, causal=False)
        h = h + a
        return h + L.swiglu(lp["ffn"], L.rmsnorm(lp["ln2"], h, cfg.norm_eps)), None

    if cfg.remat in ("full", "dots"):
        block = jax.checkpoint(block)
    h, _ = jax.lax.scan(lambda c, lp: block(lp, c), h, params["enc"])
    return L.rmsnorm(params["ln_enc"], h, cfg.norm_eps)


def cross_kv(params: Params, cfg, enc_out: jnp.ndarray) -> Params:
    """Precompute per-decoder-layer cross-attention K/V from encoder output."""
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim

    def one(lp):
        k = (enc_out @ weight(lp["xattn"]["wk"], ("fsdp", "tensor"))).reshape(
            b, s, cfg.n_kv_heads, hd)
        v = (enc_out @ weight(lp["xattn"]["wv"], ("fsdp", "tensor"))).reshape(
            b, s, cfg.n_kv_heads, hd)
        return {"k": k, "v": v}

    return jax.vmap(one)(params["dec"])  # leading layer axis


# ----------------------------------------------------------------- decoder
def _dec_block(lp, cfg, h, positions, xkv, cache):
    a, nc = L.attention(lp["attn"], cfg, L.rmsnorm(lp["ln1"], h, cfg.norm_eps),
                        positions, causal=True, cache=cache)
    h = h + a
    x, _ = L.attention(lp["xattn"], cfg, L.rmsnorm(lp["ln2"], h, cfg.norm_eps),
                       positions, causal=False, xattn_kv=(xkv["k"], xkv["v"]))
    h = h + x
    h = h + L.swiglu(lp["ffn"], L.rmsnorm(lp["ln3"], h, cfg.norm_eps))
    return h, nc


def decode(params, cfg, tokens, xkv, positions=None, cache=None):
    h = L.embed_lookup(params["embed"], tokens)
    b, s, _ = h.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    block = lambda lp, h, xk, lc: _dec_block(lp, cfg, h, positions, xk, lc)
    if cfg.remat in ("full", "dots"):
        block = jax.checkpoint(block)

    def scan_fn(h, xs):
        if cache is not None:
            lp, xk, lc = xs
            h, nc = block(lp, h, xk, lc)
            return h, nc
        lp, xk = xs
        h, _ = block(lp, h, xk, None)
        return h, None

    if cache is not None:
        h, new_cache = jax.lax.scan(scan_fn, h, (params["dec"], xkv, cache))
    else:
        h, _ = jax.lax.scan(scan_fn, h, (params["dec"], xkv))
        new_cache = None
    return L.rmsnorm(params["ln_f"], h, cfg.norm_eps), new_cache


# -------------------------------------------------------------------- train
def loss_fn(params, cfg, batch):
    """batch: frames (B,S_src,d), tokens (B,S), labels (B,S)."""
    enc_out = encode(params, cfg, batch["frames"])
    xkv = cross_kv(params, cfg, enc_out)
    h, _ = decode(params, cfg, batch["tokens"], xkv)
    return L.chunked_cross_entropy(h, params["embed"], batch["labels"],
                                   cfg.loss_chunk)


# -------------------------------------------------------------------- serve
def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd), dtype),
        "len": jnp.zeros((cfg.n_layers,), jnp.int32),
        "xkv": {
            "k": jnp.zeros((cfg.n_layers, batch, cfg.source_len,
                            cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((cfg.n_layers, batch, cfg.source_len,
                            cfg.n_kv_heads, hd), dtype),
        },
    }


def cache_specs(cfg) -> Params:
    return {
        "k": (None, "batch", "kvseq", "kv", None),
        "v": (None, "batch", "kvseq", "kv", None),
        "len": (),
        "xkv": {"k": (None, "batch", None, "kv", None),
                "v": (None, "batch", None, "kv", None)},
    }


def prefill(params, cfg, tokens, cache, frames=None):
    """Encode the source, cache cross-KV, run the prompt through the decoder."""
    enc_out = encode(params, cfg, frames)
    xkv = cross_kv(params, cfg, enc_out)
    sc = {"k": cache["k"], "v": cache["v"], "len": cache["len"]}
    per_layer = jax.tree.map(lambda a: a, sc)
    h, new_sc = decode(params, cfg, tokens, xkv, cache=per_layer)
    new_cache = {**new_sc, "xkv": jax.tree.map(
        lambda a: a.astype(cache["k"].dtype), xkv)}
    return L.unembed(params["embed"], h[:, -1:]), new_cache


def decode_step(params, cfg, token, cache):
    b = token.shape[0]
    pos = jnp.broadcast_to(cache["len"][0][None, None], (b, 1)).astype(jnp.int32)
    sc = {"k": cache["k"], "v": cache["v"], "len": cache["len"]}
    h, new_sc = decode(params, cfg, token, cache["xkv"], positions=pos, cache=sc)
    new_cache = {**new_sc, "xkv": cache["xkv"]}
    return L.unembed(params["embed"], h), new_cache
