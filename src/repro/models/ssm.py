"""Mamba2 (SSD -- state-space duality) language model [arXiv:2405.21060].

Implements the chunked SSD algorithm: within-chunk attention-like einsums
(quadratic in the chunk length only) + an inter-chunk state recurrence, which
is exactly the block decomposition the paper derives from the duality.  On
TPU the within-chunk part is the MXU hot spot -- the Pallas kernel
(`kernels/ssd_scan.py`) tiles it for VMEM; this module is the pure-jnp
implementation that doubles as the kernel oracle.

Decode is O(1): a (heads, state, head_dim) recurrent state + a small causal
conv ring buffer -- which is why the SSM archs run the ``long_500k`` shape.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain, weight

from . import layers as L

Params = Dict[str, Any]


# ---------------------------------------------------------------- SSD core
def segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k] (i >= j)."""
    t = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jnp.ndarray,      # (B, S, H, P)  -- already multiplied by dt
    dA: jnp.ndarray,     # (B, S, H)     -- dt * A (negative)
    Bm: jnp.ndarray,     # (B, S, G, N)
    Cm: jnp.ndarray,     # (B, S, G, N)
    chunk: int = 256,
    initial_state: Optional[jnp.ndarray] = None,  # (B, H, N, P)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan. Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    b, s, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    r = h // g
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s

    def pad3(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))

    xp, dAp, Bp, Cp = pad3(x), pad3(dA), pad3(Bm), pad3(Cm)
    xp = xp.reshape(b, nc, q, h, p)
    dAp = dAp.reshape(b, nc, q, h)
    Bp = Bp.reshape(b, nc, q, g, n)
    Cp = Cp.reshape(b, nc, q, g, n)

    dA_cs = jnp.cumsum(dAp, axis=2)                      # (b,nc,q,h)
    # --- intra-chunk (quadratic in q) ---
    Lmat = jnp.exp(segsum(jnp.moveaxis(dAp, 3, 2)))      # (b,nc,h,q,q)
    Lmat = jnp.where(jnp.isfinite(Lmat), Lmat, 0.0)
    scores = jnp.einsum("bcigp,bcjgp->bcgij", Cp, Bp)    # (b,nc,g,q,q) p==n here
    scores = scores.reshape(b, nc, g, 1, q, q)
    Lh = Lmat.reshape(b, nc, g, r, q, q)
    y_diag = jnp.einsum("bcgrij,bcjgrp->bcigrp",
                        scores * Lh,
                        xp.reshape(b, nc, q, g, r, p))

    # --- chunk states ---
    decay_last = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)    # (b,nc,q,h)
    states = jnp.einsum(
        "bcjgn,bcjgrp->bcgrnp",
        Bp,
        xp.reshape(b, nc, q, g, r, p) * decay_last.reshape(b, nc, q, g, r, 1),
    )                                                     # (b,nc,g,r,n,p)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])             # (b,nc,h)
    s0 = (jnp.zeros((b, h, n, p), x.dtype) if initial_state is None
          else initial_state.astype(x.dtype))

    def scan_fn(prev, inp):
        st, dec = inp                                      # (b,g,r,n,p), (b,h)
        decr = dec.reshape(b, g, r, 1, 1)
        new = prev * decr + st
        return new, prev                                   # emit state *before* chunk

    states_hr = states
    final, prevs = jax.lax.scan(
        scan_fn,
        s0.reshape(b, g, r, n, p),
        (jnp.moveaxis(states_hr, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prevs = jnp.moveaxis(prevs, 0, 1)                      # (b,nc,g,r,n,p)

    # --- off-diagonal contribution ---
    in_decay = jnp.exp(dA_cs)                              # (b,nc,q,h)
    y_off = jnp.einsum("bcign,bcgrnp->bcigrp", Cp, prevs)
    y_off = y_off * in_decay.reshape(b, nc, q, g, r, 1)

    y = (y_diag + y_off).reshape(b, nc, q, h, p)
    y = y.reshape(b, nc * q, h, p)[:, :s]
    return y, final.reshape(b, h, n, p)


def ssd_decode_step(state, x, dA, Bm, Cm):
    """O(1) recurrent update. state (B,H,N,P); x (B,H,P) pre-multiplied by dt;
    dA (B,H); Bm/Cm (B,G,N). Returns (y (B,H,P), new_state)."""
    b, h, n, p = state.shape
    g = Bm.shape[1]
    r = h // g
    dec = jnp.exp(dA)[..., None, None]                     # (B,H,1,1)
    Bh = jnp.repeat(Bm, r, axis=1)                         # (B,H,N)
    Ch = jnp.repeat(Cm, r, axis=1)
    new = state * dec + Bh[..., :, None] * x[..., None, :]
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new)
    return y, new


# ------------------------------------------------------------- Mamba block
def mamba_init(key, cfg) -> Params:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h, w = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.conv_width
    conv_dim = di + 2 * g * n
    k1, k2, k3 = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)
    s = 1.0 / math.sqrt(d)
    return {
        "in_proj": (jax.random.normal(k1, (d, 2 * di + 2 * g * n + h)) * s).astype(dt),
        "conv_w": (jax.random.normal(k2, (w, conv_dim)) * (1.0 / math.sqrt(w))).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": L.rmsnorm_init(di, jnp.float32),
        "out_proj": (jax.random.normal(k3, (di, d)) * (1.0 / math.sqrt(di))
                     / math.sqrt(2 * cfg.n_layers)).astype(dt),
    }


def mamba_specs(cfg) -> Params:
    return {
        "in_proj": ("fsdp", "tensor"),
        "conv_w": (None, "tensor"),
        "conv_b": ("tensor",),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm": {"scale": (None,)},
        "out_proj": ("tensor", "fsdp"),
    }


def _split_proj(cfg, zxbcdt):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n :]
    return z, xBC, dt


def _causal_conv(xBC, w, b, state=None):
    """Depthwise causal conv, width W. xBC (B,S,C); w (W,C).
    state: (B, W-1, C) history for decode. Returns (out, new_state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((xBC.shape[0], width - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = state.astype(xBC.dtype)
    xfull = jnp.concatenate([pad, xBC], axis=1)            # (B, S+W-1, C)
    out = sum(xfull[:, i : i + xBC.shape[1]] * w[i] for i in range(width))
    new_state = xfull[:, -(width - 1):]
    return jax.nn.silu(out + b), new_state


def mamba_block(p: Params, cfg, x: jnp.ndarray,
                cache: Optional[Params] = None
                ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """x: (B,S,d) -> (B,S,d). cache: {"conv": (B,W-1,C), "ssm": (B,H,N,P)}."""
    b, s, d = x.shape
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_head_dim

    zxbcdt = constrain(x @ weight(p["in_proj"], ("fsdp", "tensor")),
                       ("batch", None, "tensor"))
    z, xBC, dtp = _split_proj(cfg, zxbcdt)
    conv_state = cache["conv"] if cache is not None else None
    xBC, new_conv = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)

    xin = xBC[..., :di].reshape(b, s, h, pdim)
    Bm = xBC[..., di : di + g * n].reshape(b, s, g, n)
    Cm = xBC[..., di + g * n :].reshape(b, s, g, n)
    dt = jax.nn.softplus(dtp.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                        # (H,)
    dA = dt * A

    xdt = xin.astype(jnp.float32) * dt[..., None]
    if cache is not None and s == 1:
        y, new_ssm = ssd_decode_step(
            cache["ssm"].astype(jnp.float32), xdt[:, 0], dA[:, 0],
            Bm[:, 0].astype(jnp.float32), Cm[:, 0].astype(jnp.float32))
        y = y[:, None]
    else:
        init_state = cache["ssm"].astype(jnp.float32) if cache is not None else None
        if cfg.use_flash:  # route the intra-chunk hot spot through Pallas
            from repro.kernels import ops as kops

            y, new_ssm = kops.ssd_chunked_pallas(
                xdt, dA, Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                chunk=cfg.ssd_chunk, initial_state=init_state)
        else:
            y, new_ssm = ssd_chunked(
                xdt, dA, Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                chunk=cfg.ssd_chunk, initial_state=init_state)

    y = y + xin.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = L.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = constrain(y @ weight(p["out_proj"], ("tensor", "fsdp")),
                    ("batch", "seq", "fsdp"))
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": new_ssm.astype(cache["ssm"].dtype)}
    return out, new_cache


# ------------------------------------------------------------------- model
def _layer_init(key, cfg) -> Params:
    return {"ln": L.rmsnorm_init(cfg.d_model, jnp.float32),
            "mamba": mamba_init(key, cfg)}


def init(key, cfg) -> Params:
    ke, kl = jax.random.split(key)
    stacked = jax.vmap(lambda k: _layer_init(k, cfg))(jax.random.split(kl, cfg.n_layers))
    return {"embed": L.embed_init(ke, cfg), "layers": stacked,
            "ln_f": L.rmsnorm_init(cfg.d_model, jnp.float32)}


def param_specs(cfg) -> Params:
    lay = {"ln": {"scale": (None,)}, "mamba": mamba_specs(cfg)}
    stacked = jax.tree.map(lambda s: (None,) + tuple(s), lay,
                           is_leaf=lambda x: isinstance(x, tuple))
    return {"embed": L.embed_specs(cfg), "layers": stacked,
            "ln_f": {"scale": (None,)}}


def forward(params, cfg, tokens, cache=None):
    h = L.embed_lookup(params["embed"], tokens)

    def block(lp, h, lc):
        o, nc = mamba_block(lp["mamba"], cfg, L.rmsnorm(lp["ln"], h, cfg.norm_eps), lc)
        return h + o, nc

    if cfg.remat == "full":
        block = jax.checkpoint(block)
    elif cfg.remat == "dots":
        block = jax.checkpoint(block, policy=jax.checkpoint_policies.checkpoint_dots)

    def scan_fn(h, xs):
        if cache is not None:
            lp, lc = xs
            h, nc = block(lp, h, lc)
            return h, nc
        h, _ = block(xs, h, None)
        return h, None

    if cache is not None:
        h, new_cache = jax.lax.scan(scan_fn, h, (params["layers"], cache))
    else:
        h, _ = jax.lax.scan(scan_fn, h, params["layers"])
        new_cache = None
    return L.rmsnorm(params["ln_f"], h, cfg.norm_eps), new_cache


def loss_fn(params, cfg, batch):
    h, _ = forward(params, cfg, batch["tokens"])
    return L.chunked_cross_entropy(h, params["embed"], batch["labels"], cfg.loss_chunk)


def init_cache(cfg, batch: int, max_len: int = 0, dtype=jnp.bfloat16) -> Params:
    """SSM cache is O(1) in sequence length (max_len unused -- API parity)."""
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    conv_dim = di + 2 * g * n
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, cfg.ssm_heads, n, cfg.ssm_head_dim),
                         jnp.float32),
    }


def cache_specs(cfg) -> Params:
    return {"conv": (None, "batch", None, "tensor"),
            "ssm": (None, "batch", "tensor", None, None)}


def prefill(params, cfg, tokens, cache):
    h, new_cache = forward(params, cfg, tokens, cache=cache)
    return L.unembed(params["embed"], h[:, -1:]), new_cache


def decode_step(params, cfg, token, cache):
    h, new_cache = forward(params, cfg, token, cache=cache)
    return L.unembed(params["embed"], h), new_cache
