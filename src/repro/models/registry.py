"""Family dispatch: one uniform surface over all model families.

``get_family(cfg)`` returns a ``Family`` namespace with
    init, param_specs, loss_fn, init_cache, cache_specs, prefill, decode_step
so train/serve/launch code is family-agnostic.  VLM and encdec families take
extra stub-frontend inputs (vision/frame embeddings) through the batch dict.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp

from . import encdec, hybrid, ssm, transformer

__all__ = ["Family", "get_family"]


@dataclass(frozen=True)
class Family:
    name: str
    init: Callable
    param_specs: Callable
    loss_fn: Callable
    init_cache: Callable
    cache_specs: Callable
    prefill: Callable
    decode_step: Callable


def _tfm_prefill(params, cfg, batch, cache):
    return transformer.prefill(params, cfg, batch["tokens"], cache,
                               prefix_embeds=batch.get("vision_embeds"))


def _ssm_prefill(params, cfg, batch, cache):
    return ssm.prefill(params, cfg, batch["tokens"], cache)


def _hyb_prefill(params, cfg, batch, cache):
    return hybrid.prefill(params, cfg, batch["tokens"], cache)


def _enc_prefill(params, cfg, batch, cache):
    return encdec.prefill(params, cfg, batch["tokens"], cache,
                          frames=batch["frames"])


_FAMILIES: Dict[str, Family] = {}
for fam, mod, pre in (
    ("dense", transformer, _tfm_prefill),
    ("moe", transformer, _tfm_prefill),
    ("vlm", transformer, _tfm_prefill),
    ("ssm", ssm, _ssm_prefill),
    ("hybrid", hybrid, _hyb_prefill),
    ("encdec", encdec, _enc_prefill),
):
    _FAMILIES[fam] = Family(
        name=fam,
        init=mod.init,
        param_specs=mod.param_specs,
        loss_fn=mod.loss_fn,
        init_cache=mod.init_cache,
        cache_specs=mod.cache_specs,
        prefill=pre,
        decode_step=mod.decode_step,
    )


def get_family(cfg) -> Family:
    try:
        return _FAMILIES[cfg.family]
    except KeyError:
        raise ValueError(f"unknown model family {cfg.family!r}") from None
