"""Unified model configuration covering all assigned architecture families.

Families: dense | moe | ssm | hybrid | encdec (audio) | vlm.
One ``ModelConfig`` describes any of them; family-specific fields are zero /
unused otherwise.  ``configs/<arch>.py`` instantiates the exact assigned
configs; every config also provides a ``reduced()`` variant for CPU smoke
tests (same family and code paths, tiny dimensions).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

__all__ = ["ModelConfig"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    head_dim: int = 0               # 0 -> d_model // n_heads

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # 0 -> d_ff
    dense_residual: bool = False    # arctic: dense FFN + MoE residual per layer

    # --- SSM (Mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0              # N
    ssm_head_dim: int = 64          # P
    ssm_expand: int = 2             # d_inner = expand * d_model
    ssm_groups: int = 1             # G (B/C groups)
    conv_width: int = 4
    ssd_chunk: int = 256

    # --- hybrid (zamba2) ------------------------------------------------------
    attn_every: int = 0             # apply the shared attention block every k layers

    # --- encoder-decoder (whisper) -------------------------------------------
    enc_layers: int = 0
    source_len: int = 1500          # encoder frames (stub frontend)

    # --- VLM (internvl) -------------------------------------------------------
    vision_tokens: int = 0          # precomputed patch embeddings (stub frontend)

    # --- MoE dispatch ----------------------------------------------------------
    capacity_factor: float = 1.25
    moe_dispatch: str = "sorted"    # sorted | dense | a2a (explicit shard_map)

    # --- common ---------------------------------------------------------------
    rope_theta: float = 10_000.0
    attn_chunk: int = 1024          # blockwise-attention chunk (S > 2*chunk)
    loss_chunk: int = 256           # chunked cross-entropy rows (vocab memory)
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    window: int = 0                 # sliding-window attention (0 = full)
    subquadratic: bool = False      # eligible for long_500k
    dtype: str = "bfloat16"
    remat: str = "none"             # none | full | dots
    use_flash: bool = False         # route attention through the Pallas kernel
    opt_state_dtype: str = "float32"  # bf16 for >=100B params so Adam fits HBM

    # ------------------------------------------------------------------ props
    @property
    def padded_vocab(self) -> int:
        """Embedding-table vocab padded to a multiple of 256 so the vocab dim
        divides any mesh axis (50280 -> 50432 etc.); loss labels never index
        the pad rows.  Standard practice (MaxText pads the same way)."""
        return -(-self.vocab // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def moe_ffn(self) -> int:
        return self.moe_d_ff or self.d_ff

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # --------------------------------------------------------------- counting
    def param_count(self) -> int:
        """Total parameters (N for the 6*N*D roofline term)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        hd = self.resolved_head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        ffn = 3 * d * self.d_ff  # SwiGLU: gate, up, down
        if self.family in ("dense", "vlm"):
            per_layer = attn + ffn + 2 * d
        elif self.family == "moe":
            moe = self.n_experts * 3 * d * self.moe_ffn + d * self.n_experts
            per_layer = attn + moe + 2 * d
            if self.dense_residual:
                per_layer += ffn
        elif self.family == "ssm":
            per_layer = self._mamba_block_params() + d
        elif self.family == "hybrid":
            per_layer = self._mamba_block_params() + d
            # one shared attention+MLP block (weights shared across uses)
            emb += attn + ffn + 2 * d
        elif self.family == "encdec":
            dec = attn + d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                + self.n_heads * hd * d + ffn + 3 * d  # self + cross + mlp
            enc = attn + ffn + 2 * d
            return emb + self.n_layers * dec + self.enc_layers * enc
        return emb + self.n_layers * per_layer

    def _mamba_block_params(self) -> int:
        d, di = self.d_model, self.d_inner
        g, n, h = self.ssm_groups, self.ssm_state, self.ssm_heads
        in_proj = d * (2 * di + 2 * g * n + h)
        conv = (di + 2 * g * n) * self.conv_width
        out_proj = di * d
        return in_proj + conv + out_proj + 3 * h + di

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if self.family != "moe" or not self.n_experts:
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        moe_total = self.n_layers * self.n_experts * 3 * d * self.moe_ffn
        moe_active = self.n_layers * self.top_k * 3 * d * self.moe_ffn
        return full - moe_total + moe_active
