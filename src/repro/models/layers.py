"""Shared neural-net layers: RMSNorm, RoPE, GQA attention, SwiGLU, MoE.

Pure-functional JAX.  Parameters are plain dicts of arrays; every builder has
a twin ``*_specs`` returning the same tree of *logical* partition specs
(tuples of logical axis names) consumed by ``repro.parallel.sharding``.

Sharding constraints on activations are applied through
``repro.parallel.sharding.constrain`` which is a no-op outside a mesh
context, so the same model code runs on 1 CPU device and on the 512-device
production mesh.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import constrain, weight

Params = Dict[str, Any]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ----------------------------------------------------------------- norms
def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=jnp.float32)}


def rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return out.astype(x.dtype)


# ----------------------------------------------------------------- RoPE
def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (..., S) int32 -> cos/sin of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, D). cos/sin: (B, S, D/2) or (S, D/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ------------------------------------------------------------- attention
def attention_init(key, cfg) -> Params:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    dt = _dtype(cfg)
    return {
        "wq": (jax.random.normal(k1, (d, h * hd)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (d, kv * hd)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (d, kv * hd)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (h * hd, d)) * (s / math.sqrt(2 * cfg.n_layers))).astype(dt),
    }


def attention_specs(cfg) -> Params:
    return {
        "wq": ("fsdp", "tensor"),
        "wk": ("fsdp", "tensor"),
        "wv": ("fsdp", "tensor"),
        "wo": ("tensor", "fsdp"),
    }


def blockwise_attention(q, k, v, causal: bool = True, window: int = 0,
                        q_chunk: int = 1024, k_chunk: int = 1024):
    """Pure-jnp flash attention: online-softmax over KV chunks, scan over Q
    chunks.  O(S * chunk) memory; this is both the long-sequence XLA path and
    the oracle for the Pallas kernel (kernels/ref.py re-exports it).

    q: (B, Sq, H, D); k/v: (B, Sk, KV, D).
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    rep = h // kv
    nq = -(-sq // q_chunk)
    nk = -(-sk // k_chunk)
    pad_q = nq * q_chunk - sq
    pad_k = nk * k_chunk - sk
    qf = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kf = jnp.pad(k.astype(jnp.float32), ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vf = jnp.pad(v.astype(jnp.float32), ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    scale = 1.0 / math.sqrt(d)
    qf = qf.reshape(b, nq, q_chunk, kv, rep, d) * scale
    kf = kf.reshape(b, nk, k_chunk, kv, d)
    vf = vf.reshape(b, nk, k_chunk, kv, d)

    def q_step(_, qi):
        qc, qidx = qi  # (b, q_chunk, kv, rep, d), scalar chunk index
        q_pos = qidx * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            acc, m, l = carry
            kc, vc, kidx = ki
            k_pos = kidx * k_chunk + jnp.arange(k_chunk)
            s = jnp.einsum("bqkrd,bskd->bkrqs", qc, kc)
            mask = k_pos[None, :] < sk
            if causal:
                mask = mask & (k_pos[None, :] <= q_pos[:, None])
            if window:
                mask = mask & (k_pos[None, :] > (q_pos[:, None] - window))
            s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bkrqs,bskd->bkrqd", p, vc)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, kv, rep, q_chunk, d), jnp.float32)
        m0 = jnp.full((b, kv, rep, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kv, rep, q_chunk), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.moveaxis(kf, 1, 0), jnp.moveaxis(vf, 1, 0), jnp.arange(nk)),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out

    _, outs = jax.lax.scan(
        q_step, None, (jnp.moveaxis(qf, 1, 0), jnp.arange(nq)))
    # outs: (nq, b, kv, rep, q_chunk, d) -> (b, sq, h, d)
    out = jnp.moveaxis(outs, 0, 3).reshape(b, kv, rep, nq * q_chunk, d)
    out = out[:, :, :, :sq]
    out = jnp.moveaxis(out.reshape(b, h, sq, d), 1, 2)
    return out.astype(q.dtype)


def attend(q, k, v, cfg, causal: bool = True, window: int = 0):
    """Dispatch: Pallas flash kernel / blockwise-XLA / naive by size."""
    s = q.shape[1]
    if cfg.use_flash and causal and s > 1:
        from repro.kernels import ops as kops

        return kops.flash_attention(q, k, v, causal=True, window=window)
    if s > 2 * cfg.attn_chunk:
        return blockwise_attention(q, k, v, causal=causal, window=window,
                                   q_chunk=cfg.attn_chunk, k_chunk=cfg.attn_chunk)
    return _sdpa(q, k, v, causal=causal, window=window)


def _sdpa(q, k, v, causal: bool, window: int = 0, q_offset: int = 0):
    """Reference scaled-dot-product attention with GQA broadcast.

    q: (B, Sq, H, D), k/v: (B, Sk, KV, D). H = KV * rep.
    """
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    rep = h // kv
    qf = q.astype(jnp.float32) / math.sqrt(d)
    qg = qf.reshape(b, sq, kv, rep, d)
    scores = jnp.einsum("bqkrd,bskd->bkrqs", qg, k.astype(jnp.float32))
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > (qpos[:, None] - window)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkrqs,bskd->bqkrd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


def attention(
    p: Params,
    cfg,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    causal: bool = True,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    xattn_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """GQA attention with optional KV cache (decode) or cross-attention KV.

    cache: {"k": (B, S_max, KV, D), "v": ..., "len": scalar int32}; when given,
    new K/V are scattered at ``len`` and attention runs over the cache.
    """
    b, s, d_model = x.shape
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    q = constrain(x @ weight(p["wq"], ("fsdp", "tensor")),
                  ("batch", None, "tensor")).reshape(b, s, h, hd)
    if xattn_kv is not None:
        k, v = xattn_kv
    else:
        k = constrain(x @ weight(p["wk"], ("fsdp", "tensor")),
                      ("batch", None, "tensor")).reshape(b, s, kv, hd)
        v = constrain(x @ weight(p["wv"], ("fsdp", "tensor")),
                      ("batch", None, "tensor")).reshape(b, s, kv, hd)
        cos, sin = rope_angles(positions, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is not None and xattn_kv is None and s == 1:
        # decode (single token): append at `len`, attend over the whole cache
        idx = cache["len"]
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        new_cache = {"k": ck, "v": cv, "len": idx + s}
        smax = ck.shape[1]
        kpos = jnp.arange(smax)
        valid = kpos < (idx + s)
        if cfg.window:
            valid &= kpos > (idx + s - 1 - cfg.window)
        qf = (q.astype(jnp.float32) / math.sqrt(hd)).reshape(b, s, kv, h // kv, hd)
        scores = jnp.einsum("bqkrd,bskd->bkrqs", qf, ck.astype(jnp.float32))
        scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkrqs,bskd->bqkrd", probs, cv.astype(jnp.float32))
        out = out.reshape(b, s, h, hd).astype(x.dtype)
    else:
        if cfg.use_flash and xattn_kv is None and causal and s > 1:
            from repro.kernels import ops as kops

            out = kops.flash_attention(q, k, v, causal=True, window=cfg.window)
        else:
            out = _sdpa(q, k, v, causal=causal, window=cfg.window)
        if cache is not None:  # prefill fills the cache
            smax = cache["k"].shape[1]
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            new_cache = {"k": ck, "v": cv, "len": jnp.asarray(s, jnp.int32)}

    out = out.reshape(b, s, h * hd)
    return constrain(out @ weight(p["wo"], ("tensor", "fsdp")),
                     ("batch", "seq", "fsdp")), new_cache


# ----------------------------------------------------------------- SwiGLU
def swiglu_init(key, d: int, d_ff: int, n_layers: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(d_ff) / math.sqrt(2 * n_layers)
    return {
        "gate": (jax.random.normal(k1, (d, d_ff)) * s).astype(dtype),
        "up": (jax.random.normal(k2, (d, d_ff)) * s).astype(dtype),
        "down": (jax.random.normal(k3, (d_ff, d)) * so).astype(dtype),
    }


def swiglu_specs() -> Params:
    return {"gate": ("fsdp", "tensor"), "up": ("fsdp", "tensor"), "down": ("tensor", "fsdp")}


def swiglu(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = (jax.nn.silu(x @ weight(p["gate"], ("fsdp", "tensor")))
         * (x @ weight(p["up"], ("fsdp", "tensor"))))
    h = constrain(h, ("batch", None, "tensor"))
    return constrain(h @ weight(p["down"], ("tensor", "fsdp")),
                     ("batch", "seq", "fsdp"))


# -------------------------------------------------------------------- MoE
def moe_init(key, cfg) -> Params:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_ffn
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(f) / math.sqrt(2 * cfg.n_layers)
    dt = _dtype(cfg)
    return {
        "router": (jax.random.normal(k1, (d, e)) * s).astype(jnp.float32),
        "gate": (jax.random.normal(k2, (e, d, f)) * s).astype(dt),
        "up": (jax.random.normal(k3, (e, d, f)) * s).astype(dt),
        "down": (jax.random.normal(k4, (e, f, d)) * so).astype(dt),
    }


def moe_specs() -> Params:
    return {
        "router": (None, "tensor"),
        "gate": ("expert", "fsdp", "tensor"),
        "up": ("expert", "fsdp", "tensor"),
        "down": ("expert", "tensor", "fsdp"),
    }


def moe_dense(p: Params, cfg, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense-einsum MoE dispatch: every token through every expert, masked.

    Compute scales with n_experts -- used only as the correctness oracle for
    tiny configs (tests) and as the degenerate path for very small token
    counts.  Returns (output, aux_loss).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = x.astype(jnp.float32) @ p["router"]               # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
    comb = jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32) * topw[..., None], axis=2)
    aux = _aux_loss(probs, comb, e)

    xe = x.astype(_dtype(cfg))
    hg = jnp.einsum("bsd,edf->bsef", xe, weight(p["gate"], ("expert", "fsdp", "tensor")))
    hu = jnp.einsum("bsd,edf->bsef", xe, weight(p["up"], ("expert", "fsdp", "tensor")))
    h = jax.nn.silu(hg) * hu
    # contract E and F together so (B,S,E,D) is never materialized
    h = h * comb.astype(h.dtype)[..., None]
    out = jnp.einsum("bsef,efd->bsd", h,
                     weight(p["down"], ("expert", "tensor", "fsdp")))
    return constrain(out.astype(x.dtype), ("batch", "seq", "fsdp")), aux


def _aux_loss(probs, comb, e):
    density = jnp.mean(comb > 0, axis=tuple(range(comb.ndim - 1)))
    mean_prob = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    return jnp.sum(density * mean_prob) * e


def moe(p: Params, cfg, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k routed MoE with sorted grouped dispatch (TPU-native).

    Tokens are replicated k times, sorted by expert id, packed into a static
    (E, capacity, d) buffer (overflow dropped -- capacity_factor controls
    headroom), run through batched expert matmuls, and scattered back with
    their router weights.  FLOPs scale with *active* params (top_k), unlike
    the dense-einsum oracle.  Returns (output, aux_loss).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n = b * s
    if cfg.moe_dispatch == "a2a":
        from .moe_a2a import a2a_available, moe_a2a

        if a2a_available(cfg):  # explicit EP schedule (shard_map collectives)
            return moe_a2a(p, cfg, x)
    if cfg.moe_dispatch == "dense" or n * k <= 4 * e:
        # tiny workloads: the dense-einsum oracle is cheaper than sorting
        return moe_dense(p, cfg, x)
    cap = max(1, int(math.ceil(n * k * cfg.capacity_factor / e)))

    xf = x.reshape(n, d)
    logits = xf.astype(jnp.float32) @ p["router"]              # (n, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)                       # (n, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    eid = topi.reshape(n * k)
    w = topw.reshape(n * k)
    tok = jnp.arange(n * k, dtype=jnp.int32) // k
    order = jnp.argsort(eid)                                   # stable
    eid_s, w_s, tok_s = eid[order], w[order], tok[order]

    counts = jnp.zeros((e,), jnp.int32).at[eid].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(n * k, dtype=jnp.int32) - offsets[eid_s]
    in_cap = rank < cap
    rank_c = jnp.where(in_cap, rank, cap)                      # OOB -> dropped

    xs = jnp.take(xf, tok_s, axis=0).astype(_dtype(cfg))
    buf = jnp.zeros((e, cap, d), _dtype(cfg)).at[eid_s, rank_c].set(
        xs, mode="drop")
    buf = constrain(buf, ("expert", None, None))

    hg = jnp.einsum("ecd,edf->ecf", buf, weight(p["gate"], ("expert", "fsdp", "tensor")))
    hu = jnp.einsum("ecd,edf->ecf", buf, weight(p["up"], ("expert", "fsdp", "tensor")))
    h = constrain(jax.nn.silu(hg) * hu, ("expert", None, "tensor"))
    o = jnp.einsum("ecf,efd->ecd", h,
                   weight(p["down"], ("expert", "tensor", "fsdp")))  # (E, cap, d)

    contrib = o[eid_s, rank_c] * (w_s * in_cap)[:, None].astype(o.dtype)
    y = jnp.zeros((n, d), jnp.float32).at[tok_s].add(contrib.astype(jnp.float32))

    comb = jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32) * topw[..., None], axis=1)
    aux = _aux_loss(probs, comb, e)
    return constrain(y.reshape(b, s, d).astype(x.dtype), ("batch", "seq", "fsdp")), aux


# ------------------------------------------------------------- embeddings
def embed_init(key, cfg) -> Params:
    dt = _dtype(cfg)
    v = cfg.padded_vocab  # pad rows are never indexed by labels/tokens
    p = {
        "tok": (jax.random.normal(key, (v, cfg.d_model)) * 0.02).astype(dt)
    }
    if not cfg.tie_embeddings:
        p["out"] = (
            jax.random.normal(jax.random.fold_in(key, 1), (cfg.d_model, v)) * 0.02
        ).astype(dt)
    return p


def embed_specs(cfg) -> Params:
    p = {"tok": ("tensor", "fsdp")}
    if not cfg.tie_embeddings:
        p["out"] = ("fsdp", "tensor")
    return p


def embed_lookup(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return constrain(jnp.take(p["tok"], tokens, axis=0), ("batch", "seq", "fsdp"))


def unembed(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    w = (weight(p["out"], ("fsdp", "tensor")) if "out" in p
         else weight(p["tok"], ("tensor", "fsdp")).T)
    return constrain(x @ w, ("batch", None, "tensor"))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    return jnp.mean(lse - ll)


def chunked_cross_entropy(h: jnp.ndarray, embed_p: Params, labels: jnp.ndarray,
                          chunk: int = 256) -> jnp.ndarray:
    """CE loss without materializing (B, S, vocab) logits.

    Scans over sequence chunks; each chunk computes its logits, reduces to
    (lse - ll), and is discarded.  Peak extra memory is (B, chunk, vocab).
    """
    w = (weight(embed_p["out"], ("fsdp", "tensor")) if "out" in embed_p
         else weight(embed_p["tok"], ("tensor", "fsdp")).T)
    b, s, d = h.shape
    if s <= chunk:
        return cross_entropy(unembed(embed_p, h), labels)
    nc = -(-s // chunk)
    pad = nc * chunk - s
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)))
    valid = jnp.pad(jnp.ones((b, s), jnp.float32), ((0, 0), (0, pad)))
    hp = hp.reshape(b, nc, chunk, d)
    lp = lp.reshape(b, nc, chunk)
    valid = valid.reshape(b, nc, chunk)

    @jax.checkpoint  # recompute chunk logits in backward: never stack (B,chunk,V)
    def step(acc, args):
        hc, lc, vc = args  # (b, chunk, d), (b, chunk), (b, chunk)
        logits = constrain(hc @ w, ("batch", None, "tensor")).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((lse - ll) * vc), None

    total, _ = jax.lax.scan(
        step, jnp.zeros((), jnp.float32),
        (jnp.moveaxis(hp, 1, 0), jnp.moveaxis(lp, 1, 0), jnp.moveaxis(valid, 1, 0)),
    )
    return total / (b * s)
