"""Explicit-collective MoE dispatch (the Megatron-MoE / EP schedule).

GSPMD lowers the einsum-formulated MoE dispatch (``layers.moe``) through
data-dependent scatters, which the CPU partitioner (and sometimes the TPU
one) turns into replication-heavy all-reduces -- the dominant term in the
arctic-480b baseline (§Perf pick 2).  This module expresses the *correct*
schedule by hand with ``jax.shard_map``:

layout (the ``moe_ep``/``moe_a2a`` rule variant):
    tokens : batch sharded over the data axes, d_model full
    experts: sharded over the model axis  (E_loc = E / n_model)
    expert FFN dim (f): sharded over the data axes (f_loc = f / n_data)

per-device schedule (all collectives explicit, all O(tokens), not O(weights)):
    1. route + pack LOCAL tokens into (E, cap_loc, d)      -- no communication
    2. slice my model-shard's experts  (E_loc, cap_loc, d) -- free
    3. all_gather over data: every f-shard needs every token that hits its
       experts                                   (E_loc, n_data*cap_loc, d)
    4. expert matmuls with local weight shards (d full, f_loc)
    5. psum_scatter over data: sum f-partials, keep my tokens' slice
                                                  (E_loc, cap_loc, d)
    6. unpack + weight locally, psum over model: every expert shard
       contributes its experts' outputs to my tokens        (n_loc, d)

Collective bytes per layer-pass per device ~ a few hundred MB of *token*
traffic vs ~1.7 GB of *weight* gathers (arctic) for the FSDP alternative --
the §Perf pick-2 napkin, now implemented rather than estimated.

Differentiable end to end (shard_map collectives have transposes).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import current_mesh, current_rules, logical_to_spec

Params = dict


def _axis_size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def a2a_available(cfg) -> bool:
    """True when the ambient mesh/rules support the explicit EP schedule."""
    mesh, rules = current_mesh(), current_rules()
    if mesh is None or rules is None:
        return False
    e_ax = rules.lookup("expert")
    if isinstance(e_ax, tuple) or e_ax not in mesh.axis_names:
        return False
    n_model = mesh.shape[e_ax]
    return cfg.n_experts % n_model == 0


def moe_a2a(p: Params, cfg, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in for ``layers.moe`` under the explicit EP schedule."""
    mesh, rules = current_mesh(), current_rules()
    e_ax = rules.lookup("expert")                       # e.g. "model"
    f_ax = rules.lookup("tensor")                       # e.g. "data"/None
    b_ax = logical_to_spec(("batch",), mesh, rules)[0]  # data axes (filtered)
    n_model = mesh.shape[e_ax]
    n_data = _axis_size(mesh, f_ax)

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    e_loc = e // n_model
    n_loc = (b * s) // _axis_size(mesh, b_ax)
    cap_loc = max(1, int(math.ceil(n_loc * k * cfg.capacity_factor / e)))

    x_spec = P(b_ax, None, None)
    gate_spec = P(e_ax, None, f_ax)
    down_spec = P(e_ax, f_ax, None)

    def local(x_l, router, gate_l, up_l, down_l):
        bl, sl, _ = x_l.shape
        n = bl * sl
        xf = x_l.reshape(n, d)

        # 1. local routing + pack (identical math to layers.moe, all local)
        logits = xf.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)
        topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

        eid = topi.reshape(n * k)
        w = topw.reshape(n * k)
        tok = jnp.arange(n * k, dtype=jnp.int32) // k
        order = jnp.argsort(eid)
        eid_s, w_s, tok_s = eid[order], w[order], tok[order]
        counts = jnp.zeros((e,), jnp.int32).at[eid].add(1)
        offsets = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
        rank = jnp.arange(n * k, dtype=jnp.int32) - offsets[eid_s]
        in_cap = rank < cap_loc
        rank_c = jnp.where(in_cap, rank, cap_loc)

        xs = jnp.take(xf, tok_s, axis=0).astype(x_l.dtype)
        buf = jnp.zeros((e, cap_loc, d), x_l.dtype).at[eid_s, rank_c].set(
            xs, mode="drop")

        # 2. my model-shard's experts
        j = jax.lax.axis_index(e_ax)
        buf_my = jax.lax.dynamic_slice(
            buf, (j * e_loc, 0, 0), (e_loc, cap_loc, d))

        # 3. gather tokens across the f-shard axis (token traffic, not weights)
        if f_ax is not None:
            buf_g = jax.lax.all_gather(buf_my, f_ax, axis=1, tiled=True)
        else:
            buf_g = buf_my                              # f unsharded

        # 4. expert matmuls on local weight shards
        hg = jnp.einsum("ecd,edf->ecf", buf_g, gate_l)
        hu = jnp.einsum("ecd,edf->ecf", buf_g, up_l)
        h = jax.nn.silu(hg) * hu
        o_part = jnp.einsum("ecf,efd->ecd", h, down_l)  # partial over f shards

        # 5. reduce f-partials, keep my tokens' slice
        if f_ax is not None:
            o_my = jax.lax.psum_scatter(o_part, f_ax, scatter_dimension=1,
                                        tiled=True)     # (e_loc, cap_loc, d)
        else:
            o_my = o_part

        # 6. unpack my experts' contributions to my tokens, psum over experts
        is_mine = (eid_s >= j * e_loc) & (eid_s < (j + 1) * e_loc)
        eid_rel = jnp.clip(eid_s - j * e_loc, 0, e_loc - 1)
        contrib = o_my[eid_rel, jnp.minimum(rank_c, cap_loc - 1)]
        wgt = (w_s * in_cap * is_mine).astype(jnp.float32)
        y = jnp.zeros((n, d), jnp.float32).at[tok_s].add(
            contrib.astype(jnp.float32) * wgt[:, None])
        y = jax.lax.psum(y.astype(x_l.dtype), e_ax)  # psum token-sized, bf16

        # aux load-balance loss (global over experts; mean over token shards)
        comb = jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32)
                       * topw[..., None], axis=1)
        density = jnp.mean(comb > 0, axis=0)
        mean_prob = jnp.mean(probs, axis=0)
        aux = jnp.sum(density * mean_prob) * e
        axes = [a for a in ((b_ax,) if isinstance(b_ax, str) else (b_ax or ()))]
        if axes:
            aux = jax.lax.pmean(aux, tuple(axes))
        return y.reshape(bl, sl, d), aux

    if hasattr(jax, "shard_map"):  # jax >= 0.6: top-level API, check_vma kwarg
        fn = jax.shard_map(
            local,
            mesh=mesh,
            in_specs=(x_spec, P(None, None), gate_spec, gate_spec, down_spec),
            out_specs=(x_spec, P()),
            check_vma=False,
        )
    else:
        from jax.experimental.shard_map import shard_map as _shard_map

        fn = _shard_map(
            local,
            mesh=mesh,
            in_specs=(x_spec, P(None, None), gate_spec, gate_spec, down_spec),
            out_specs=(x_spec, P()),
            check_rep=False,
        )
    return fn(x, p["router"], p["gate"], p["up"], p["down"])
