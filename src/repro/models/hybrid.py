"""Zamba2-style hybrid LM: a Mamba2 backbone with a *shared* attention+MLP
block applied every ``attn_every`` layers [arXiv:2411.15242].

The shared block's weights are a single set reused at every application
(Zamba2's parameter-sharing trick), but each application carries its own KV
state.  The layer stack is executed as a scan over *groups*: each group scans
``attn_every`` stacked Mamba layers and then applies the shared attention
block once.  ``n_layers`` must be divisible by ``attn_every``.

Decode uses a ring-buffer sliding-window KV cache of size ``cfg.window`` per
shared-block application, which keeps the ``long_500k`` decode state O(window)
instead of O(seq) -- this is why the hybrid arch runs the 500k shape (see
DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain, weight

from . import layers as L
from . import ssm as M

Params = Dict[str, Any]


def _n_groups(cfg) -> int:
    k = cfg.attn_every or cfg.n_layers
    assert cfg.n_layers % k == 0, (cfg.n_layers, k)
    return cfg.n_layers // k


# ------------------------------------------------------------------ params
def init(key, cfg) -> Params:
    ke, kl, ka, kf = jax.random.split(key, 4)
    stacked = jax.vmap(lambda k: M._layer_init(k, cfg))(
        jax.random.split(kl, cfg.n_layers))
    shared = {
        "ln1": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "ln2": L.rmsnorm_init(cfg.d_model, jnp.float32),
        "attn": L.attention_init(ka, cfg),
        "ffn": L.swiglu_init(kf, cfg.d_model, cfg.d_ff, cfg.n_layers,
                             jnp.dtype(cfg.dtype)),
    }
    return {"embed": L.embed_init(ke, cfg), "layers": stacked,
            "shared": shared, "ln_f": L.rmsnorm_init(cfg.d_model, jnp.float32)}


def param_specs(cfg) -> Params:
    lay = {"ln": {"scale": (None,)}, "mamba": M.mamba_specs(cfg)}
    stacked = jax.tree.map(lambda s: (None,) + tuple(s), lay,
                           is_leaf=lambda x: isinstance(x, tuple))
    shared = {
        "ln1": {"scale": (None,)},
        "ln2": {"scale": (None,)},
        "attn": L.attention_specs(cfg),
        "ffn": L.swiglu_specs(),
    }
    return {"embed": L.embed_specs(cfg), "layers": stacked,
            "shared": shared, "ln_f": {"scale": (None,)}}


# ------------------------------------------------------- shared attn (ring)
def _ring_attend(p: Params, cfg, x, positions, cache):
    """Shared-block attention.  cache None -> full (windowed) attention;
    cache {"k","v","pos","len"} with ring buffers of size R -> decode."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    h, kv = cfg.n_heads, cfg.n_kv_heads
    q = (x @ weight(p["wq"], ("fsdp", "tensor"))).reshape(b, s, h, hd)
    k = (x @ weight(p["wk"], ("fsdp", "tensor"))).reshape(b, s, kv, hd)
    v = (x @ weight(p["wv"], ("fsdp", "tensor"))).reshape(b, s, kv, hd)
    cos, sin = L.rope_angles(positions, hd, cfg.rope_theta)
    q = L.apply_rope(q, cos, sin)
    k = L.apply_rope(k, cos, sin)

    if cache is None:
        out = L.attend(q, k, v, cfg, causal=True, window=cfg.window)
        return (out.reshape(b, s, h * hd) @ p["wo"]), None

    R = cache["k"].shape[1]
    idx = cache["len"]                                   # scalar int32
    if s == 1:
        slot = jnp.mod(idx, R)
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, slot, 0, 0))
        cpos = jax.lax.dynamic_update_slice(
            cache["pos"], positions[0, :1].astype(jnp.int32), (slot,))
        new_len = idx + 1
        # valid slots: written (< new_len in ring terms) and within window
        slots = jnp.arange(R)
        written = slots < jnp.minimum(new_len, R)
        qpos = positions[0, 0]
        in_window = (cpos > qpos - (cfg.window or 10**9)) & (cpos <= qpos)
        valid = written & in_window
        qf = (q.astype(jnp.float32) / math.sqrt(hd)).reshape(b, s, kv, h // kv, hd)
        scores = jnp.einsum("bqkrd,bskd->bkrqs", qf, ck.astype(jnp.float32))
        scores = jnp.where(valid[None, None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkrqs,bskd->bqkrd", probs, cv.astype(jnp.float32))
        out = out.reshape(b, s, h * hd).astype(x.dtype)
        new_cache = {"k": ck, "v": cv, "pos": cpos, "len": new_len}
        return out @ p["wo"], new_cache

    # prefill: run windowed attention over the prompt, stash the tail in ring
    out = L.attend(q, k, v, cfg, causal=True, window=cfg.window)
    take = min(R, s)
    tail_k = k[:, -take:].astype(cache["k"].dtype)
    tail_v = v[:, -take:].astype(cache["v"].dtype)
    tail_p = positions[0, -take:].astype(jnp.int32)
    ck = jax.lax.dynamic_update_slice(cache["k"], tail_k, (0, 0, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], tail_v, (0, 0, 0, 0))
    cpos = jax.lax.dynamic_update_slice(cache["pos"], tail_p, (0,))
    new_cache = {"k": ck, "v": cv, "pos": cpos,
                 "len": jnp.asarray(take, jnp.int32)}
    return (out.reshape(b, s, h * hd) @ p["wo"]), new_cache


def _shared_block(p: Params, cfg, h, positions, cache):
    a, nc = _ring_attend(p["attn"], cfg, L.rmsnorm(p["ln1"], h, cfg.norm_eps),
                         positions, cache)
    h = h + constrain(a, ("batch", "seq", "fsdp"))
    h = h + L.swiglu(p["ffn"], L.rmsnorm(p["ln2"], h, cfg.norm_eps))
    return h, nc


# ----------------------------------------------------------------- forward
def forward(params, cfg, tokens, positions=None, cache=None):
    h = L.embed_lookup(params["embed"], tokens)
    b, s, _ = h.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    ng = _n_groups(cfg)
    per = cfg.n_layers // ng
    grouped = jax.tree.map(
        lambda a: a.reshape((ng, per) + a.shape[1:]), params["layers"])

    def mamba_block(lp, h, lc):
        o, nc = M.mamba_block(lp["mamba"], cfg,
                              L.rmsnorm(lp["ln"], h, cfg.norm_eps), lc)
        return h + o, nc

    if cfg.remat == "full":
        mamba_block = jax.checkpoint(mamba_block)
    elif cfg.remat == "dots":
        mamba_block = jax.checkpoint(
            mamba_block, policy=jax.checkpoint_policies.checkpoint_dots)

    def group_fn(h, xs):
        if cache is not None:
            glp, (gmc, gac) = xs
        else:
            glp, gmc, gac = xs, None, None

        def inner(hh, ys):
            if gmc is not None:
                lp, lc = ys
                hh, nc = mamba_block(lp, hh, lc)
                return hh, nc
            hh, _ = mamba_block(ys, hh, None)
            return hh, None

        if gmc is not None:
            h, new_mc = jax.lax.scan(inner, h, (glp, gmc))
        else:
            h, _ = jax.lax.scan(inner, h, glp)
            new_mc = None
        h, new_ac = _shared_block(params["shared"], cfg, h, positions, gac)
        if cache is not None:
            return h, (new_mc, new_ac)
        return h, None

    if cache is not None:
        gm = jax.tree.map(lambda a: a.reshape((ng, per) + a.shape[1:]),
                          cache["mamba"])
        h, (new_mc, new_ac) = jax.lax.scan(group_fn, h, (grouped, (gm, cache["attn"])))
        new_cache = {
            "mamba": jax.tree.map(
                lambda a: a.reshape((ng * per,) + a.shape[2:]), new_mc),
            "attn": new_ac,
        }
    else:
        h, _ = jax.lax.scan(group_fn, h, grouped)
        new_cache = None
    return L.rmsnorm(params["ln_f"], h, cfg.norm_eps), new_cache


def loss_fn(params, cfg, batch):
    h, _ = forward(params, cfg, batch["tokens"])
    return L.chunked_cross_entropy(h, params["embed"], batch["labels"],
                                   cfg.loss_chunk)


# ------------------------------------------------------------------- serve
def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    ng = _n_groups(cfg)
    R = min(max_len, cfg.window) if cfg.window else max_len
    hd = cfg.resolved_head_dim
    return {
        "mamba": M.init_cache(cfg, batch, max_len, dtype),
        "attn": {
            "k": jnp.zeros((ng, batch, R, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((ng, batch, R, cfg.n_kv_heads, hd), dtype),
            "pos": jnp.zeros((ng, R), jnp.int32),
            "len": jnp.zeros((ng,), jnp.int32),
        },
    }


def cache_specs(cfg) -> Params:
    return {
        "mamba": M.cache_specs(cfg),
        "attn": {"k": (None, "batch", "kvseq", "kv", None),
                 "v": (None, "batch", "kvseq", "kv", None),
                 "pos": (), "len": ()},
    }


def prefill(params, cfg, tokens, cache):
    h, new_cache = forward(params, cfg, tokens, cache=cache)
    return L.unembed(params["embed"], h[:, -1:]), new_cache


def decode_step(params, cfg, token, cache):
    b = token.shape[0]
    pos = jnp.broadcast_to(cache["attn"]["len"][0][None, None], (b, 1)).astype(jnp.int32)
    h, new_cache = forward(params, cfg, token, positions=pos, cache=cache)
    return L.unembed(params["embed"], h), new_cache
