"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free.

64L d_model=2560 (attn-free) d_ff=0 vocab=50280, ssm_state=128
[arXiv:2405.21060; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    conv_width=4,
    ssd_chunk=256,
    subquadratic=True,            # O(1)-state decode: runs long_500k
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, vocab=256, ssm_state=16, ssm_head_dim=16,
        ssd_chunk=32, remat="none", dtype="float32",
    )
