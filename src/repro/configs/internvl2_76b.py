"""internvl2-76b [vlm] — InternViT + InternLM2 backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[arXiv:2404.16821; unverified]

The vision tower is a STUB per the assignment: ``input_specs()`` supplies
precomputed patch embeddings (B, vision_tokens, d_model) prepended to the
text sequence.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    vision_tokens=256,
    remat="full",
    opt_state_dtype="bfloat16",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab=256, vision_tokens=8, remat="none", dtype="float32",
        opt_state_dtype="float32",
    )
