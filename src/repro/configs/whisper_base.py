"""whisper-base [audio] — encoder-decoder, conv frontend STUB.

6L d_model=512 8H (GQA kv=8) d_ff=2048 vocab=51865
[arXiv:2212.04356; unverified]

``input_specs()`` provides precomputed frame embeddings (B, 1500, d) for the
encoder; the decoder is the assigned 6-layer stack with self+cross attention.
long_500k is skipped (full attention, enc-dec).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="encdec",
    n_layers=6,            # decoder layers
    enc_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=51865,
    source_len=1500,
    remat="none",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, source_len=16, dtype="float32",
    )
