"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]

The shared attention block is applied every ``attn_every`` Mamba layers with
a single reused weight set (Zamba2's parameter sharing).  At the long_500k
shape the shared block uses a sliding window (ring-buffer KV cache), so the
whole arch decodes with O(window + ssm_state) state — hence subquadratic.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    conv_width=4,
    ssd_chunk=256,
    attn_every=6,                 # 54 layers -> 9 shared-block applications
    window=4096,                  # sliding-window attention in shared blocks
    subquadratic=True,
    remat="full",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, attn_every=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=256, ssm_state=16, ssm_head_dim=16, ssd_chunk=32,
        window=32, remat="none", dtype="float32",
    )
