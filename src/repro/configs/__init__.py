"""Assigned-architecture registry + the input-shape grid.

Every arch is selectable as ``--arch <id>`` (dashed id); each config module
defines ``CONFIG`` (the exact assigned config) and ``reduced()`` (same family
and code paths, tiny dimensions, for CPU smoke tests).

The shape grid is the assignment's: train_4k / prefill_32k / decode_32k /
long_500k.  ``shapes_for(cfg)`` filters out cells that are inapplicable to an
arch family (long_500k needs sub-quadratic attention; see DESIGN.md
§Arch-applicability).
"""

from __future__ import annotations

from dataclasses import dataclass
from importlib import import_module
from typing import Dict, List, Tuple

from repro.models.config import ModelConfig

__all__ = ["ARCH_IDS", "get_config", "SHAPES", "Shape", "shapes_for"]

ARCH_IDS: Tuple[str, ...] = (
    "arctic-480b",
    "phi3.5-moe-42b-a6.6b",
    "llama3.2-3b",
    "deepseek-coder-33b",
    "tinyllama-1.1b",
    "phi3-mini-3.8b",
    "mamba2-2.7b",
    "internvl2-76b",
    "zamba2-2.7b",
    "whisper-base",
)

_MODULES: Dict[str, str] = {
    "arctic-480b": "arctic_480b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "llama3.2-3b": "llama32_3b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "phi3-mini-3.8b": "phi3_mini",
    "mamba2-2.7b": "mamba2_2_7b",
    "internvl2-76b": "internvl2_76b",
    "zamba2-2.7b": "zamba2_2_7b",
    "whisper-base": "whisper_base",
}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; choose from {list(_MODULES)}")
    mod = import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.reduced() if reduced else mod.CONFIG


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Tuple[Shape, ...] = (
    Shape("train_4k", 4_096, 256, "train"),
    Shape("prefill_32k", 32_768, 32, "prefill"),
    Shape("decode_32k", 32_768, 128, "decode"),
    Shape("long_500k", 524_288, 1, "decode"),
)


def shapes_for(cfg: ModelConfig) -> List[Shape]:
    """The applicable subset of the shape grid for this arch."""
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not cfg.subquadratic:
            continue  # full attention at 524k: skipped per assignment
        out.append(s)
    return out
