"""arctic-480b [moe] — 128 experts top-2 + dense residual per layer.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2
[hf:Snowflake/snowflake-arctic-base; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    n_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual=True,          # dense FFN residual branch per layer
    remat="full",
    opt_state_dtype="bfloat16",   # ~480B params: Adam must fit 16 GB/chip HBM
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        moe_d_ff=96, vocab=256, n_experts=8, top_k=2, remat="none",
        dtype="float32", opt_state_dtype="float32",
    )
