"""Fault-tolerant checkpointing: atomic directories, async save, auto-resume.

Layout::

    <dir>/step_000100.ckpt      # one container file (npz + json tree spec)
    <dir>/step_000100.ckpt.tmp  # in-flight write (never read)
    <dir>/LATEST                # atomic pointer, written last

* **Atomicity**: the container is written to ``.tmp`` then ``os.replace``d;
  ``LATEST`` is updated only after the data file is durable, so a crash at any
  point leaves a consistent store (the paper's in-situ thesis applied to the
  checkpoint path: the *consumer* of a checkpoint never sees a torn file).
* **Async**: ``AsyncCheckpointer.save`` snapshots device arrays to host
  (blocking only for D2H) and hands serialization to a background thread --
  training resumes while the previous checkpoint is still being written,
  the standard overlap trick at scale.
* **Auto-resume**: ``restore_latest`` returns (step, state) or None; the train
  driver always calls it first, which is what makes preemption/node failure a
  restart, not a loss.
* **Retention**: keep the newest ``keep`` checkpoints (older ones deleted
  after a successful save).
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..analysis.lockcheck import make_lock

__all__ = ["save_pytree", "load_pytree", "load_pytree_flat",
           "AsyncCheckpointer", "restore_latest"]


def _flatten_with_paths(tree: Any) -> List[Tuple[str, np.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


def save_pytree(tree: Any, path: str) -> str:
    """Serialize a pytree to one container file, atomically."""
    leaves = _flatten_with_paths(tree)
    treedef = jax.tree_util.tree_structure(tree)
    arrays = {f"a{i}": arr for i, (_, arr) in enumerate(leaves)}
    meta = {
        "keys": [k for k, _ in leaves],
        "treedef": str(treedef),
        "time": time.time(),
    }
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        header = json.dumps(meta).encode()
        f.write(len(header).to_bytes(8, "little"))
        f.write(header)
        np.savez(f, **arrays)
    os.replace(tmp, path)
    return path


def load_pytree(path: str, like: Any) -> Any:
    """Load a container into the structure of ``like`` (order-checked)."""
    with open(path, "rb") as f:
        hlen = int.from_bytes(f.read(8), "little")
        meta = json.loads(f.read(hlen).decode())
        npz = np.load(f)
        arrays = [npz[f"a{i}"] for i in range(len(meta["keys"]))]
    ref_leaves, treedef = jax.tree_util.tree_flatten(like)
    if len(ref_leaves) != len(arrays):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, expected {len(ref_leaves)}")
    leaves = []
    for ref, arr in zip(ref_leaves, arrays):
        if tuple(ref.shape) != tuple(arr.shape):
            raise ValueError(f"shape mismatch {ref.shape} vs {arr.shape}")
        leaves.append(arr.astype(ref.dtype) if hasattr(ref, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_pytree_flat(path: str) -> Dict[str, np.ndarray]:
    """Load a container WITHOUT a reference structure: {path-key: array}.

    The elastic-rescale path re-cuts a dead task's checkpoint into a
    different number of shards; at that point nobody holds a ``like``
    structure of the old size, so the order-checked :func:`load_pytree` is
    unusable.  Keys are the flatten-with-path strings written at save time
    (a flat dict state ``{"acc": ...}`` yields the key ``"['acc']"``).
    """
    with open(path, "rb") as f:
        hlen = int.from_bytes(f.read(8), "little")
        meta = json.loads(f.read(hlen).decode())
        npz = np.load(f)
        return {k: npz[f"a{i}"] for i, k in enumerate(meta["keys"])}


def _ckpt_name(step: int) -> str:
    return f"step_{step:08d}.ckpt"


class AsyncCheckpointer:
    """Background-thread checkpoint writer with retention + LATEST pointer."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._lock = make_lock("leaf:ckpt")
        self._inflight: Optional[threading.Thread] = None
        # a failed background write (disk full, permission flip) used to die
        # silently on its daemon thread -- callers kept "checkpointing" into
        # the void.  The error is parked here and re-raised at the next
        # save()/wait(), i.e. on the caller's thread, where the recovery
        # supervisor can see it.
        self._error: Optional[BaseException] = None
        self.saved_steps: List[int] = []

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any, block: bool = False) -> None:
        host_state = jax.tree.map(np.asarray, state)  # D2H snapshot (blocking)
        self.wait()  # at most one in-flight write (raises a parked error)

        def work():
            try:
                path = os.path.join(self.dir, _ckpt_name(step))
                save_pytree(host_state, path)
                with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
                    f.write(str(step))
                os.replace(os.path.join(self.dir, "LATEST.tmp"),
                           os.path.join(self.dir, "LATEST"))
                with self._lock:
                    self.saved_steps.append(step)
                    self._gc()
            except BaseException as e:  # surfaced on the caller's next call
                with self._lock:
                    self._error = e

        t = threading.Thread(target=work, daemon=True)
        with self._lock:
            self._inflight = t
        t.start()
        if block:
            self.wait()

    def wait(self) -> None:
        """Join the in-flight write; re-raise any background write error on
        THIS thread (a checkpoint that did not land must not ack)."""
        with self._lock:
            t, self._inflight = self._inflight, None
        if t is not None:
            t.join()
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise err

    def _gc(self) -> None:
        for s in sorted(self.saved_steps)[: -self.keep]:
            p = os.path.join(self.dir, _ckpt_name(s))
            if os.path.exists(p):
                os.remove(p)
            self.saved_steps.remove(s)

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip())

    def restore(self, step: int, like: Any) -> Any:
        return load_pytree(os.path.join(self.dir, _ckpt_name(step)), like)


def restore_latest(directory: str, like: Any) -> Optional[Tuple[int, Any]]:
    ck = AsyncCheckpointer(directory)
    step = ck.latest_step()
    if step is None:
        return None
    return step, ck.restore(step, like)
