"""Deterministic tokenized data pipeline with host-side prefetch.

The pipeline is the in-situ *producer substrate*: a seeded synthetic corpus
(mixture of Zipfian unigrams and repeated n-gram "documents", so the LM loss
actually decreases) packed into fixed-length sequences, iterated in
globally-consistent order, sharded onto the mesh's ("pod","data") axes with
``jax.make_array_from_callback`` (each host materializes only its shard), and
prefetched one step ahead on a background thread so host data work overlaps
device compute.

Checkpointable: the iterator state is just (seed, step) -- restoring a
checkpoint resumes the exact batch sequence, which is what makes
checkpoint/restart deterministic end-to-end.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import numpy as np

__all__ = ["DataConfig", "SyntheticCorpus", "make_batch_iter", "Prefetcher"]


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    ngram_repeat: int = 8        # learnable structure: repeated n-grams


class SyntheticCorpus:
    """Deterministic batch factory: batch(step) is pure in (seed, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # Zipfian unigram table (shared across steps; cheap to rebuild)
        v = cfg.vocab
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks
        self._probs = p / p.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 32) ^ step)
        b, s = cfg.global_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab, size=(b, s + 1), p=self._probs).astype(np.int32)
        # plant learnable n-gram repeats (period-k structure)
        k = cfg.ngram_repeat
        if k and s + 1 >= 2 * k:
            n_rep = -(-(s + 1) // k)  # ceil: planted covers the full length
            seeds = toks[:, :k]
            planted = np.tile(seeds, (1, n_rep))[:, : s + 1]
            mask = rng.random((b, 1)) < 0.5  # half the docs are periodic
            toks = np.where(mask, planted, toks)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def shard_batch(batch: Dict[str, np.ndarray], mesh, batch_spec) -> Dict[str, Any]:
    """Place a host batch onto the mesh, sharded over the batch axes."""
    from jax.sharding import NamedSharding

    out = {}
    for k, v in batch.items():
        sh = NamedSharding(mesh, batch_spec)
        out[k] = jax.make_array_from_callback(
            v.shape, sh, lambda idx, vv=v: vv[idx])
    return out


class Prefetcher:
    """One-step-ahead background prefetch (host data work overlaps compute)."""

    def __init__(self, it: Iterator[Any], depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._done = object()

        def work():
            try:
                for item in it:
                    self._q.put(item)
            finally:
                self._q.put(self._done)

        self._t = threading.Thread(target=work, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def make_batch_iter(
    cfg: DataConfig,
    start_step: int = 0,
    num_steps: Optional[int] = None,
    mesh=None,
    batch_spec=None,
    prefetch: bool = True,
) -> Iterator[Tuple[int, Dict[str, Any]]]:
    corpus = SyntheticCorpus(cfg)

    def gen():
        step = start_step
        while num_steps is None or step < start_step + num_steps:
            b = corpus.batch(step)
            if mesh is not None and batch_spec is not None:
                b = shard_batch(b, mesh, batch_spec)
            yield step, b
            step += 1

    return Prefetcher(gen()) if prefetch else gen()
