"""AdamW + LR schedule + gradient clipping, implemented directly in JAX.

Optimizer state is a pytree shaped like the params and therefore shards like
the params under the same logical specs (ZeRO-3 equivalent: m/v live on the
fsdp axis).  ``opt_state_dtype="bfloat16"`` stores m/v in bf16 -- required for
the >=100B-param archs so Adam fits 16 GB/chip HBM (see configs).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
           "global_norm", "clip_by_global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"


class OptState(NamedTuple):
    step: jnp.ndarray            # scalar int32
    m: Any                       # first moment, params-shaped
    v: Any                       # second moment, params-shaped


def adamw_init(params: Any, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def cosine_lr(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), n


def adamw_update(
    params: Any, grads: Any, state: OptState, cfg: AdamWConfig
) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = cosine_lr(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        mf = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        vf = v.astype(jnp.float32) * cfg.b2 + jnp.square(g) * (1 - cfg.b2)
        update = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * update).astype(p.dtype),
                mf.astype(sdt), vf.astype(sdt))

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_params, OptState(step, new_m, new_v), metrics
