from .checkpoint import AsyncCheckpointer, load_pytree, restore_latest, save_pytree
from .data import DataConfig, Prefetcher, SyntheticCorpus, make_batch_iter
from .optim import AdamWConfig, adamw_init, adamw_update, cosine_lr
from .trainer import TrainState, init_state, make_train_step, state_specs

__all__ = [
    "AsyncCheckpointer", "load_pytree", "restore_latest", "save_pytree",
    "DataConfig", "Prefetcher", "SyntheticCorpus", "make_batch_iter",
    "AdamWConfig", "adamw_init", "adamw_update", "cosine_lr",
    "TrainState", "init_state", "make_train_step", "state_specs",
]
