"""Family-agnostic training step: loss -> grad -> (accumulate) -> AdamW.

``make_train_step`` builds a jittable ``train_step(state, batch)`` for any
arch config.  Under a mesh the step is pjit'd with params/opt-state sharded by
the logical specs and the batch sharded over ("pod","data"); without a mesh it
runs on one CPU device -- same code (the sharding constraints are ambient
no-ops).

Microbatch gradient accumulation (``accum_steps``) scans over microbatches,
keeping the weight update -- and hence the FSDP all-gather / reduce-scatter
traffic -- once per *global* batch: the standard collective-amortization trick
at scale.

Optional int8 gradient compression (``compress_grads``): grads are quantized
per-leaf (symmetric, absmax scale) before entering the accumulation buffer and
dequantized at update time, with an error-feedback residual folded into the
next microbatch.  On a real pod this halves/quarters reduce-scatter bytes;
here it is exercised for correctness and counted in the roofline's collective
term.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import get_family
from repro.parallel.sharding import constrain, current_rules

from .optim import AdamWConfig, OptState, adamw_init, adamw_update

__all__ = ["TrainState", "make_train_step", "init_state", "state_specs"]


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    rng: jnp.ndarray


def init_state(key, cfg, opt_cfg: Optional[AdamWConfig] = None) -> TrainState:
    opt_cfg = opt_cfg or AdamWConfig(state_dtype=cfg.opt_state_dtype)
    fam = get_family(cfg)
    params = fam.init(key, cfg)
    return TrainState(params=params, opt=adamw_init(params, opt_cfg),
                      rng=jax.random.PRNGKey(0))


def state_specs(cfg) -> TrainState:
    """Logical-axis spec tree for the full TrainState (ZeRO-3: m/v like params)."""
    fam = get_family(cfg)
    pspecs = fam.param_specs(cfg)
    return TrainState(
        params=pspecs,
        opt=OptState(step=(), m=pspecs, v=pspecs),
        rng=(),
    )


# ----------------------------------------------------------- int8 compression
def _quantize(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def make_train_step(
    cfg,
    opt_cfg: Optional[AdamWConfig] = None,
    accum_steps: int = 1,
    compress_grads: bool = False,
) -> Callable[[TrainState, Dict[str, jnp.ndarray]], Tuple[TrainState, Dict]]:
    """Build train_step(state, batch) -> (new_state, metrics).

    batch leaves have leading dim = global_batch; with accum_steps > 1 the
    leading dim must divide into ``accum_steps`` microbatches.
    """
    opt_cfg = opt_cfg or AdamWConfig(state_dtype=cfg.opt_state_dtype)
    fam = get_family(cfg)
    loss_fn = fam.loss_fn

    def grads_of(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, batch)
        return loss, grads

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        params = state.params
        batch = {k: constrain(v, ("batch",) + (None,) * (v.ndim - 1))
                 for k, v in batch.items()}

        if accum_steps == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = {
                k: v.reshape((accum_steps, v.shape[0] // accum_steps) + v.shape[1:])
                for k, v in batch.items()
            }
            # accumulate in the optimizer-state dtype: bf16 for >=100B-param
            # archs so the accumulation buffer fits HBM (f32 otherwise)
            acc_dt = jnp.dtype(opt_cfg.state_dtype)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)

            if compress_grads:
                err0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

                def accum(carry, mb):
                    acc, err = carry
                    l, g = grads_of(params, mb)

                    def comp(a, gg, e):
                        q, s = _quantize(gg.astype(jnp.float32) + e)
                        deq = _dequantize(q, s)
                        return a + deq.astype(a.dtype), (gg.astype(jnp.float32) + e) - deq

                    pairs = jax.tree.map(comp, acc, g, err)
                    acc = jax.tree.map(lambda t: t[0], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
                    err = jax.tree.map(lambda t: t[1], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
                    return (acc, err), l

                (gsum, _), losses = jax.lax.scan(accum, (zero, err0), micro)
            else:
                def accum(acc, mb):
                    l, g = grads_of(params, mb)
                    return jax.tree.map(
                        lambda a, gg: a + gg.astype(a.dtype), acc, g), l

                gsum, losses = jax.lax.scan(accum, zero, micro)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = jnp.mean(losses)

        # ZeRO grad sharding hint: constrain grads to the param layout so
        # GSPMD reduce-scatters them instead of all-reduce+slice (active in
        # the weight-gather sharding mode; no-op on a single device).
        rules = current_rules()
        if rules is not None and rules.weight_gather:
            pspecs = fam.param_specs(cfg)
            spec_leaves = jax.tree.leaves(
                pspecs, is_leaf=lambda x: isinstance(x, tuple))
            g_leaves, td = jax.tree.flatten(grads)
            grads = jax.tree_util.tree_unflatten(
                td, [constrain(g, sp) for g, sp in zip(g_leaves, spec_leaves)])

        new_params, new_opt, om = adamw_update(params, grads, state.opt, opt_cfg)
        metrics = {"loss": loss, **om}
        return TrainState(new_params, new_opt, state.rng), metrics

    return train_step
