"""Zero-copy transport fast path: CoW views, shared fan-out payloads,
pipelined channels, raw mmap spill container, and ChannelTimeout."""

import os
import threading
import time

import numpy as np
import pytest

from repro.core import h5, Wilkins
from repro.core.channel import (NO_DATA, Channel, ChannelMux, ChannelTimeout)
from repro.core.datamodel import (BlockOwnership, File, compile_path_pattern,
                                  match_path, reset_transport_stats,
                                  transport_stats)
from repro.core.vol import VOL


# ---------------------------------------------------------------------------
# CoW dataset views
# ---------------------------------------------------------------------------
def test_view_shares_memory_until_write():
    f = File("a.h5")
    ds = f.create_dataset("/g/d", data=np.arange(16.0))
    v = ds.view()
    assert np.shares_memory(v.read_direct(), ds.read_direct())
    assert ds.share_count == 2 and v.share_count == 2

    reset_transport_stats()
    v[0] = 99.0  # first write -> exactly one CoW copy
    s = transport_stats().snapshot()
    assert s["cow_copies"] == 1
    assert s["bytes_copied"] == ds.nbytes
    assert not np.shares_memory(v.read_direct(), ds.read_direct())
    assert v[0] == 99.0 and ds[0] == 0.0  # source untouched

    v[1] = 5.0  # second write: already private, no further copy
    assert transport_stats().snapshot()["cow_copies"] == 1


def test_create_dataset_snapshots_caller_array():
    """h5py semantics: the file owns its buffers. A producer reusing one
    scratch array across steps must not corrupt queued payloads."""
    scratch = np.arange(8.0)
    f = File("a.h5")
    ds = f.create_dataset("/d", data=scratch)
    assert not np.shares_memory(ds.read_direct(), scratch)
    scratch[:] = -1.0  # caller mutates their buffer after the close/serve
    assert ds[0] == 0.0


def test_view_write_through_source_also_copies():
    f = File("a.h5")
    ds = f.create_dataset("/d", data=np.zeros(8))
    v = ds.view()
    ds[3] = 7.0  # writer side materializes; the view keeps the old snapshot
    assert ds[3] == 7.0 and v[3] == 0.0


def test_shared_buffer_reads_are_readonly_aliases():
    f = File("a.h5")
    ds = f.create_dataset("/d", data=np.arange(4))
    v = ds.view()
    alias = v.read_direct()
    assert not alias.flags.writeable
    with pytest.raises(ValueError):
        alias[0] = 1


def test_file_view_is_structural_and_zero_copy():
    f = File("x.h5")
    f.attrs["run"] = 1
    d = f.create_dataset("/a/b", data=np.ones((4, 4)))
    d.attrs["t"] = 2
    reset_transport_stats()
    fv = f.view()
    assert transport_stats().snapshot()["bytes_copied"] == 0
    assert np.shares_memory(fv["/a/b"].read_direct(), d.read_direct())
    assert fv.attrs["run"] == 1 and fv["/a/b"].attrs["t"] == 2


# ---------------------------------------------------------------------------
# fan-out shares one payload
# ---------------------------------------------------------------------------
def test_fanout_serves_one_shared_payload():
    """4 channels on one VOL serve ONE filtered payload, no data copies."""
    vol = VOL("producer")
    chans = [
        Channel(f"p->c{i}", ("producer", 0), ("consumer", i), "o.h5", ["/grid"])
        for i in range(4)
    ]
    vol.outgoing.extend(chans)

    f = File("o.h5")
    src = f.create_dataset("/grid", data=np.arange(1000, dtype=np.uint64))

    reset_transport_stats()
    vol.on_file_close(f)
    assert transport_stats().snapshot()["bytes_copied"] == 0

    got = [c.get(timeout=5) for c in chans]
    arrs = [g["/grid"].read_direct() for g in got]
    for a in arrs:
        assert np.shares_memory(a, src.read_direct())
    np.testing.assert_array_equal(arrs[0], np.arange(1000, dtype=np.uint64))


def test_fanout_workflow_consumers_share_memory():
    yaml = """
tasks:
  - func: producer
    outports:
      - filename: o.h5
        dsets: [{name: /g, memory: 1}]
  - func: consumer
    taskCount: 4
    inports:
      - filename: o.h5
        dsets: [{name: /g, memory: 1}]
"""
    lock = threading.Lock()
    received = []

    def producer():
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=np.arange(256.0))

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            with lock:
                received.append(f["/g"].read_direct())

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer})
    w.run(timeout=60)
    assert len(received) == 4
    for a in received[1:]:
        assert np.shares_memory(received[0], a)


def test_legacy_mode_materializes_copies():
    vol = VOL("producer")
    chans = [
        Channel(f"p->c{i}", ("producer", 0), ("consumer", i), "o.h5", ["/g"],
                zero_copy=False)
        for i in range(3)
    ]
    vol.outgoing.extend(chans)
    f = File("o.h5")
    src = f.create_dataset("/g", data=np.zeros(512))
    reset_transport_stats()
    vol.on_file_close(f)
    assert transport_stats().snapshot()["bytes_copied"] == 3 * src.nbytes
    for c in chans:
        g = c.get(timeout=5)
        assert not np.shares_memory(g["/g"].read_direct(), src.read_direct())


# ---------------------------------------------------------------------------
# raw mmap spill container
# ---------------------------------------------------------------------------
def test_spill_roundtrip_preserves_attrs_and_ownership(tmp_path):
    f = File("snap.h5")
    f.attrs["step"] = 12
    d = f.create_dataset("/grid", data=np.arange(100, dtype=np.uint64))
    d.attrs["timestep"] = 3
    own = BlockOwnership()
    own.add(0, (0,), (50,))
    own.add(1, (50,), (50,))
    d.ownership = own
    f.create_dataset("/p/pos", data=np.ones((10, 3), np.float32))

    path = f.save(str(tmp_path))
    g = File.load(path)
    np.testing.assert_array_equal(g["/grid"][:], np.arange(100, dtype=np.uint64))
    assert g.attrs["step"] == 12
    assert g["/grid"].attrs["timestep"] == 3
    assert g["/grid"].ownership.blocks[1] == ((50,), (50,))
    assert g.total_bytes() == f.total_bytes()


def test_spill_load_is_mmap_backed_and_aligned(tmp_path):
    f = File("snap.h5")
    f.create_dataset("/a", data=np.arange(7, dtype=np.int8))  # odd size
    f.create_dataset("/b", data=np.arange(5, dtype=np.float64))
    path = f.save(str(tmp_path))

    reset_transport_stats()
    g = File.load(path, mmap=True)
    assert transport_stats().snapshot()["bytes_copied"] == 0  # zero-copy load
    assert isinstance(g["/a"].read_direct(), np.memmap) or isinstance(
        g["/a"].read_direct().base, np.memmap)
    np.testing.assert_array_equal(g["/b"][:], np.arange(5, dtype=np.float64))

    # 64-byte segment alignment in the container
    import json
    with open(path, "rb") as fh:
        assert fh.read(8) == b"WLKNRAW1"
        hlen = int.from_bytes(fh.read(8), "little")
        meta = json.loads(fh.read(hlen).decode())
    for info in meta["datasets"].values():
        assert info["offset"] % 64 == 0


def test_spill_roundtrip_empty_and_scalar_datasets(tmp_path):
    f = File("e.h5")
    f.create_dataset("/empty", data=np.zeros((0, 3), np.float32))
    f.create_dataset("/scalar", data=np.float64(7.5), shape=())
    f.create_dataset("/d", data=np.arange(4))
    path = f.save(str(tmp_path))
    g = File.load(path)
    assert g["/empty"].shape == (0, 3)
    assert float(g["/scalar"][()]) == 7.5
    np.testing.assert_array_equal(g["/d"][:], np.arange(4))


def test_spill_loaded_dataset_is_cow_writable(tmp_path):
    f = File("s.h5")
    f.create_dataset("/d", data=np.arange(10.0))
    path = f.save(str(tmp_path))
    g = File.load(path)
    g["/d"][0] = -1.0  # mmap mode="r" buffer -> write triggers CoW copy
    assert g["/d"][0] == -1.0
    h = File.load(path)
    assert h["/d"][0] == 0.0  # container on disk untouched


def test_file_transport_spill_cleans_up(tmp_path):
    """The file:1 path round-trips through the raw container and unlinks."""
    yaml = """
tasks:
  - func: p
    outports:
      - filename: out.h5
        dsets: [{name: /d, file: 1, memory: 0}]
  - func: c
    inports:
      - filename: out.h5
        dsets: [{name: /d, file: 1, memory: 0}]
"""
    got = []

    def p():
        for t in range(3):
            with h5.File("out.h5", "w") as f:
                f.create_dataset("/d", data=np.arange(10.0) + t)

    def c():
        while True:
            f = h5.File("out.h5", "r")
            if f is None:
                break
            got.append(np.asarray(f["/d"][:]))

    w = Wilkins(yaml, {"p": p, "c": c}, spill_dir=str(tmp_path))
    w.run(timeout=30)
    assert len(got) == 3
    np.testing.assert_array_equal(got[2], np.arange(10.0) + 2)
    assert os.listdir(str(tmp_path)) == []  # consumed spills are unlinked


# ---------------------------------------------------------------------------
# queue_depth pipelining
# ---------------------------------------------------------------------------
def _pipeline_yaml(queue_depth):
    return f"""
tasks:
  - func: producer
    outports:
      - filename: o.h5
        dsets: [{{name: /g, memory: 1}}]
  - func: consumer
    inports:
      - filename: o.h5
        queue_depth: {queue_depth}
        dsets: [{{name: /g, memory: 1}}]
"""


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_queue_depth_serves_all_steps_in_order(depth):
    n = 8
    got = []

    def producer():
        for t in range(n):
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/g", data=np.array([t]))

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            time.sleep(0.01)
            got.append(int(f["/g"][0]))

    w = Wilkins(_pipeline_yaml(depth), {"producer": producer, "consumer": consumer})
    rep = w.run(timeout=60)
    assert got == list(range(n))
    assert rep.total_served == n and rep.total_dropped == 0


def test_queue_depth_pipelines_producer():
    """With depth >= 2 a fast producer runs ahead instead of blocking."""
    ch1 = Channel("d1", ("p", 0), ("c", 0), "o.h5", ["/g"], queue_depth=1)
    ch2 = Channel("d2", ("p", 0), ("c", 0), "o.h5", ["/g"], queue_depth=2)
    f = File("o.h5")
    f.create_dataset("/g", data=np.zeros(4))
    assert ch1.offer(f) and ch2.offer(f)
    assert ch2.offer(f)  # second step queues without any consumer
    assert ch2.peek_pending()
    done = []
    t = threading.Thread(target=lambda: done.append(ch1.offer(f)))
    t.start()
    time.sleep(0.05)
    assert not done  # depth-1 rendezvous: producer is blocked
    assert ch1.get(timeout=5) is not None
    t.join(timeout=5)
    assert done == [True]
    assert ch2.get(timeout=5) is not None and ch2.get(timeout=5) is not None


def test_graph_queue_depth_from_yaml():
    from repro.core.graph import WorkflowGraph

    g = WorkflowGraph.from_yaml(_pipeline_yaml(3))
    assert g.edges[0].queue_depth == 3
    w = Wilkins(_pipeline_yaml(3), {"producer": lambda: None, "consumer": lambda: None})
    assert w.channels[0].queue_depth == 3
    with pytest.raises(ValueError):
        WorkflowGraph.from_yaml(_pipeline_yaml(0))


# ---------------------------------------------------------------------------
# ChannelTimeout + mux
# ---------------------------------------------------------------------------
def test_get_timeout_raises_not_none():
    ch = Channel("t", ("p", 0), ("c", 0), "o.h5", ["/g"])
    t0 = time.monotonic()
    with pytest.raises(ChannelTimeout):
        ch.get(timeout=0.05)
    assert time.monotonic() - t0 >= 0.05
    assert ch.stats.consumer_wait_s > 0  # timeout path is accounted

    ch.finish()
    assert ch.get(timeout=0.05) is None  # producer-done is still None


def test_try_get_sentinels():
    ch = Channel("t", ("p", 0), ("c", 0), "o.h5", ["/g"])
    assert ch.try_get() is NO_DATA
    f = File("o.h5")
    f.create_dataset("/g", data=np.ones(3))
    assert ch.offer(f)
    out = ch.try_get()
    assert out is not NO_DATA and out is not None
    ch.finish()
    assert ch.try_get() is None


def test_mux_no_missed_wakeup():
    mux = ChannelMux()
    token = mux.token()
    mux.notify()  # lands "between scan and wait"
    t0 = time.monotonic()
    assert mux.wait(token, timeout=5) != token
    assert time.monotonic() - t0 < 1.0  # returned immediately, no timeout


def test_fanin_mux_delivers_from_any_channel():
    chans = [Channel(f"p{i}", ("p", i), ("c", 0), "o.h5", ["/g"]) for i in range(3)]
    vol = VOL("c")
    vol.incoming.extend(chans)

    def producer(i, delay):
        time.sleep(delay)
        f = File("o.h5")
        f.create_dataset("/g", data=np.array([i]))
        chans[i].offer(f)
        chans[i].finish()

    threads = [threading.Thread(target=producer, args=(i, 0.02 * (i + 1)))
               for i in range(3)]
    for t in threads:
        t.start()
    got = []
    while True:
        f = vol.on_file_open("o.h5")
        if f is None:
            break
        got.append(int(f["/g"][0]))
    for t in threads:
        t.join()
    assert sorted(got) == [0, 1, 2]


# ---------------------------------------------------------------------------
# satellites: global run deadline, matches_file memo, bounded event ring
# ---------------------------------------------------------------------------
def test_run_timeout_is_one_global_deadline():
    """A hung workflow with many task threads fails after ~timeout, not
    N_threads x timeout (the old per-join bug)."""
    yaml = """
tasks:
  - func: a
    taskCount: 3
  - func: b
    taskCount: 3
"""
    release = threading.Event()

    def hang():
        release.wait(5.0)

    w = Wilkins(yaml, {"a": hang, "b": hang})
    t0 = time.monotonic()
    with pytest.raises(TimeoutError):
        w.run(timeout=0.3)
    elapsed = time.monotonic() - t0
    release.set()  # let the leaked daemon threads exit promptly
    assert elapsed < 1.5  # 6 threads x 0.3s per-join would be >= 1.8s


def test_matches_file_is_memoized():
    ch = Channel("m", ("p", 0), ("c", 0), "plt*.h5", ["/g"])
    assert ch.matches_file("plt00010.h5")
    assert not ch.matches_file("other.h5")
    assert ch._match_cache == {"plt00010.h5": True, "other.h5": False}
    # memo hit returns the same answer without recompiling the reverse glob
    assert ch.matches_file("plt00010.h5") and not ch.matches_file("other.h5")


def test_event_ring_is_bounded_with_drop_counter():
    ch = Channel("e", ("p", 0), ("c", 0), "o.h5", ["/g"],
                 record_events=True, events_maxlen=8)
    for i in range(20):
        ch._event_locked("producer", f"tick{i}")
    assert len(ch.stats.events) == 8
    assert ch.stats.events_dropped == 12
    # the ring keeps the NEWEST events (oldest roll off)
    assert ch.stats.events[-1][2] == "tick19"
    assert ch.stats.events[0][2] == "tick12"


# ---------------------------------------------------------------------------
# glob matcher cache
# ---------------------------------------------------------------------------
def test_compiled_pattern_cache_hits():
    m1 = compile_path_pattern("/group1/*")
    m2 = compile_path_pattern("/group1/*")
    assert m1 is m2  # LRU-cached compiled matcher
    assert m1.matches("/group1/grid")
    assert m1.matches("/group1/deep/nest")
    assert not m1.matches("/other/grid")
    assert match_path("/group1/*", "/group1/grid")
