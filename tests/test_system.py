"""End-to-end behaviour tests for the Wilkins workflow system (the paper)."""

import threading
import time

import numpy as np
import pytest

from repro.core import h5, Wilkins, WorkflowGraph


def _grid(t, n=100):
    return np.arange(n, dtype=np.uint64) + t


PIPELINE_YAML = """
tasks:
  - func: producer
    nprocs: 4
    outports:
      - filename: outfile.h5
        dsets:
          - {name: /group1/grid, memory: 1}
          - {name: /group1/particles, memory: 1}
  - func: consumer1
    nprocs: 2
    inports:
      - filename: outfile.h5
        dsets:
          - {name: /group1/grid, memory: 1}
  - func: consumer2
    nprocs: 2
    inports:
      - filename: outfile.h5
        dsets:
          - {name: /group1/particles, memory: 1}
"""


def test_listing1_three_task_workflow():
    """Paper Listing 1: 1 producer, 2 consumers, per-dataset channels."""
    seen = {"c1": [], "c2": []}

    def producer():
        for t in range(3):
            with h5.File("outfile.h5", "w") as f:
                f.create_dataset("/group1/grid", data=_grid(t))
                f.create_dataset("/group1/particles",
                                 data=np.full((50, 3), t, np.float32))

    def consumer1():
        while True:
            f = h5.File("outfile.h5", "r")
            if f is None:
                break
            assert "/group1/grid" in f
            assert "/group1/particles" not in f  # data-centric selection
            seen["c1"].append(int(f["/group1/grid"][0]))

    def consumer2():
        f = h5.File("outfile.h5", "r")
        if f is None:
            return
        assert "/group1/particles" in f and "/group1/grid" not in f
        seen["c2"].append(float(f["/group1/particles"][0, 0]))

    w = Wilkins(PIPELINE_YAML, {"producer": producer, "consumer1": consumer1,
                                "consumer2": consumer2})
    rep = w.run(timeout=60)
    assert seen["c1"] == [0, 1, 2]        # stateful consumer: launched once
    assert seen["c2"] == [0.0, 1.0, 2.0]  # stateless: relaunched per datum
    assert rep.total_served == 6
    assert rep.task_launches[("consumer2", 0)] >= 3


def test_same_code_standalone(tmp_path):
    """Ease-of-adoption contract: identical task code runs standalone."""
    h5.set_standalone_dir(str(tmp_path))
    try:
        def producer():
            with h5.File("outfile.h5", "w") as f:
                f.create_dataset("/group1/grid", data=_grid(7))

        def consumer():
            f = h5.File("outfile.h5", "r")
            return np.asarray(f["/group1/grid"][:])

        producer()  # no workflow: writes a real container file
        got = consumer()
        np.testing.assert_array_equal(got, _grid(7))
    finally:
        h5.set_standalone_dir(".")


def test_file_transport_spill(tmp_path):
    """The ``file: 1`` transport path spills through disk."""
    yaml = """
tasks:
  - func: p
    outports:
      - filename: out.h5
        dsets:
          - {name: /d, file: 1, memory: 0}
  - func: c
    inports:
      - filename: out.h5
        dsets:
          - {name: /d, file: 1, memory: 0}
"""
    got = []

    def p():
        with h5.File("out.h5", "w") as f:
            f.create_dataset("/d", data=np.arange(10.0))

    def c():
        f = h5.File("out.h5", "r")
        if f is not None:
            got.append(np.asarray(f["/d"][:]))

    w = Wilkins(yaml, {"p": p, "c": c}, spill_dir=str(tmp_path))
    w.run(timeout=30)
    assert len(got) == 1
    np.testing.assert_array_equal(got[0], np.arange(10.0))


def test_ensemble_fanin_round_robin():
    """Paper Listing 2 / Fig 3: 4 producers x 2 consumers, round-robin."""
    yaml = """
tasks:
  - func: producer
    taskCount: 4
    outports:
      - filename: outfile.h5
        dsets: [{name: /group1/grid, memory: 1}]
  - func: consumer
    taskCount: 2
    nprocs: 5
    inports:
      - filename: outfile.h5
        dsets: [{name: /group1/grid, memory: 1}]
"""
    g = WorkflowGraph.from_yaml(yaml)
    assert len(g.edges) == 1
    links = g.edges[0].instance_links(4, 2)
    assert links == [(0, 0), (1, 1), (2, 0), (3, 1)]  # Fig 3 exactly

    lock = threading.Lock()
    got = {0: 0, 1: 0}

    def producer():
        with h5.File("outfile.h5", "w") as f:
            f.create_dataset("/group1/grid", data=_grid(0))

    def consumer(comm):
        while True:
            f = h5.File("outfile.h5", "r")
            if f is None:
                break
            with lock:
                got[comm.instance] += 1

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer})
    w.run(timeout=60)
    assert got == {0: 2, 1: 2}  # each consumer serves 2 producers


@pytest.mark.parametrize("topology,np_,nc", [("fan-out", 1, 4), ("NxN", 3, 3)])
def test_ensemble_topologies(topology, np_, nc):
    yaml = f"""
tasks:
  - func: producer
    taskCount: {np_}
    outports:
      - filename: o.h5
        dsets: [{{name: /g, memory: 1}}]
  - func: consumer
    taskCount: {nc}
    inports:
      - filename: o.h5
        dsets: [{{name: /g, memory: 1}}]
"""
    def producer():
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=_grid(1))

    n_recv = []
    lock = threading.Lock()

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            with lock:
                n_recv.append(1)

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer})
    assert w.graph.topology_kind() == topology
    w.run(timeout=60)
    assert len(n_recv) == max(np_, nc)


def test_subset_writers():
    """Paper §3.2.2 (LAMMPS idiom): io_proc/nwriters restricts I/O ranks."""
    yaml = """
tasks:
  - func: sim
    nprocs: 32
    nwriters: 1
    outports:
      - filename: dump.h5
        dsets: [{name: /particles/*, memory: 1}]
  - func: detector
    nprocs: 8
    inports:
      - filename: dump.h5
        dsets: [{name: /particles/*, memory: 1}]
"""
    w = Wilkins(yaml, {"sim": lambda: None, "detector": lambda: None})
    vol = w.vols[("sim", 0)]
    assert vol.io_procs == 1 and vol.nprocs == 32
    comm = w._make_comm("sim", 0)
    assert comm.is_io_proc(0) and not comm.is_io_proc(1)


def test_custom_actions_nyx_idiom(tmp_path):
    """Paper Listing 5: double open/close custom I/O via action script."""
    script = tmp_path / "actions.py"
    script.write_text("""
def nyx(vol, rank):
    def afc_cb(f):
        if vol.file_close_counter % 2 == 1:
            vol.clear_files()  # 1st close: single-rank metadata I/O, don't serve
        else:
            vol.serve_all(True, True)
            vol.clear_files()
            vol.broadcast_files()
    def bfo_cb(name):
        pass
    vol.set_after_file_close(afc_cb)
    vol.set_before_file_open(bfo_cb)
""")
    yaml = """
tasks:
  - func: nyx
    nprocs: 4
    actions: ["actions", "nyx"]
    outports:
      - filename: plt*.h5
        dsets: [{name: /level_0/density, memory: 1}]
  - func: reeber
    nprocs: 2
    inports:
      - filename: plt*.h5
        dsets: [{name: /level_0/density, memory: 1}]
"""
    received = []

    def nyx():
        for t in range(2):
            # first close: metadata-only (single-process small I/O)
            with h5.File(f"plt{t:05d}.h5", "w") as f:
                f.create_dataset("/level_0/density", data=np.zeros(4))
            # second close: bulk parallel write -> serve
            with h5.File(f"plt{t:05d}.h5", "w") as f:
                f.create_dataset("/level_0/density", data=np.full(64, float(t)))

    def reeber():
        while True:
            f = h5.File("plt*.h5", "r")
            if f is None:
                break
            received.append(float(f["/level_0/density"][0]))

    w = Wilkins(yaml, {"nyx": nyx, "reeber": reeber},
                action_dirs=[str(tmp_path)])
    w.run(timeout=60)
    # only the second (bulk) close of each timestep was served
    assert received == [0.0, 1.0]


def test_fault_tolerance_restart():
    """Driver restarts a failing task instance within the restart budget."""
    attempts = {"n": 0}

    def flaky():
        attempts["n"] += 1
        if attempts["n"] == 1:
            raise RuntimeError("injected failure")
        with h5.File("o.h5", "w") as f:
            f.create_dataset("/g", data=_grid(0))

    got = []

    def consumer():
        f = h5.File("o.h5", "r")
        if f is not None:
            got.append(1)

    yaml = """
tasks:
  - func: flaky
    outports:
      - filename: o.h5
        dsets: [{name: /g, memory: 1}]
  - func: consumer
    inports:
      - filename: o.h5
        dsets: [{name: /g, memory: 1}]
"""
    w = Wilkins(yaml, {"flaky": flaky, "consumer": consumer}, max_restarts=2)
    rep = w.run(timeout=30)
    assert attempts["n"] == 2
    assert len(rep.failures) == 1
    assert got == [1]


def test_cycle_topology():
    """Cycles are a supported directed topology (computational steering)."""
    yaml = """
tasks:
  - func: sim
    outports:
      - filename: state.h5
        dsets: [{name: /x, memory: 1}]
    inports:
      - filename: steer.h5
        dsets: [{name: /param, memory: 1}]
  - func: steer
    inports:
      - filename: state.h5
        dsets: [{name: /x, memory: 1}]
    outports:
      - filename: steer.h5
        dsets: [{name: /param, memory: 1}]
"""
    g = WorkflowGraph.from_yaml(yaml)
    assert len(g.edges) == 2  # sim->steer and steer->sim

    steps = {"sim": [], "steer": []}

    def sim():
        x = 1.0
        for t in range(3):
            with h5.File("state.h5", "w") as f:
                f.create_dataset("/x", data=np.array([x]))
            f = h5.File("steer.h5", "r")
            if f is None:
                break
            x = float(f["/param"][0])
            steps["sim"].append(x)

    def steer():
        while True:
            f = h5.File("state.h5", "r")
            if f is None:
                break
            x = float(f["/x"][0])
            steps["steer"].append(x)
            with h5.File("steer.h5", "w") as g2:
                g2.create_dataset("/param", data=np.array([x * 2]))

    w = Wilkins(yaml, {"sim": sim, "steer": steer})
    w.run(timeout=60)
    assert steps["sim"] == [2.0, 4.0, 8.0]  # steering doubled each step


# ---------------------------------------------------------------------------
# failure paths: error chaining + partial report
# ---------------------------------------------------------------------------
def test_run_failure_chains_secondary_errors_and_attaches_report():
    """Every failing task's error is reachable from the raised exception
    (__context__ chain), and the partial WorkflowReport rides on it."""
    from repro.core.driver import WorkflowReport

    yaml = """
tasks:
  - func: a
  - func: b
"""

    def a():
        raise ValueError("boom-a")

    def b():
        time.sleep(0.05)
        raise KeyError("boom-b")

    w = Wilkins(yaml, {"a": a, "b": b})
    with pytest.raises((ValueError, KeyError)) as ei:
        w.run(timeout=30)
    err = ei.value
    kinds, e = set(), err
    while e is not None:
        kinds.add(type(e))
        e = e.__context__
    assert {ValueError, KeyError} <= kinds   # no error silently discarded
    rep = err.report
    assert isinstance(rep, WorkflowReport)
    assert rep.wall_time_s > 0
    assert {f.error for f in rep.failures} == \
        {"ValueError: boom-a", "KeyError: 'boom-b'"}


def test_run_timeout_attaches_partial_report_and_secondary_errors():
    """The join-deadline TimeoutError no longer discards the report, and a
    task error raised before the hang stays chained on it."""
    yaml = """
tasks:
  - func: hang
  - func: fail
"""
    release = threading.Event()

    def hang():
        release.wait(5.0)

    def fail():
        raise RuntimeError("early failure")

    w = Wilkins(yaml, {"hang": hang, "fail": fail})
    with pytest.raises(TimeoutError) as ei:
        w.run(timeout=0.3)
    release.set()
    err = ei.value
    assert "wilkins-hang-0" in str(err)
    rep = err.report                       # partial report, not discarded
    assert rep.channels == [] or rep.channels is w.channels
    assert [f.error for f in rep.failures] == ["RuntimeError: early failure"]
    kinds, e = set(), err
    while e is not None:
        kinds.add(type(e))
        e = e.__context__
    assert RuntimeError in kinds
