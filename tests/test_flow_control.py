"""Flow-control semantics (paper §3.6, Table 2 behaviour)."""

import time

import numpy as np
import pytest

from repro.core import h5, Wilkins
from repro.core.channel import Channel, FlowControl


def test_io_freq_decoding():
    assert FlowControl.from_io_freq(0) == (FlowControl.ALL, 1)
    assert FlowControl.from_io_freq(1) == (FlowControl.ALL, 1)
    assert FlowControl.from_io_freq(5) == (FlowControl.SOME, 5)
    assert FlowControl.from_io_freq(-1) == (FlowControl.LATEST, 1)
    with pytest.raises(ValueError):
        FlowControl.from_io_freq(-3)


def _run_workflow(io_freq, n_steps=6, consumer_sleep=0.05):
    yaml = f"""
tasks:
  - func: producer
    outports:
      - filename: o.h5
        dsets: [{{name: /g, memory: 1}}]
  - func: consumer
    inports:
      - filename: o.h5
        io_freq: {io_freq}
        dsets: [{{name: /g, memory: 1}}]
"""
    got = []

    def producer():
        for t in range(n_steps):
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/g", data=np.array([t]))

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            time.sleep(consumer_sleep)
            got.append(int(f["/g"][0]))

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer})
    rep = w.run(timeout=60)
    return got, rep


def test_flow_control_all():
    got, rep = _run_workflow(io_freq=1)
    assert got == [0, 1, 2, 3, 4, 5]      # every timestep served
    assert rep.total_dropped == 0


def test_flow_control_some():
    got, rep = _run_workflow(io_freq=2)
    assert got == [1, 3, 5]               # every 2nd close served
    assert rep.total_dropped == 3


def test_flow_control_some_n5():
    got, rep = _run_workflow(io_freq=5, n_steps=10)
    assert got == [4, 9]
    assert rep.total_dropped == 8


def test_flow_control_latest_drops_when_consumer_busy():
    got, rep = _run_workflow(io_freq=-1, n_steps=8, consumer_sleep=0.15)
    # only timesteps where the consumer was already waiting are served; the
    # rest are dropped at zero cost -- exact counts are timing-dependent.
    assert rep.total_dropped > 0
    assert got == sorted(got)             # in-order, never stale reordering
    assert len(got) + rep.total_dropped == 8


def test_flow_control_reduces_producer_wait():
    """The paper's Table 2 effect: 'some' saves producer idle time."""
    _, rep_all = _run_workflow(io_freq=1, n_steps=6, consumer_sleep=0.08)
    _, rep_some = _run_workflow(io_freq=3, n_steps=6, consumer_sleep=0.08)
    wait_all = sum(c.stats.producer_wait_s for c in rep_all.channels)
    wait_some = sum(c.stats.producer_wait_s for c in rep_some.channels)
    assert wait_some < wait_all


def test_gantt_events_recorded():
    yaml = """
tasks:
  - func: p
    outports:
      - filename: o.h5
        dsets: [{name: /g, memory: 1}]
  - func: c
    inports:
      - filename: o.h5
        dsets: [{name: /g, memory: 1}]
"""
    def p():
        for t in range(2):
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/g", data=np.array([t]))

    def c():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break

    w = Wilkins(yaml, {"p": p, "c": c}, record_events=True)
    rep = w.run(timeout=30)
    events = rep.gantt_events()
    kinds = {e[3] for e in events}
    assert "serve" in kinds and "recv" in kinds  # Fig 5 reconstruction data


# ---------------------------------------------------------------------------
# waiter accounting (latest rendezvous fan-in)
# ---------------------------------------------------------------------------
def test_waiter_accounting_dedupes_mux_and_get():
    """A consumer the VOL mux marked waiting that then blocks in ``get`` on
    the same channel is ONE waiter, not two registrations."""
    import threading

    from repro.core.channel import ChannelTimeout

    ch = Channel("w", ("p", 0), ("c", 0), "o.h5", ["/g"], io_freq=-1)
    observed = []
    registered = threading.Event()

    def consumer():
        ch.set_consumer_waiting(True)   # the VOL mux marks us...
        registered.set()
        try:
            with pytest.raises(ChannelTimeout):
                ch.get(timeout=0.5)     # ...then the same thread blocks in get
        finally:
            ch.set_consumer_waiting(False)

    th = threading.Thread(target=consumer)
    th.start()
    assert registered.wait(2.0)
    deadline = time.monotonic() + 2.0
    while time.monotonic() < deadline and th.is_alive():
        observed.append(ch.waiting_consumers())
        time.sleep(0.02)
    th.join()
    assert max(observed) == 1           # never double-counted
    assert ch.waiting_consumers() == 0  # balanced after both exits


def test_latest_fanin_rendezvous():
    """2 producers -> 1 `latest` consumer through the VOL mux: data arrives
    fresh and in per-producer order, and waiter accounting drains to zero."""
    yaml = """
tasks:
  - func: producer
    taskCount: 2
    outports:
      - filename: o.h5
        dsets: [{name: /g, memory: 1}]
  - func: consumer
    inports:
      - filename: o.h5
        io_freq: -1
        dsets: [{name: /g, memory: 1}]
"""
    got = []

    def producer(comm):
        for t in range(5):
            with h5.File("o.h5", "w") as f:
                f.create_dataset("/g", data=np.array([comm.instance * 100 + t]))
            time.sleep(0.02)

    def consumer():
        while True:
            f = h5.File("o.h5", "r")
            if f is None:
                break
            got.append(int(f["/g"][0]))

    w = Wilkins(yaml, {"producer": producer, "consumer": consumer})
    rep = w.run(timeout=60)
    assert rep.total_served + rep.total_dropped == 10
    assert rep.total_served == len(got)
    for inst in (0, 1):
        mine = [g for g in got if g // 100 == inst]
        assert mine == sorted(mine)     # never stale reordering per producer
    assert all(c.waiting_consumers() == 0 for c in rep.channels)
