"""Optional-hypothesis shim for the test suite.

The container image does not ship ``hypothesis`` and the environment forbids
installing it.  Property-based tests import ``given``/``settings``/``st`` from
here: with hypothesis present they run normally; without it they are skipped
(instead of erroring the whole collection, which killed the tier-1 run).
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Accepts any strategy construction chain without doing anything."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, _name):
            return self

        def map(self, *_a, **_k):
            return self

        def filter(self, *_a, **_k):
            return self

    class _St:
        def __getattr__(self, _name):
            return _StrategyStub()

    st = _St()
