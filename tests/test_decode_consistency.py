"""Decode-path equivalence beyond the basics: ring-buffer sliding-window
cache past the window boundary (hybrid), and encoder-decoder prefill+decode
vs the full decoder forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.registry import get_family

KEY = jax.random.PRNGKey(7)


@pytest.mark.slow
def test_hybrid_ring_cache_past_window():
    """Decoding far past cfg.window must match the windowed full forward --
    the ring buffer overwrites old slots, the full forward masks them."""
    cfg = get_config("zamba2-2.7b", reduced=True).replace(window=16)
    fam = get_family(cfg)
    params = fam.init(KEY, cfg)
    rng = np.random.default_rng(3)
    b, total = 1, 48                      # 3x the window
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, total)), jnp.int32)

    from repro.models import hybrid as M
    from repro.models import layers as L

    # incremental: prefill 8, decode the rest one by one
    cache = fam.init_cache(cfg, b, total, dtype=jnp.float32)
    _, cache = fam.prefill(params, cfg, {"tokens": toks[:, :8]}, cache)
    dec_logits = {}
    for t in range(8, total):
        logits, cache = fam.decode_step(params, cfg, toks[:, t:t + 1], cache)
        dec_logits[t] = np.asarray(logits[0, 0])

    # reference: full forward at selected positions (windowed attention)
    for t in (20, 33, total - 1):
        h, _ = M.forward(params, cfg, toks[:, :t + 1])
        want = np.asarray(L.unembed(params["embed"], h[:, -1:])[0, 0])
        np.testing.assert_allclose(dec_logits[t], want, atol=5e-3, rtol=5e-3,
                                   err_msg=f"position {t}")


def test_encdec_decode_matches_forward():
    cfg = get_config("whisper-base", reduced=True)
    fam = get_family(cfg)
    params = fam.init(KEY, cfg)
    rng = np.random.default_rng(4)
    b, s = 1, 10
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    frames = jnp.asarray(rng.normal(size=(b, cfg.source_len, cfg.d_model)) * 0.02,
                         jnp.float32)

    from repro.models import encdec as M
    from repro.models import layers as L

    enc_out = M.encode(params, cfg, frames)
    xkv = M.cross_kv(params, cfg, enc_out)
    h, _ = M.decode(params, cfg, toks, xkv)
    want = np.asarray(L.unembed(params["embed"], h[:, -1:]))

    cache = fam.init_cache(cfg, b, 32, dtype=jnp.float32)
    _, cache = fam.prefill(params, cfg,
                           {"tokens": toks[:, :-1], "frames": frames}, cache)
    got, _ = fam.decode_step(params, cfg, toks[:, -1:], cache)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-3, rtol=2e-3)


def test_vlm_prefix_changes_logits():
    cfg = get_config("internvl2-76b", reduced=True)
    fam = get_family(cfg)
    params = fam.init(KEY, cfg)
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, 8)), jnp.int32)

    from repro.models import transformer as M
    from repro.models import layers as L

    v1 = jnp.asarray(rng.normal(size=(1, cfg.vision_tokens, cfg.d_model)) * 0.1,
                     jnp.float32)
    v2 = jnp.zeros_like(v1)
    h1, _, _ = M.forward(params, cfg, toks, prefix_embeds=v1)
    h2, _, _ = M.forward(params, cfg, toks, prefix_embeds=v2)
    l1 = np.asarray(L.unembed(params["embed"], h1[:, -1:]))
    l2 = np.asarray(L.unembed(params["embed"], h2[:, -1:]))
    assert np.abs(l1 - l2).max() > 1e-4  # vision prefix reaches the text tail
    assert h1.shape[1] == cfg.vision_tokens + 8
