"""Elastic rescale: supervisor-driven M->N restart with channel
re-partitioning, plus the stall/health watchdog.

The workflow under test mirrors the recovery suite's 2-producer /
2-consumer diamond, but the consumers run ``taskCount: 2`` with
redistributing inports and an ``on_failure: {rescale: {nslots: N}}``
policy: a crash (or a watchdog-declared stall, or a programmatic
``comm.rescale`` call) brings the consumer down and relaunches it at a
DIFFERENT instance count.  The checkpointed accumulator is sharded along
axis 0 (``sharded_axes={"acc": 0}``), so the surgery re-cuts it across
the new instances with ``reshard_blocks`` and replays the undelivered
steps into the re-partitioned channels -- the concatenated final output
must be byte-identical to a crash-free run at any size.
"""

import json

import numpy as np
import pytest

from repro.core import (FailurePolicy, FaultSpec, TelemetryTimeline, Wilkins,
                        WorkflowGraph, h5, reshard_blocks, world)
from repro.core.redistribute import even_blocks

STEPS = 4
GLOBAL = 50  # deliberately not divisible by 3: ragged shards in the sweep

DSETS = {"a.h5": "/g", "b.h5": "/h"}


def _a(t):
    return np.arange(GLOBAL, dtype=np.float64) + 100.0 * t


def _b(t):
    return 2.0 * np.arange(GLOBAL, dtype=np.float64) + 1000.0 * t


EXPECTED_C1 = sum(_a(t) for t in range(STEPS))
EXPECTED_C2 = sum(_a(t) + 3.0 * _b(t) for t in range(STEPS))


def _rescale_yaml(n1=1, n2=1, extra_c1="", nprocs_c1=1):
    """2 single-instance producers -> 2 two-instance elastic consumers."""
    return f"""
tasks:
  - func: p1
    outports:
      - filename: a.h5
        dsets: [{{name: /g, memory: 1}}]
    on_failure:
      restart: {{max_retries: 3}}
  - func: p2
    outports:
      - filename: b.h5
        dsets: [{{name: /h, memory: 1}}]
    on_failure:
      restart: {{max_retries: 3}}
  - func: c1
    taskCount: 2
    nprocs: {nprocs_c1}
    {extra_c1}
    inports:
      - filename: a.h5
        redistribute: 1
        dsets: [{{name: /g, memory: 1}}]
    on_failure:
      rescale: {{nslots: {n1}, max_retries: 3}}
  - func: c2
    taskCount: 2
    inports:
      - filename: a.h5
        redistribute: 1
        dsets: [{{name: /g, memory: 1}}]
      - filename: b.h5
        redistribute: 1
        dsets: [{{name: /h, memory: 1}}]
    on_failure:
      rescale: {{nslots: {n2}, max_retries: 3}}
"""


def _make_producer(filename, dset, make):
    def producer():
        comm = world()
        state = {"step": np.zeros((), np.int64)}
        restored = comm.restore(state)
        start = 0
        if restored is not None:
            _, state = restored
            start = int(state["step"])
        for t in range(start, STEPS):
            with h5.File(filename, "w") as f:
                f.create_dataset(DSETS[filename], data=make(t))
            comm.checkpoint({"step": np.array(t + 1, np.int64)})
    return producer


def _make_consumer(results, key, primary, extras=(), weights=(1.0,)):
    """Accumulate this instance's slab of every step; shard-checkpoint it.

    The accumulator is sized from the instance's frozen ``RedistSpec``
    (slot block along axis 0), so the same function body runs unchanged
    at ANY instance count -- including the post-rescale incarnations,
    whose restored ``acc`` was re-cut by the surgery.
    """
    def consumer():
        comm = world()
        spec = comm.resolve_redist_spec(port=primary)
        _, shape = even_blocks((GLOBAL,), spec.nslots)[spec.slot]
        like = {"acc": np.zeros(shape, np.float64),
                "n": np.zeros((), np.int64)}
        state = like
        restored = comm.restore(like)
        if restored is not None:
            _, state = restored
        acc = np.asarray(state["acc"]).copy()
        n = int(state["n"])
        while True:
            f0 = h5.File(primary, "r")
            if f0 is None:
                break
            delta = weights[0] * f0[DSETS[primary]][...]
            for w, extra in zip(weights[1:], extras):
                fx = h5.File(extra, "r")
                delta = delta + w * fx[DSETS[extra]][...]
            acc = acc + delta
            n += 1
            comm.checkpoint({"acc": acc, "n": np.array(n, np.int64)},
                            sharded_axes={"acc": 0})
        results[(key, comm.instance)] = (acc.copy(), n)
    return consumer


def _rescale_workflow(tmp_path, tag, n1=1, n2=1, extra_c1="", nprocs_c1=1):
    results = {}
    funcs = {
        "p1": _make_producer("a.h5", "/g", _a),
        "p2": _make_producer("b.h5", "/h", _b),
        "c1": _make_consumer(results, "c1", "a.h5"),
        "c2": _make_consumer(results, "c2", "a.h5", extras=("b.h5",),
                             weights=(1.0, 3.0)),
    }
    w = Wilkins(_rescale_yaml(n1=n1, n2=n2, extra_c1=extra_c1,
                              nprocs_c1=nprocs_c1),
                funcs, spill_dir=str(tmp_path / tag))
    return w, results


def _assert_byte_identical(w, results):
    """Concatenated per-instance accumulators == the closed-form global sum,
    byte for byte, at whatever size each consumer ENDED the run."""
    for key, expected in (("c1", EXPECTED_C1), ("c2", EXPECTED_C2)):
        n_inst = w.graph.tasks[key].task_count
        parts = []
        for j in range(n_inst):
            assert (key, j) in results, \
                f"{key}[{j}] never finished (have {sorted(results)})"
            acc, n = results[(key, j)]
            assert n == STEPS, f"{key}[{j}] saw {n}/{STEPS} steps"
            parts.append(acc)
        got = np.concatenate(parts)
        assert got.tobytes() == expected.tobytes(), \
            f"{key}: output differs from crash-free reference"


# ---------------------------------------------------------------------------
# baseline: the elastic workflow without any fault is byte-exact at size 2
# ---------------------------------------------------------------------------
def test_crash_free_elastic_workflow(tmp_path):
    w, results = _rescale_workflow(tmp_path, "ref")
    rep = w.run(timeout=60)
    _assert_byte_identical(w, results)
    assert rep.rescales == []
    assert rep.stalls == []
    assert w.graph.tasks["c1"].task_count == 2


# ---------------------------------------------------------------------------
# tentpole: crash -> policy rescale (shrink AND grow) -> byte-identical
# ---------------------------------------------------------------------------
def test_policy_rescale_shrink_byte_identical(tmp_path):
    """c1 crashes mid-stream; ``rescale: {nslots: 1}`` relaunches it at
    half size, re-cuts the shard checkpoints, replays the undelivered
    steps -- and the event is visible in report, summary and timeline."""
    w, results = _rescale_workflow(tmp_path, "shrink", n1=1)
    rep = w.run(timeout=60,
                faults=FaultSpec(task="c1", point="recv", step=1, instance=0))
    _assert_byte_identical(w, results)
    assert w.graph.tasks["c1"].task_count == 1

    assert len(rep.rescales) == 1
    ev = rep.rescales[0]
    assert ev["task"] == "c1"
    assert ev["old_nslots"] == 2 and ev["new_nslots"] == 1
    assert ev["trigger"] == "policy"
    assert ev["latency_s"] >= 0.0
    assert "InjectedFault" in ev["reason"]
    # visibility: timeline event, summary line, scheduler snapshot
    tl = rep.timeline.events("rescale")
    assert len(tl) == 1 and tl[0]["task"] == "c1"
    assert tl[0]["old_nslots"] == 2 and tl[0]["new_nslots"] == 1
    assert "RESCALE c1: nslots 2->1" in rep.summary()
    assert rep.scheduler["rescale_events"] == tl
    assert rep.scheduler["rescales"] == 1


def test_policy_rescale_grow_byte_identical(tmp_path):
    """c2 (the fan-in consumer) grows 2->3: both inbound edges are re-cut
    to three slots and the ragged 50-element shards still sum exactly."""
    w, results = _rescale_workflow(tmp_path, "grow", n2=3)
    rep = w.run(timeout=60,
                faults=FaultSpec(task="c2", point="open", step=2, instance=1))
    _assert_byte_identical(w, results)
    assert w.graph.tasks["c2"].task_count == 3
    assert len(rep.rescales) == 1
    assert rep.rescales[0]["new_nslots"] == 3
    assert "RESCALE c2: nslots 2->3" in rep.summary()


def test_rescale_with_producer_restart_in_same_run(tmp_path):
    """A producer crash (plain restart) and a consumer rescale in ONE run:
    the two recovery protocols compose."""
    w, results = _rescale_workflow(tmp_path, "mixed", n1=1)
    rep = w.run(timeout=60, faults=[
        FaultSpec(task="p1", point="close", step=1),
        FaultSpec(task="c1", point="recv", step=2, instance=1),
    ])
    _assert_byte_identical(w, results)
    assert [r["task"] for r in rep.restarts] == ["p1"]
    assert [r["task"] for r in rep.rescales] == ["c1"]


# ---------------------------------------------------------------------------
# satellite: the M->N sweep -- every task, every step boundary, every
# target size in {1, 2, 3} (grow, same-size, shrink)
# ---------------------------------------------------------------------------
def _sweep_cases():
    cases = []
    for n in (1, 2, 3):
        for pt in ("open", "recv"):
            for s in range(STEPS):
                cases.append(("c1", pt, s, n))
            # c2 opens two files per loop iteration: steps run 0..2*STEPS-1
            for s in range(2 * STEPS):
                cases.append(("c2", pt, s, n))
    return cases


SWEEP = _sweep_cases()
#: fast representative subset: shrink/grow/same-size, first/mid/last step,
#: pre-delivery (open) and post-delivery (recv) windows, both consumers
FAST_SWEEP = [
    ("c1", "recv", 0, 1),          # shrink from the very first delivery
    ("c1", "open", STEPS - 1, 3),  # grow at the last pre-delivery window
    ("c2", "recv", 3, 1),          # fan-in shrink mid-stream (b.h5 leg)
    ("c2", "open", 5, 3),          # fan-in grow late (a.h5 leg, step 2)
    ("c1", "recv", 2, 2),          # same-size rescale == managed restart
]


def _run_sweep_case(tmp_path, task, point, step, n):
    kw = {"n1": n} if task == "c1" else {"n2": n}
    w, results = _rescale_workflow(tmp_path, f"{task}_{point}_{step}_{n}",
                                   **kw)
    rep = w.run(timeout=60,
                faults=FaultSpec(task=task, point=point, step=step))
    _assert_byte_identical(w, results)
    assert w.graph.tasks[task].task_count == n
    if n != 2:
        assert [r["task"] for r in rep.rescales] == [task]
        assert rep.rescales[0]["new_nslots"] == n


@pytest.mark.parametrize("task,point,step,n", FAST_SWEEP)
def test_rescale_sweep_representative(tmp_path, task, point, step, n):
    _run_sweep_case(tmp_path, task, point, step, n)


@pytest.mark.slow
@pytest.mark.parametrize("task,point,step,n", SWEEP)
def test_rescale_sweep_exhaustive(tmp_path, task, point, step, n):
    _run_sweep_case(tmp_path, task, point, step, n)


# ---------------------------------------------------------------------------
# programmatic trigger: comm.rescale() without any fault
# ---------------------------------------------------------------------------
def test_programmatic_rescale_from_task_code(tmp_path):
    """A steering task calls ``comm.rescale("c1", nslots=1)`` mid-run; the
    supervisor interrupts the live instances and the last arriver performs
    the surgery -- no crash anywhere."""
    results = {}

    def p1():
        comm = world()
        state = {"step": np.zeros((), np.int64)}
        restored = comm.restore(state)
        start = int(restored[1]["step"]) if restored is not None else 0
        for t in range(start, STEPS):
            with h5.File("a.h5", "w") as f:
                f.create_dataset("/g", data=_a(t))
            comm.checkpoint({"step": np.array(t + 1, np.int64)})
            if t == 1 and start == 0:
                op = comm.rescale("c1", nslots=1, reason="steering decision")
                assert op is not None

    funcs = {
        "p1": p1,
        "p2": _make_producer("b.h5", "/h", _b),
        "c1": _make_consumer(results, "c1", "a.h5"),
        "c2": _make_consumer(results, "c2", "a.h5", extras=("b.h5",),
                             weights=(1.0, 3.0)),
    }
    w = Wilkins(_rescale_yaml(n1=1), funcs, spill_dir=str(tmp_path / "api"))
    rep = w.run(timeout=60)
    _assert_byte_identical(w, results)
    assert w.graph.tasks["c1"].task_count == 1
    assert len(rep.rescales) == 1
    ev = rep.rescales[0]
    assert ev["trigger"] == "api" and ev["reason"] == "steering decision"
    assert "RESCALE c1" in rep.summary()


# ---------------------------------------------------------------------------
# satellite: health watchdog -- stall detection and rescale-down
# ---------------------------------------------------------------------------
def test_watchdog_stall_triggers_rescale_down(tmp_path):
    """c1[0] goes silent (injected stall far past ``stall_timeout_s``); the
    watchdog declares it stalled, fences it, and applies the rescale
    policy.  The zombie wakes into a superseded world and exits quietly;
    output stays byte-identical at the new size."""
    w, results = _rescale_workflow(
        tmp_path, "stall", n1=1, extra_c1="stall_timeout_s: 0.25")
    rep = w.run(timeout=60,
                faults=FaultSpec(task="c1", kind="stall", point="recv",
                                 step=1, instance=0, seconds=1.5))
    _assert_byte_identical(w, results)
    assert w.graph.tasks["c1"].task_count == 1

    assert len(rep.stalls) == 1
    st = rep.stalls[0]
    assert st["task"] == "c1" and st["instance"] == 0
    assert st["silent_s"] >= st["timeout_s"] == 0.25
    assert st["action"] == "rescale"
    assert len(rep.rescales) == 1
    assert rep.rescales[0]["trigger"] == "stall"
    # visibility: timeline + summary
    assert len(rep.timeline.events("stall")) == 1
    assert "STALL c1[0]" in rep.summary()
    assert "RESCALE c1: nslots 2->1" in rep.summary()


def test_watchdog_hysteresis_spares_slow_tasks(tmp_path):
    """Slow-but-progressing is NOT stalled: per-step delays shorter than
    the window keep the heartbeats coming, so the 2-strike hysteresis
    never fires and the task finishes at its original size."""
    w, results = _rescale_workflow(
        tmp_path, "slow", n1=1, extra_c1="stall_timeout_s: 0.6")
    rep = w.run(timeout=60,
                faults=FaultSpec(task="c1", kind="slow_io", point="recv",
                                 step=None, times=None, attempt=None,
                                 seconds=0.12))
    _assert_byte_identical(w, results)
    assert w.graph.tasks["c1"].task_count == 2
    assert rep.stalls == []
    assert rep.rescales == []


# ---------------------------------------------------------------------------
# nprocs-only rescale: logical rank count moves, topology does not
# ---------------------------------------------------------------------------
def test_nprocs_only_rescale(tmp_path):
    results = {}
    yaml = _rescale_yaml(n1=1).replace(
        "rescale: {nslots: 1, max_retries: 3}",
        "rescale: {nprocs: 2, max_retries: 3}", 1)
    funcs = {
        "p1": _make_producer("a.h5", "/g", _a),
        "p2": _make_producer("b.h5", "/h", _b),
        "c1": _make_consumer(results, "c1", "a.h5"),
        "c2": _make_consumer(results, "c2", "a.h5", extras=("b.h5",),
                             weights=(1.0, 3.0)),
    }
    w = Wilkins(yaml, funcs, spill_dir=str(tmp_path / "nprocs"))
    rep = w.run(timeout=60,
                faults=FaultSpec(task="c1", point="recv", step=1, instance=0))
    _assert_byte_identical(w, results)
    # the instance count never moved; the logical rank count did
    assert w.graph.tasks["c1"].task_count == 2
    assert w.graph.tasks["c1"].nprocs == 2
    assert len(rep.rescales) == 1
    ev = rep.rescales[0]
    assert ev["old_nslots"] == 2 and ev["new_nslots"] == 2
    assert ev["old_nprocs"] == 1 and ev["new_nprocs"] == 2
    assert "nprocs 1->2" in rep.summary()
    # every consumer-side frozen spec now subdivides slots into 2 ranks
    for ch in rep.channels:
        if ch.consumer[0] == "c1" and ch.redistribute is not None:
            assert ch.redistribute.nranks == 2


# ---------------------------------------------------------------------------
# satellite: rescale/stall events survive the telemetry JSON roundtrip
# ---------------------------------------------------------------------------
def test_rescale_events_survive_json_roundtrip(tmp_path):
    w, results = _rescale_workflow(
        tmp_path, "roundtrip", n1=1, extra_c1="stall_timeout_s: 0.25")
    rep = w.run(timeout=60, faults=[
        FaultSpec(task="c1", kind="stall", point="recv", step=1, instance=0,
                  seconds=1.5),
        FaultSpec(task="c2", point="recv", step=3, instance=0),
    ])
    _assert_byte_identical(w, results)
    text = rep.timeline.to_json()
    json.loads(text)  # well-formed
    tl2 = TelemetryTimeline.from_json(text)
    assert tl2.events("rescale") == rep.timeline.events("rescale")
    assert tl2.events("stall") == rep.timeline.events("stall")
    assert len(tl2.events("rescale")) == 2  # c1 (stall) + c2 (policy)
    assert {e["trigger"] for e in tl2.events("rescale")} == \
        {"stall", "policy"}
    # the summary names both surgeries and the stall
    s = rep.summary()
    assert "RESCALE c1: nslots 2->1" in s
    assert "RESCALE c2: nslots 2->1" in s
    assert "STALL c1[0]" in s


# ---------------------------------------------------------------------------
# satellite: parse-time validation of rescale / stall declarations
# ---------------------------------------------------------------------------
def _yaml_with_policy(policy, extra_task="", inport_extra=""):
    return f"""
tasks:
  - func: src
    {extra_task}
    outports:
      - filename: x.h5
        dsets: [{{name: /d, memory: 1}}]
  - func: sink
    inports:
      - filename: x.h5
        {inport_extra}
        dsets: [{{name: /d, memory: 1}}]
    on_failure:
      {policy}
"""


def test_graph_rejects_rescale_on_producer():
    yaml = """
tasks:
  - func: src
    outports:
      - filename: x.h5
        dsets: [{name: /d, memory: 1}]
    on_failure:
      rescale: {nslots: 2}
  - func: sink
    inports:
      - filename: x.h5
        dsets: [{name: /d, memory: 1}]
"""
    with pytest.raises(ValueError, match="task 'src'.*pure consumer"):
        WorkflowGraph.from_yaml(yaml)


def test_graph_rejects_rescale_with_multi_instance_producer():
    yaml = _yaml_with_policy("rescale: {nslots: 3}",
                             extra_task="taskCount: 2")
    with pytest.raises(ValueError,
                       match="task 'sink'.*'src' has taskCount=2"):
        WorkflowGraph.from_yaml(yaml)


def test_graph_rejects_rescale_on_file_mode_edge():
    yaml = """
tasks:
  - func: src
    outports:
      - filename: x.h5
        dsets: [{name: /d, file: 1, memory: 0}]
  - func: sink
    inports:
      - filename: x.h5
        dsets: [{name: /d, file: 1, memory: 0}]
    on_failure:
      rescale: {nslots: 2}
"""
    with pytest.raises(ValueError, match="task 'sink'.*memory transport"):
        WorkflowGraph.from_yaml(yaml)


def test_graph_rejects_rescale_on_latest_mode_edge():
    yaml = _yaml_with_policy("rescale: {nslots: 2}",
                             inport_extra="io_freq: -1")
    with pytest.raises(ValueError, match="task 'sink'.*io_freq: -1"):
        WorkflowGraph.from_yaml(yaml)


def test_graph_rejects_rescale_on_isolated_task():
    yaml = """
tasks:
  - func: lonely
    on_failure:
      rescale: {nslots: 2}
"""
    with pytest.raises(ValueError, match="task 'lonely'.*no inport edge"):
        WorkflowGraph.from_yaml(yaml)


def test_graph_rejects_stall_timeout_without_managed_policy():
    yaml = _yaml_with_policy("restart: {max_retries: 2}",
                             ).replace("on_failure:",
                                       "stall_timeout_s: 1.0\n    on_failure:")
    with pytest.raises(ValueError,
                       match="task 'sink'.*stall_timeout_s requires"):
        WorkflowGraph.from_yaml(yaml)


def test_graph_rejects_nonpositive_stall_timeout():
    yaml = _yaml_with_policy("rescale: {nslots: 1}").replace(
        "on_failure:", "stall_timeout_s: 0\n    on_failure:")
    with pytest.raises(ValueError,
                       match="task 'sink'.*stall_timeout_s must be > 0"):
        WorkflowGraph.from_yaml(yaml)


def test_policy_rejects_bad_rescale_mappings():
    with pytest.raises(ValueError, match="task 't'"):
        FailurePolicy.from_yaml({"rescale": {"nslots": 0}}, "t")
    with pytest.raises(ValueError, match="cannot combine rescale"):
        FailurePolicy.from_yaml({"rescale": {"nslots": 2}, "drop": {}}, "t")
    with pytest.raises(ValueError, match="cannot combine restart"):
        FailurePolicy.from_yaml(
            {"rescale": {"nslots": 2}, "restart": {}}, "t")


def test_driver_validates_programmatic_rescale(tmp_path):
    """The same structural rules guard ``RunSupervisor.rescale`` calls that
    never went through YAML validation."""
    w, _ = _rescale_workflow(tmp_path, "val")
    with pytest.raises(ValueError, match="unknown task"):
        w._validate_rescale_request("nope", nslots=1)
    with pytest.raises(ValueError, match="nothing to change"):
        w._validate_rescale_request("c1")
    with pytest.raises(ValueError, match="nslots must be >= 1"):
        w._validate_rescale_request("c1", nslots=0)
    with pytest.raises(ValueError, match="pure consumer"):
        w._validate_rescale_request("p1", nslots=2)
    # a legal request validates clean
    w._validate_rescale_request("c1", nslots=3)
    w._validate_rescale_request("p1", nprocs=2)  # nprocs-only is fine


# ---------------------------------------------------------------------------
# satellite: reshard_blocks hardening -- M->N with N>M, ragged shards,
# empty source blocks, byte-equivalence against the single-shard baseline
# ---------------------------------------------------------------------------
def test_reshard_blocks_grow_ragged():
    g = np.arange(11.0)
    out = reshard_blocks([g[:4], g[4:8], g[8:]], 5)
    assert [o.shape[0] for o in out] == [3, 2, 2, 2, 2]
    assert np.concatenate(out).tobytes() == g.tobytes()


def test_reshard_blocks_empty_source_block():
    g = np.arange(11.0)
    out = reshard_blocks([g[:4], g[4:4], g[4:]], 2)
    assert np.concatenate(out).tobytes() == g.tobytes()


def test_reshard_blocks_more_ranks_than_elements():
    out = reshard_blocks([np.arange(3.0)], 5)
    assert [o.shape[0] for o in out] == [1, 1, 1, 0, 0]
    assert np.concatenate(out).tolist() == [0.0, 1.0, 2.0]


def test_reshard_blocks_all_empty():
    out = reshard_blocks([np.zeros((0,), np.float32)] * 2, 3)
    assert [o.shape for o in out] == [(0,)] * 3
    assert all(o.dtype == np.float32 for o in out)


def test_reshard_blocks_preserves_dtype():
    out = reshard_blocks([np.arange(5, dtype=np.int32)], 2)
    assert all(o.dtype == np.int32 for o in out)


@pytest.mark.parametrize("m,n", [(1, 4), (2, 3), (3, 2), (4, 1), (3, 5)])
def test_reshard_blocks_matches_single_shard_baseline(m, n):
    """Re-cutting an M-way decomposition must land byte-identical to
    cutting the stitched global array directly."""
    rng = np.random.default_rng(m * 10 + n)
    g = rng.standard_normal((13, 7))
    cuts = [s for s, _ in even_blocks((13,), m)][1:]
    blocks = np.split(g, [c[0] for c in cuts], axis=0)
    via_m = reshard_blocks(blocks, n)
    via_1 = reshard_blocks([g], n)
    assert len(via_m) == len(via_1) == n
    for a, b in zip(via_m, via_1):
        assert a.tobytes() == b.tobytes()


def test_reshard_blocks_axis1():
    a = np.arange(24.0).reshape(4, 6)
    out = reshard_blocks([a[:, :2], a[:, 2:]], 4, axis=1)
    assert [o.shape for o in out] == [(4, 2), (4, 2), (4, 1), (4, 1)]
    assert np.concatenate(out, axis=1).tobytes() == a.tobytes()


def test_reshard_blocks_rejects_bad_inputs():
    with pytest.raises(ValueError, match="at least one source block"):
        reshard_blocks([], 2)
    with pytest.raises(ValueError, match="new_nranks must be >= 1"):
        reshard_blocks([np.arange(3.0)], 0)
    with pytest.raises(ValueError, match="axis 2 out of range"):
        reshard_blocks([np.arange(3.0)], 2, axis=2)
    with pytest.raises(ValueError, match="disagree off-axis"):
        reshard_blocks([np.zeros((2, 3)), np.zeros((2, 4))], 2)
