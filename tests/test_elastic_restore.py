"""Elastic scaling: a checkpoint taken on one mesh restores onto a DIFFERENT
mesh and training continues bit-compatibly.

Checkpoints store *global* host arrays (save_pytree snapshots via
np.asarray), so restoring is just device_put with the new mesh's shardings --
this test proves it end to end on 8 virtual devices: train on a (2,4) mesh,
checkpoint, restore onto a (4,2) mesh (as after losing/gaining nodes), train
one more step, and match the uninterrupted run's loss exactly.
"""

import os
import subprocess

import pytest
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import tempfile
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.configs import get_config
    from repro.parallel.sharding import DEFAULT_RULES, tree_shardings, use_mesh
    from repro.train import (AdamWConfig, AsyncCheckpointer, SyntheticCorpus,
                             DataConfig, init_state, make_train_step,
                             restore_latest, state_specs)

    cfg = get_config("tinyllama-1.1b", reduced=True)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=3)
    corpus = SyntheticCorpus(dcfg)
    step_raw = make_train_step(cfg, ocfg)

    def mesh_of(shape):
        return Mesh(np.array(jax.devices()).reshape(shape), ("data", "model"))

    def run_steps(mesh, state, steps, start):
        with use_mesh(mesh, DEFAULT_RULES):
            jstep = jax.jit(lambda s, b: step_raw(s, b))
            losses = []
            for i in range(start, start + steps):
                batch = {k: jnp.asarray(v) for k, v in corpus.batch(i).items()}
                state, m = jstep(state, batch)
                losses.append(float(m["loss"]))
        return state, losses

    # uninterrupted reference on mesh A
    mesh_a = mesh_of((2, 4))
    with use_mesh(mesh_a, DEFAULT_RULES):
        sh_a = tree_shardings(mesh_a, state_specs(cfg), DEFAULT_RULES)
        s0 = jax.jit(lambda k: init_state(k, cfg, ocfg),
                     out_shardings=sh_a)(jax.random.PRNGKey(0))
    ref, ref_losses = run_steps(mesh_a, s0, 3, 0)

    # interrupted: 2 steps on mesh A, checkpoint, restore on mesh B (4,2)
    with tempfile.TemporaryDirectory() as d:
        part, l01 = run_steps(mesh_a, s0, 2, 0)
        ck = AsyncCheckpointer(d, keep=1)
        ck.save(2, part, block=True)
        del part

        mesh_b = mesh_of((4, 2))         # "the cluster changed shape"
        host_like = jax.tree.map(np.asarray, s0)
        step_no, host_state = restore_latest(d, host_like)
        assert step_no == 2
        sh_b = tree_shardings(mesh_b, state_specs(cfg), DEFAULT_RULES)
        state_b = jax.tree.map(
            lambda h, s: jax.device_put(np.asarray(h), s),
            host_state, sh_b)
        # NamedTuple reconstruction (tree.map preserves structure)
        _, l2 = run_steps(mesh_b, state_b, 1, 2)

    np.testing.assert_allclose(l01 + l2, ref_losses, rtol=1e-5)
    print("ELASTIC_OK", l01 + l2)
""")


@pytest.mark.slow
def test_checkpoint_restores_across_mesh_shapes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ELASTIC_OK" in out.stdout
